"""Paper Figs. 2 & 5: node-utilization traces for steered campaigns.

Runs the molecular-design campaign (simulate / train / infer task mix,
resource reallocation on retrain) and derives utilization from the
``repro.observe`` event log — the per-task lifecycle trace — rather than
an ad-hoc sampler thread. Also reports:

  * the static-vs-adaptive reallocation comparison (the paper's
    utilization-maximizing steering: an ``AdaptiveReallocator`` shifts
    slots toward the backlogged pool on a synthetic imbalanced
    workload);
  * the stateful-caching ablation from the protein-generation study
    (Fig. 5's '+30% folding throughput from keeping models in RAM').
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import numpy as np

from repro.app import AppSpec, ColmenaApp, QueueSpec, SteeringSpec, TaskDef
from repro.core import BatchRetrainThinker, stateful_task
from repro.observe import render_text, run_bursty, run_two_pool


def _sim(x, dt=0.02):
    time.sleep(dt)
    return float(-np.sum((np.asarray(x) - 0.3) ** 2))


def _train(X, y, dt=0.1):
    time.sleep(dt)
    X = np.asarray(X); y = np.asarray(y)
    return np.linalg.lstsq(X, y, rcond=None)[0]


class CampaignThinker(BatchRetrainThinker):
    def __init__(self, queues, dim=4, **kw):
        super().__init__(queues, **kw)
        self.dim = dim
        self.rng = np.random.default_rng(0)
        self.w = None

    def simulate_args(self):
        base = self.rng.uniform(-1, 1, self.dim)
        if self.w is not None:
            base = np.clip(0.5 * self.w[: self.dim] + 0.5 * base, -1, 1)
        return (base,)

    def make_train_task(self):
        X = np.stack([np.asarray(r.args[0]) for r in self.database])
        y = np.asarray([r.value for r in self.database])
        return (X, y), {}

    def on_train(self, result):
        if result.success:
            self.w = np.asarray(result.value)


def run_campaign(n_workers: int = 6, max_results: int = 60):
    """Molecular-design campaign; utilization read off the event log."""
    app = ColmenaApp(AppSpec(
        tasks=[
            TaskDef(fn=_sim, method="simulate", pool="simulate"),
            TaskDef(fn=_train, method="train", pool="ml"),
        ],
        queues=QueueSpec(topics=("simulate", "train")),
        pools={"simulate": n_workers - 1, "ml": 1, "default": 1},
        steering=SteeringSpec(CampaignThinker, dict(
            n_slots=n_workers - 1, retrain_after=10,
            max_results=max_results, ml_slots=1)),
    ))
    app.execute(timeout=120)

    report = app.observe_report()
    util = {
        "simulate": report["utilization"].get("simulate", 0.0),
        "ml": report["utilization"].get("ml", 0.0),
    }
    return util, report, app.thinker.train_rounds


def reallocation_comparison(
    n_slots: int = 8, n_sim: int = 48, n_ml: int = 8, task_s: float = 0.05,
) -> Tuple[Dict, Dict]:
    """Static split vs AdaptiveReallocator on the same imbalanced workload.

    The ml pool's work drains early; a static split strands its slots
    while the adaptive policy migrates them to the sim backlog, raising
    whole-campaign utilization (the acceptance comparison)."""
    static, _, _ = run_two_pool(
        n_slots=n_slots, n_sim=n_sim, n_ml=n_ml, task_s=task_s, adaptive=False)
    adaptive, _, _ = run_two_pool(
        n_slots=n_slots, n_sim=n_sim, n_ml=n_ml, task_s=task_s, adaptive=True)
    return static, adaptive


def elastic_comparison(
    n_bursts: int = 3, burst_size: int = 18, gap_s: float = 0.35, task_s: float = 0.03,
) -> Tuple[dict, dict]:
    """Static max-size fleet vs ElasticScaler on the same bursty load.

    Both runs execute identical work; the static fleet idles through
    every inter-burst gap while the elastic one shrinks to the PoolSpec
    floor, so utilization (busy seconds / worker-seconds of capacity)
    must come out >= static — the elastic acceptance gate."""
    static = run_bursty(elastic=False, n_bursts=n_bursts, burst_size=burst_size,
                        gap_s=gap_s, task_s=task_s)
    elastic = run_bursty(elastic=True, n_bursts=n_bursts, burst_size=burst_size,
                         gap_s=gap_s, task_s=task_s)
    return static, elastic


def main_elastic_gate(quick: bool = True, recorder=None) -> None:
    """CI gate: elastic fleet utilization >= static under bursty load,
    with all work completed on both sides."""
    static, elastic = elastic_comparison(burst_size=12 if quick else 24)
    s_u, e_u = static["utilization"], elastic["utilization"]
    print(f"elastic,static_util,{s_u:.3f}")
    print(f"elastic,elastic_util,{e_u:.3f}")
    print(f"elastic,gain_pct,{(e_u - s_u) / max(s_u, 1e-9) * 100:.0f}")
    print(f"elastic,resizes,{elastic['resizes']}")
    if recorder is not None:
        recorder.metric("elastic_static_util", s_u)
        recorder.metric("elastic_util", e_u, gate=(">=", s_u))
        recorder.metric("elastic_resizes", elastic["resizes"])
    assert static["completed"] == elastic["completed"], (
        f"work mismatch: static {static['completed']} vs elastic {elastic['completed']}"
    )
    assert e_u >= s_u, f"elastic utilization {e_u:.3f} < static {s_u:.3f}"


@stateful_task
def _fold_cached(seq, registry=None):
    """Protein-folding stand-in: 'model load' is cached in worker RAM."""
    if "model" not in registry:
        time.sleep(0.05)                      # expensive load, once
        registry["model"] = np.random.default_rng(0).standard_normal((64, 64))
    time.sleep(0.005)                         # the actual fold
    return float(registry["model"].sum())


def _fold_uncached(seq):
    time.sleep(0.05)                          # reload every task
    time.sleep(0.005)
    return 0.0


def stateful_caching_ablation(n_tasks: int = 20):
    """Fig. 5 lesson: keeping models in RAM raises task throughput.

    Driver mode: no steering agents — the caller drives the composed
    queues directly."""
    rates = {}
    for mode, fn in (("cached", _fold_cached), ("uncached", _fold_uncached)):
        app = ColmenaApp(AppSpec(tasks={"fold": fn}, pools={"default": 2}, observe=None))
        with app.run() as handle:
            t0 = time.monotonic()
            for i in range(n_tasks):
                handle.queues.send_inputs(f"seq{i}", method="fold")
            for _ in range(n_tasks):
                assert handle.queues.get_result(timeout=30).success
            rates[mode] = n_tasks / (time.monotonic() - t0)
    return rates


def main(quick: bool = True, recorder=None):
    util, report, rounds = run_campaign(max_results=30 if quick else 80)
    print(f"utilization,simulate_busy_frac,{util['simulate']:.3f}")
    print(f"utilization,ml_busy_frac,{util['ml']:.3f}")
    print(f"utilization,train_rounds,{rounds}")
    print(render_text(report))

    static, adaptive = reallocation_comparison(
        n_sim=24 if quick else 48, n_ml=4 if quick else 8)
    s_u, a_u = static["utilization"]["total"], adaptive["utilization"]["total"]
    print(f"reallocation,static_util,{s_u:.3f}")
    print(f"reallocation,adaptive_util,{a_u:.3f}")
    print(f"reallocation,gain_pct,{(a_u - s_u) / max(s_u, 1e-9) * 100:.0f}")
    print(f"reallocation,lifecycle_complete,{int(adaptive['lifecycle']['complete'])}")

    main_elastic_gate(quick=quick, recorder=recorder)

    rates = stateful_caching_ablation(12 if quick else 40)
    speedup = rates["cached"] / rates["uncached"]
    print(f"stateful_cache,cached_rate,{rates['cached']:.1f}")
    print(f"stateful_cache,uncached_rate,{rates['uncached']:.1f}")
    print(f"stateful_cache,speedup,{speedup:.2f}")
    if recorder is not None:
        recorder.metric("simulate_busy_frac", util["simulate"])
        recorder.metric("ml_busy_frac", util["ml"])
        recorder.metric("realloc_static_util", s_u)
        recorder.metric("realloc_adaptive_util", a_u)
        recorder.metric("stateful_cache_speedup_x", speedup, unit="x")
    return util, rates


if __name__ == "__main__":
    main(quick=False)
