"""Paper Figs. 2 & 5: node-utilization traces for steered campaigns.

Runs the molecular-design campaign (simulate / train / infer task mix,
resource reallocation on retrain) on a simulated worker pool and emits a
utilization timeline: fraction of workers busy per task type over time,
plus the stateful-caching ablation from the protein-generation study
(Fig. 5's '+30% folding throughput from keeping models in RAM').
"""

from __future__ import annotations

import time
from collections import defaultdict
from typing import Dict, List

import numpy as np

from repro.core import (
    BatchRetrainThinker,
    InMemoryConnector,
    LocalColmenaQueues,
    ResourceRequest,
    Store,
    TaskServer,
    WorkerPool,
    stateful_task,
)


def _sim(x, dt=0.02):
    time.sleep(dt)
    return float(-np.sum((np.asarray(x) - 0.3) ** 2))


def _train(X, y, dt=0.1):
    time.sleep(dt)
    X = np.asarray(X); y = np.asarray(y)
    return np.linalg.lstsq(X, y, rcond=None)[0]


class Campaign(BatchRetrainThinker):
    def __init__(self, queues, dim=4, **kw):
        super().__init__(queues, **kw)
        self.dim = dim
        self.rng = np.random.default_rng(0)
        self.w = None

    def simulate_args(self):
        base = self.rng.uniform(-1, 1, self.dim)
        if self.w is not None:
            base = np.clip(0.5 * self.w[: self.dim] + 0.5 * base, -1, 1)
        return (base,)

    def make_train_task(self):
        X = np.stack([np.asarray(r.args[0]) for r in self.database])
        y = np.asarray([r.value for r in self.database])
        return (X, y), {}

    def on_train(self, result):
        if result.success:
            self.w = np.asarray(result.value)


def run_campaign(n_workers: int = 6, max_results: int = 60):
    q = LocalColmenaQueues(topics=["simulate", "train"])
    pools = {
        "simulate": WorkerPool("simulate", n_workers - 1),
        "ml": WorkerPool("ml", 1),
        "default": WorkerPool("default", 1),
    }
    thinker = Campaign(q, n_slots=n_workers - 1, retrain_after=10,
                       max_results=max_results, ml_slots=1)
    server = TaskServer(q, {"simulate": _sim, "train": _train}, pools=pools).start()

    trace: List[Dict] = []
    import threading
    stop = threading.Event()

    def sampler():
        t0 = time.monotonic()
        while not stop.is_set():
            row = {"t": time.monotonic() - t0}
            for name, pool in pools.items():
                states = pool.worker_states()
                row[name] = sum(1 for w in states if w.busy) / max(len(states), 1)
            trace.append(row)
            time.sleep(0.01)

    s = threading.Thread(target=sampler, daemon=True)
    s.start()
    thinker.run(timeout=120)
    stop.set()
    server.stop()
    util = {
        "simulate": np.mean([r["simulate"] for r in trace]) if trace else 0.0,
        "ml": np.mean([r["ml"] for r in trace]) if trace else 0.0,
    }
    return util, trace, thinker.train_rounds


@stateful_task
def _fold_cached(seq, registry=None):
    """Protein-folding stand-in: 'model load' is cached in worker RAM."""
    if "model" not in registry:
        time.sleep(0.05)                      # expensive load, once
        registry["model"] = np.random.default_rng(0).standard_normal((64, 64))
    time.sleep(0.005)                         # the actual fold
    return float(registry["model"].sum())


def _fold_uncached(seq):
    time.sleep(0.05)                          # reload every task
    time.sleep(0.005)
    return 0.0


def stateful_caching_ablation(n_tasks: int = 20):
    """Fig. 5 lesson: keeping models in RAM raises task throughput."""
    rates = {}
    for mode, fn in (("cached", _fold_cached), ("uncached", _fold_uncached)):
        q = LocalColmenaQueues()
        server = TaskServer(q, {"fold": fn}, n_workers=2).start()
        t0 = time.monotonic()
        for i in range(n_tasks):
            q.send_inputs(f"seq{i}", method="fold")
        for _ in range(n_tasks):
            assert q.get_result(timeout=30).success
        rates[mode] = n_tasks / (time.monotonic() - t0)
        server.stop()
    return rates


def main(quick: bool = True):
    util, trace, rounds = run_campaign(max_results=30 if quick else 80)
    print(f"utilization,simulate_busy_frac,{util['simulate']:.3f}")
    print(f"utilization,ml_busy_frac,{util['ml']:.3f}")
    print(f"utilization,train_rounds,{rounds}")
    rates = stateful_caching_ablation(12 if quick else 40)
    speedup = rates["cached"] / rates["uncached"]
    print(f"stateful_cache,cached_rate,{rates['cached']:.1f}")
    print(f"stateful_cache,uncached_rate,{rates['uncached']:.1f}")
    print(f"stateful_cache,speedup,{speedup:.2f}")
    return util, rates


if __name__ == "__main__":
    main(quick=False)
