"""Paper Fig. 4: multi-site backends — local vs. federated deployment.

The paper compares Parsl (direct connection, SSH tunnels) against
Globus Compute + Globus Transfer (cloud-routed control, ~100 ms dispatch
latency, >=1 s data transfer) and shows equivalent scientific output
once ahead-of-time bulk transfer hides the latency.

Here every site is the *same* ``AppSpec`` with different backend
fields — the portability claim the app layer exists for:
  * ``local``              — in-process queues + threaded server (~ Parsl);
  * ``federated``          — ``pipe`` queues, server in its own spawned
                             process, model by value (~ Globus Compute,
                             naive);
  * ``federated+fabric``   — same, plus a file-connector fabric with the
                             shared model proxied once ahead of time;
  * ``federated+multipool`` — a multi-resource remote site: two named
                             ``PoolSpec``s (a wide "cpu" pool and a
                             narrow "accel" pool) rebuilt inside the
                             spawned server process, tasks routed by the
                             registry's pool field.
"""

from __future__ import annotations

import os
import tempfile
import time
from typing import Dict, Optional

import numpy as np

from repro.app import (
    AppSpec,
    ColmenaApp,
    FabricSpec,
    ObserveSpec,
    PoolSpec,
    QueueSpec,
    ServerSpec,
    SteeringSpec,
    TaskDef,
)
from repro.core import ConstantInflightThinker


def _score(model, x) -> float:
    time.sleep(0.01)
    m = np.asarray(model)
    return float(np.asarray(x) @ m[: len(np.asarray(x))])


def _run_site(
    backend: str,
    in_process: bool,
    model: np.ndarray,
    x: np.ndarray,
    n: int,
    workers: int = 4,
    fabric: FabricSpec = None,
    proxy_model: bool = False,
) -> Dict:
    def steering(app):
        payload = app.store.proxy(model) if proxy_model else model
        work = [((payload, x), {}) for _ in range(n)]
        return ConstantInflightThinker(app.queues, work, method="score", n_parallel=workers)

    app = ColmenaApp(AppSpec(
        tasks=[TaskDef(fn=_score, method="score")],
        queues=QueueSpec(backend=backend),
        pools={"default": workers},
        server=ServerSpec(in_process=in_process),
        fabric=fabric,
        observe=None,
        steering=SteeringSpec(steering),
    ))
    with app.run(timeout=120) as handle:
        t0 = time.monotonic()
        handle.wait()
        elapsed = time.monotonic() - t0
        results = handle.thinker.results
    ok = sum(1 for r in results if r.success)
    lat = np.median([r.timing.total for r in results if r.timing.total])
    return {"tasks_per_s": ok / elapsed, "median_latency_ms": lat * 1000, "ok": ok}


def _run_multipool_site(model: np.ndarray, x: np.ndarray, n: int) -> Dict:
    """Federated multi-resource site: one spawned server process hosting
    two named pools (rebuilt from PoolSpecs inside the child), tasks
    routed by the registry's pool field — the deployment shape the old
    single-default-pool restriction ruled out."""
    app = ColmenaApp(AppSpec(
        tasks=[
            TaskDef(fn=_score, method="score_cpu", pool="cpu"),
            TaskDef(fn=_score, method="score_accel", pool="accel"),
        ],
        queues=QueueSpec(backend="pipe"),
        pools={"cpu": PoolSpec("cpu", 3), "accel": PoolSpec("accel", 1, warm_capacity=8)},
        server=ServerSpec(in_process=False),
        observe=None,
    ))
    half = n // 2
    with app.run(timeout=120) as handle:
        t0 = time.monotonic()
        for i in range(n):
            method = "score_cpu" if i < half else "score_accel"
            handle.queues.send_inputs(model, x, method=method)
        results = [handle.queues.get_result(timeout=60) for _ in range(n)]
        elapsed = time.monotonic() - t0
    ok = sum(1 for r in results if r is not None and r.success)
    lat = np.median([r.timing.total for r in results if r is not None and r.timing.total])
    return {"tasks_per_s": ok / elapsed, "median_latency_ms": lat * 1000, "ok": ok}


def traced_federated_run(n: int = 8, out_dir: Optional[str] = None) -> Dict:
    """Cross-process tracing demo: the parent and the spawned server each
    write their own JSONL event log (one per side of the pipe); merging
    them yields one causal trace per task — zero lifecycle gaps — that
    exports straight to Perfetto."""
    from repro.observe import (
        EventLog,
        export_perfetto,
        lifecycle_gaps,
        lifecycle_order_violations,
        merge_jsonl,
    )

    tmp = out_dir or tempfile.mkdtemp(prefix="multisite_trace_")
    jsonl = os.path.join(tmp, "events.jsonl")
    model = np.random.default_rng(0).standard_normal(256)
    x = np.arange(8, dtype=np.float64)
    app = ColmenaApp(AppSpec(
        tasks=[TaskDef(fn=_score, method="score")],
        queues=QueueSpec(backend="pipe"),
        pools={"default": 2},
        server=ServerSpec(in_process=False),
        observe=ObserveSpec(jsonl_path=jsonl),
    ))
    server_jsonl = app.spec.observe.resolved_server_jsonl()
    with app.run(timeout=120) as handle:
        for _ in range(n):
            handle.queues.send_inputs(model, x, method="score")
        results = [handle.queues.get_result(timeout=60) for _ in range(n)]
    ok = sum(1 for r in results if r is not None and r.success)

    merged = EventLog(capacity=1 << 18)
    for ev in merge_jsonl([jsonl, server_jsonl]):
        merged.emit(ev)
    gaps = lifecycle_gaps(merged)
    violations = lifecycle_order_violations(merged)
    trace_path = os.path.join(tmp, "trace.json")
    export_perfetto([jsonl, server_jsonl], trace_path)
    return {
        "ok": ok,
        "lifecycle_gaps": len(gaps),
        "order_violations": len(violations),
        "trace_path": trace_path,
    }


def main(quick: bool = True, recorder=None) -> Dict[str, Dict]:
    n = 16 if quick else 64
    model = np.random.default_rng(0).standard_normal(4096)
    x = np.arange(8, dtype=np.float64)
    out = {}

    # Site A: local queues, model by value (Parsl-like single site)
    out["local"] = _run_site("local", True, model, x, n)

    # Site B: cross-process queues + server process, model by value
    out["federated"] = _run_site("pipe", False, model, x, n)

    # Site C: cross-process + fabric, model proxied once ahead of time
    out["federated+fabric"] = _run_site(
        "pipe", False, model, x, n,
        fabric=FabricSpec(connector="file", threshold=4096),
        proxy_model=True,
    )

    # Site D: cross-process with two named pools inside the server child
    out["federated+multipool"] = _run_multipool_site(model, x, n)
    assert out["federated+multipool"]["ok"] == n, "multipool site dropped tasks"

    for mode, r in out.items():
        print(f"multisite,{mode},{r['tasks_per_s']:.1f},{r['median_latency_ms']:.1f}")
        if recorder is not None:
            recorder.metric(f"{mode}_tasks_per_s", r["tasks_per_s"], unit="tasks/s")
            recorder.metric(f"{mode}_median_latency_ms", r["median_latency_ms"], unit="ms")

    # Cross-process tracing: parent + server logs must merge into one
    # complete causal trace (the federated observability acceptance).
    traced = traced_federated_run(n=min(n, 12))
    print(f"multisite,traced,{traced['ok']},gaps={traced['lifecycle_gaps']},"
          f"violations={traced['order_violations']},trace={traced['trace_path']}")
    assert traced["lifecycle_gaps"] == 0, "merged federated trace has lifecycle gaps"
    if recorder is not None:
        recorder.metric("traced_lifecycle_gaps", traced["lifecycle_gaps"],
                        gate=("<=", 0))
        recorder.metric("traced_order_violations", traced["order_violations"])
    return out


if __name__ == "__main__":
    main(quick=False)
