"""Paper Fig. 4: multi-site backends — local vs. federated deployment.

The paper compares Parsl (direct connection, SSH tunnels) against
Globus Compute + Globus Transfer (cloud-routed control, ~100 ms dispatch
latency, >=1 s data transfer) and shows equivalent scientific output
once ahead-of-time bulk transfer hides the latency.

Here: LocalColmenaQueues (in-proc ~ Parsl) vs. PipeColmenaQueues across
a process boundary with injected control-latency (~ Globus Compute),
with and without manual ahead-of-time proxying of the shared model.
"""

from __future__ import annotations

import multiprocessing as mp
import time
from typing import Dict

import numpy as np

from repro.core import (
    ConstantInflightThinker,
    FileConnector,
    LocalColmenaQueues,
    PipeColmenaQueues,
    Store,
    TaskServer,
    serve_forever,
)


def _score(model, x) -> float:
    time.sleep(0.01)
    m = np.asarray(model)
    return float(np.asarray(x) @ m[: len(np.asarray(x))])


def _run(queues, work, workers=4, in_process=True, methods=None):
    methods = methods or {"score": _score}
    server = None
    proc = None
    if in_process:
        server = TaskServer(queues, methods, n_workers=workers).start()
    else:
        proc = mp.get_context("spawn").Process(
            target=serve_forever, args=(queues, methods),
            kwargs={"n_workers": workers}, daemon=True,
        )
        proc.start()
    thinker = ConstantInflightThinker(queues, work, method="score", n_parallel=workers)
    t0 = time.monotonic()
    thinker.run(timeout=120)
    elapsed = time.monotonic() - t0
    if server:
        server.stop()
    if proc:
        queues.send_kill_signal()
        proc.join(timeout=5)
        if proc.is_alive():
            proc.terminate()
    ok = sum(1 for r in thinker.results if r.success)
    lat = np.median([r.timing.total for r in thinker.results if r.timing.total])
    return {"tasks_per_s": ok / elapsed, "median_latency_ms": lat * 1000, "ok": ok}


def main(quick: bool = True) -> Dict[str, Dict]:
    n = 16 if quick else 64
    model = np.random.default_rng(0).standard_normal(4096)
    x = np.arange(8, dtype=np.float64)
    out = {}

    # Site A: local queues, model by value (Parsl-like single site)
    q = LocalColmenaQueues()
    out["local"] = _run(q, [((model, x), {}) for _ in range(n)])

    # Site B: cross-process queues, model by value (federated, naive)
    q = PipeColmenaQueues()
    out["federated"] = _run(q, [((model, x), {}) for _ in range(n)], in_process=False)

    # Site C: cross-process + fabric, model proxied once ahead of time
    store = Store("multisite", FileConnector())
    q = PipeColmenaQueues(proxystore=store, proxy_threshold=4096)
    model_ref = store.proxy(model)
    out["federated+fabric"] = _run(q, [((model_ref, x), {}) for _ in range(n)],
                                   in_process=False)

    for mode, r in out.items():
        print(f"multisite,{mode},{r['tasks_per_s']:.1f},{r['median_latency_ms']:.1f}")
    return out


if __name__ == "__main__":
    main(quick=False)
