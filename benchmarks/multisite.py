"""Paper Fig. 4: multi-site backends — local vs. federated deployment.

The paper compares Parsl (direct connection, SSH tunnels) against
Globus Compute + Globus Transfer (cloud-routed control, ~100 ms dispatch
latency, >=1 s data transfer) and shows equivalent scientific output
once ahead-of-time bulk transfer hides the latency.

Here every site is the *same* ``AppSpec`` with different backend
fields — the portability claim the app layer exists for:
  * ``local``              — in-process queues + threaded server (~ Parsl);
  * ``federated``          — ``pipe`` queues, server in its own spawned
                             process, model by value (~ Globus Compute,
                             naive);
  * ``federated+fabric``   — same, plus a file-connector fabric with the
                             shared model proxied once ahead of time;
  * ``federated+multipool`` — a multi-resource remote site: two named
                             ``PoolSpec``s (a wide "cpu" pool and a
                             narrow "accel" pool) rebuilt inside the
                             spawned server process, tasks routed by the
                             registry's pool field.
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.app import (
    AppSpec,
    ColmenaApp,
    FabricSpec,
    PoolSpec,
    QueueSpec,
    ServerSpec,
    SteeringSpec,
    TaskDef,
)
from repro.core import ConstantInflightThinker


def _score(model, x) -> float:
    time.sleep(0.01)
    m = np.asarray(model)
    return float(np.asarray(x) @ m[: len(np.asarray(x))])


def _run_site(
    backend: str,
    in_process: bool,
    model: np.ndarray,
    x: np.ndarray,
    n: int,
    workers: int = 4,
    fabric: FabricSpec = None,
    proxy_model: bool = False,
) -> Dict:
    def steering(app):
        payload = app.store.proxy(model) if proxy_model else model
        work = [((payload, x), {}) for _ in range(n)]
        return ConstantInflightThinker(app.queues, work, method="score", n_parallel=workers)

    app = ColmenaApp(AppSpec(
        tasks=[TaskDef(fn=_score, method="score")],
        queues=QueueSpec(backend=backend),
        pools={"default": workers},
        server=ServerSpec(in_process=in_process),
        fabric=fabric,
        observe=None,
        steering=SteeringSpec(steering),
    ))
    with app.run(timeout=120) as handle:
        t0 = time.monotonic()
        handle.wait()
        elapsed = time.monotonic() - t0
        results = handle.thinker.results
    ok = sum(1 for r in results if r.success)
    lat = np.median([r.timing.total for r in results if r.timing.total])
    return {"tasks_per_s": ok / elapsed, "median_latency_ms": lat * 1000, "ok": ok}


def _run_multipool_site(model: np.ndarray, x: np.ndarray, n: int) -> Dict:
    """Federated multi-resource site: one spawned server process hosting
    two named pools (rebuilt from PoolSpecs inside the child), tasks
    routed by the registry's pool field — the deployment shape the old
    single-default-pool restriction ruled out."""
    app = ColmenaApp(AppSpec(
        tasks=[
            TaskDef(fn=_score, method="score_cpu", pool="cpu"),
            TaskDef(fn=_score, method="score_accel", pool="accel"),
        ],
        queues=QueueSpec(backend="pipe"),
        pools={"cpu": PoolSpec("cpu", 3), "accel": PoolSpec("accel", 1, warm_capacity=8)},
        server=ServerSpec(in_process=False),
        observe=None,
    ))
    half = n // 2
    with app.run(timeout=120) as handle:
        t0 = time.monotonic()
        for i in range(n):
            method = "score_cpu" if i < half else "score_accel"
            handle.queues.send_inputs(model, x, method=method)
        results = [handle.queues.get_result(timeout=60) for _ in range(n)]
        elapsed = time.monotonic() - t0
    ok = sum(1 for r in results if r is not None and r.success)
    lat = np.median([r.timing.total for r in results if r is not None and r.timing.total])
    return {"tasks_per_s": ok / elapsed, "median_latency_ms": lat * 1000, "ok": ok}


def main(quick: bool = True) -> Dict[str, Dict]:
    n = 16 if quick else 64
    model = np.random.default_rng(0).standard_normal(4096)
    x = np.arange(8, dtype=np.float64)
    out = {}

    # Site A: local queues, model by value (Parsl-like single site)
    out["local"] = _run_site("local", True, model, x, n)

    # Site B: cross-process queues + server process, model by value
    out["federated"] = _run_site("pipe", False, model, x, n)

    # Site C: cross-process + fabric, model proxied once ahead of time
    out["federated+fabric"] = _run_site(
        "pipe", False, model, x, n,
        fabric=FabricSpec(connector="file", threshold=4096),
        proxy_model=True,
    )

    # Site D: cross-process with two named pools inside the server child
    out["federated+multipool"] = _run_multipool_site(model, x, n)
    assert out["federated+multipool"]["ok"] == n, "multipool site dropped tasks"

    for mode, r in out.items():
        print(f"multisite,{mode},{r['tasks_per_s']:.1f},{r['median_latency_ms']:.1f}")
    return out


if __name__ == "__main__":
    main(quick=False)
