"""Paper §Task Queues: queue + serialization overhead microbenchmarks.

Measures per-message cost of the two queue implementations across payload
sizes (the paper's Redis-vs-Pipes tradeoff) and the serializer in
isolation, plus proxy creation/resolution cost (the fabric's overhead
floor)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import InMemoryConnector, LocalColmenaQueues, PipeColmenaQueues, Store
from repro.core.serialization import SERIALIZER


def _bench(fn, n: int = 50) -> float:
    t0 = time.monotonic()
    for _ in range(n):
        fn()
    return (time.monotonic() - t0) / n * 1e6  # us


def queue_roundtrip_us(qcls, payload: np.ndarray, n: int = 30) -> float:
    q = qcls()

    def once():
        q.send_inputs(payload, method="f")
        task = q.get_task(timeout=5)
        task.mark("compute_started")
        task.set_success(None)
        task.mark("compute_ended")
        q.send_result(task)
        q.get_result(timeout=5)

    return _bench(once, n)


def main(quick: bool = True):
    sizes = [1_000, 1_000_000] if quick else [1_000, 100_000, 1_000_000, 10_000_000]
    rows = []
    for size in sizes:
        payload = np.zeros(size // 8)
        blob, m = SERIALIZER.serialize(payload)
        ser_us = _bench(lambda: SERIALIZER.serialize(payload), 20)
        de_us = _bench(lambda: SERIALIZER.deserialize(blob), 20)
        local_us = queue_roundtrip_us(LocalColmenaQueues, payload, 20 if quick else 50)
        pipe_us = queue_roundtrip_us(PipeColmenaQueues, payload, 10 if quick else 30)
        store = Store(f"ovh-{size}", InMemoryConnector())
        proxy_us = _bench(lambda: store.proxy(payload).resolve(), 20)
        rows.append((size, ser_us, de_us, local_us, pipe_us, proxy_us))
        print(f"overhead,{size},{ser_us:.1f},{de_us:.1f},{local_us:.1f},{pipe_us:.1f},{proxy_us:.1f}")
    return rows


if __name__ == "__main__":
    main(quick=False)
