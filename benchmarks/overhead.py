"""Dispatch-path overhead: warm-worker caching x batched dispatch.

Reproduces the paper's two remaining headline optimizations — "data
fabrics that reduce communication overhead" and "workflow tasks that
cache costly operations between invocations" — on a small-task workload
where every task references the same proxied model payload through the
fabric.

Four configurations are compared (cold/warm x unbatched/batched); every
number is derived from the ``repro.observe`` event log (makespan, span
breakdown, cache hit-rate, batch occupancy), not ad-hoc wall-clock
deltas. The store's own cache is disabled so "cold" pays the real
fabric fetch per task, as separate worker nodes would.

Acceptance: warm-cache batched dispatch must cut per-task overhead by
>= 2x vs cold unbatched (the benchmark raises otherwise, so the CI
smoke job fails fast on dispatch-path regressions).
"""

from __future__ import annotations

import pickle

import numpy as np

from repro.app import AppSpec, ColmenaApp, FabricSpec, ServerSpec, TaskDef
from repro.core import BatchPolicy
from repro.observe import EventLog, MetricsAggregator


def _clone_proxy(proxy):
    """Fresh Proxy instance per task (as a cross-process control message
    would carry), so resolution cost is paid per task, not per object."""
    return pickle.loads(pickle.dumps(proxy))


def _score(model, i):
    # The small task: touch the resolved payload, return a scalar.
    return float(model[0]) + i


def run_config(
    n_tasks: int,
    payload: np.ndarray,
    warm: bool,
    batch: bool,
    n_workers: int = 4,
) -> dict:
    # Driver mode: no steering agents, the benchmark drives the queues.
    # cache_size=0: every fabric get pays the connector (disk) cost, the
    # honest stand-in for per-node fetches; only the warm-worker cache
    # (when enabled) may short-circuit it.
    app = ColmenaApp(AppSpec(
        tasks=[TaskDef(fn=_score, method="score", batch=batch)],
        pools={"default": n_workers},
        fabric=FabricSpec(connector="file", cache_size=0,
                          warm_capacity=32 if warm else 0),
        server=ServerSpec(batching=BatchPolicy(max_batch=8, linger_s=0.002)
                          if batch else None),
    ))
    with app.run(timeout=120) as handle:
        model_ref = app.store.proxy(payload)

        def run_tasks(n: int) -> list:
            for i in range(n):
                handle.queues.send_inputs(_clone_proxy(model_ref), i, method="score")
            return [handle.queues.get_result(timeout=120) for _ in range(n)]

        # Warmup: spin up worker threads, page-cache the payload file, and
        # (in the warm config) populate the per-worker caches, so the
        # measured phase reflects steady state for every configuration.
        run_tasks(2 * n_workers)
        # Rebind telemetry to a fresh log: components read ``event_log``
        # at emit time, so the measured phase records only measured tasks.
        log = EventLog()
        app.rebind_event_log(log)
        results = run_tasks(n_tasks)
        fabric_gets = app.store.metrics.gets
    assert all(r is not None and r.success for r in results), "benchmark tasks failed"

    agg = MetricsAggregator(log)
    spans = agg.overhead()
    cache = agg.cache_stats()["total"]
    batches = agg.batch_stats()["total"]
    # Per-task dispatch overhead: the server-side window (first submission
    # to last worker completion) over task count. The task function itself
    # is ~free, so this IS the dispatch+resolution cost per task; the
    # client-side result drain is reported separately via the result span.
    task_evs = [e for e in log.events() if e.kind == "task"]
    t_start = min(e.t for e in task_evs if e.stage == "submitted")
    t_end = max(e.t for e in task_evs if e.stage in ("completed", "failed"))
    per_task_us = (t_end - t_start) / n_tasks * 1e6
    return {
        "per_task_us": per_task_us,
        "span_means_us": {k: v["mean_s"] * 1e6 for k, v in spans.items()},
        "cache_hit_rate": cache.hit_rate,
        "cache_hits": cache.hits,
        "cache_misses": cache.misses,
        "mean_batch_occupancy": batches.mean_occupancy,
        "fabric_gets": fabric_gets,
    }


def main(quick: bool = True, recorder=None):
    n_tasks = 128 if quick else 512
    payload = np.random.default_rng(0).random(250_000 if quick else 500_000)  # 2 / 4 MB
    configs = [
        ("cold_unbatched", False, False),
        ("cold_batched", False, True),
        ("warm_unbatched", True, False),
        ("warm_batched", True, True),
    ]
    out = {}
    print("overhead,config,per_task_us,queue_us,dispatch_us,compute_us,result_us,"
          "cache_hit_rate,mean_batch_occupancy,fabric_gets")
    for name, warm, batch in configs:
        r = run_config(n_tasks, payload, warm=warm, batch=batch)
        out[name] = r
        s = r["span_means_us"]
        print(
            f"overhead,{name},{r['per_task_us']:.0f},{s.get('queue', 0):.0f},"
            f"{s.get('dispatch', 0):.0f},{s.get('compute', 0):.0f},{s.get('result', 0):.0f},"
            f"{r['cache_hit_rate']:.2f},{r['mean_batch_occupancy']:.1f},{r['fabric_gets']}"
        )
    ratio = out["cold_unbatched"]["per_task_us"] / max(out["warm_batched"]["per_task_us"], 1e-9)
    ok = ratio >= 2.0
    print(f"acceptance,warm_batched_speedup,{ratio:.1f}x,{'PASS' if ok else 'FAIL'}")
    if recorder is not None:
        for name, r in out.items():
            recorder.metric(f"{name}_per_task_us", r["per_task_us"], unit="us")
        recorder.metric("warm_batched_cache_hit_rate",
                        out["warm_batched"]["cache_hit_rate"])
        recorder.metric("warm_batched_speedup_x", ratio, unit="x", gate=(">=", 2.0))
    if not ok:
        raise RuntimeError(
            f"warm-batched dispatch only {ratio:.2f}x faster than cold unbatched (need >= 2x)"
        )
    return out


if __name__ == "__main__":
    main(quick=False)
