"""Paper Fig. 3: weak scaling of inference throughput vs. node count.

The paper's finding: pushing model/result data through the control
channel saturates the Task Server at ~512 nodes; moving data to the
fabric (Value Server / ProxyStore) extends scaling past 2000 nodes.

Here each 'node' is a worker thread running a real (tiny) JAX MLP
inference over a shared model; the model rides either the control
channel (copied per task) or the fabric (proxied once, cached on
workers). We report inference rate per worker count for both modes —
flat = ideal weak scaling.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.app import AppSpec, ColmenaApp, FabricSpec, SteeringSpec, TaskDef
from repro.core import ConstantInflightThinker, stateful_task

_D = 64


def _make_model(seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "w1": rng.standard_normal((_D, 4 * _D)).astype(np.float32),
        "w2": rng.standard_normal((4 * _D, 1)).astype(np.float32),
    }


@stateful_task
def infer(model, batch, registry=None):
    """Worker-side cached jit: the paper's 'avoid reinitialization' lesson."""
    fn = registry.get("infer_fn")
    if fn is None:
        fn = registry["infer_fn"] = jax.jit(
            lambda m, x: jnp.tanh(x @ m["w1"]) @ m["w2"]
        )
    out = fn({k: jnp.asarray(v) for k, v in model.items()}, jnp.asarray(batch))
    return np.asarray(out).sum()


def run_point(workers: int, use_fabric: bool, n_tasks: int = 32):
    model = _make_model()
    batch = np.random.default_rng(1).standard_normal((256, _D)).astype(np.float32)

    def steering(app):
        # Work references the composed store: the model is proxied once
        # ahead of time (manual bulk transfer) and reused by every task.
        if use_fabric:
            model_ref = app.store.proxy(model)
            work = [((model_ref, batch), {}) for _ in range(n_tasks)]
        else:
            work = [((model, batch), {}) for _ in range(n_tasks)]
        return ConstantInflightThinker(app.queues, work, method="infer", n_parallel=workers)

    app = ColmenaApp(AppSpec(
        tasks=[TaskDef(fn=infer, method="infer")],
        pools={"default": workers},
        fabric=FabricSpec(connector="memory", threshold=10_000) if use_fabric else None,
        observe=None,
        steering=SteeringSpec(steering),
    ))
    with app.run(timeout=120) as handle:
        t0 = time.monotonic()
        handle.wait()
        rate = len(handle.thinker.results) / (time.monotonic() - t0)
        cache_hits = app.store.metrics.cache_hits if use_fabric else 0
    return rate, cache_hits


def main(quick: bool = True, recorder=None):
    workers_list = [2, 8] if quick else [2, 4, 8, 16, 32]
    print("weak_scaling: workers,mode,tasks_per_s,cache_hits")
    rows = []
    for fabric in (False, True):
        for w in workers_list:
            rate, hits = run_point(w, fabric, n_tasks=16 if quick else 48)
            mode = "fabric" if fabric else "control-channel"
            rows.append((w, mode, rate, hits))
            print(f"weak_scaling,{w},{mode},{rate:.1f},{hits}")
            if recorder is not None:
                tag = "fabric" if fabric else "ctl"
                recorder.metric(f"rate_{tag}_{w}w", rate, unit="tasks/s")
    return rows


if __name__ == "__main__":
    main(quick=False)
