"""Chaos-tier soak benchmark: prove the fault paths under fire.

Pushes 10^4 (``--smoke``) to 10^5–10^6 (``--full`` / ``--tasks N``)
lightweight tasks through the federated two-site harness in
``repro.chaos.soak`` while the default ``ChaosSchedule`` fires eight
faults at it (zombie-cohort storm, two SIGKILLs of the spawned site, a
full network partition, request drops, result delays, checkpoint
corruption + resume drill, a burst flood against the elastic pool). The
``InvariantChecker`` verdict is a **hard gate**: zero lost results,
zero duplicated deliveries, zero lifecycle-order violations, intact
payloads, and bounded recovery after every fault — a violation raises,
so CI fails loudly.

``--slo`` additionally runs the streaming burn-rate engine
(``repro.observe.slo``) over the live run with auto-remediation wired
(stall -> expedite resubmission, backlog -> elastic pre-grow) and gates
on the alerting loop itself: chaos must drive at least one alert
through fire AND resolve within the resolve bound, with nothing left
firing after settle.

With ``--record DIR`` metrics land in ``BENCH_soak.json`` via
``BenchRecorder`` (the PR 6 trajectory machinery); compare runs with
``python -m repro.observe bench diff OLD NEW``. A custom schedule can
be supplied as JSON via ``--chaos FILE``
(``{"actions": [{"kind": "kill_site", "at_frac": 0.3, ...}]}``).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

SMOKE_TASKS = 10_000
QUICK_TASKS = 20_000
FULL_TASKS = 200_000


def main(
    quick: bool = True,
    recorder=None,
    n_tasks: Optional[int] = None,
    schedule=None,
    recovery_bound_s: float = 10.0,
    slo: bool = False,
) -> dict:
    from repro.chaos import SoakConfig, SoakHarness, default_chaos_schedule

    n = n_tasks if n_tasks is not None else (QUICK_TASKS if quick else FULL_TASKS)
    cfg = SoakConfig(n_tasks=n, recovery_bound_s=recovery_bound_s, slo=slo)
    sched = schedule if schedule is not None else default_chaos_schedule()
    result = SoakHarness(cfg, sched).run()
    rep = result.report

    rows = {
        "tasks": rep.n_tasks,
        "wall_s": round(result.wall_s, 3),
        "throughput_tps": round(result.throughput_tps, 1),
        "faults_fired": rep.faults_fired,
        "lost": rep.lost,
        "duplicates_suppressed": rep.duplicates_suppressed,
        "exactly_once_violations": rep.exactly_once_violations,
        "value_errors": rep.value_errors,
        "order_violations": rep.order_violations,
        "failed_deliveries": rep.failed_deliveries,
        "resubmits": rep.resubmits,
        "max_recovery_s": round(rep.max_recovery_s, 3),
        "site_kills": result.metrics.get("site_kills", 0),
        "resume_drills": result.metrics.get("resume_drills", 0),
        "pool_resizes": result.metrics.get("pool_resizes", 0),
        "requests_dropped": result.metrics.get("requests_dropped", 0),
        "local_retries": result.metrics.get("local_retries", 0),
        "verdict": "PASS" if rep.ok else "FAIL",
    }
    if slo:
        rows.update({
            "alerts_fired": result.metrics.get("alerts_fired", 0),
            "alerts_resolved": result.metrics.get("alerts_resolved", 0),
            "alerts_unresolved": result.metrics.get("alerts_unresolved", 0),
            "max_alert_resolve_s": round(result.metrics.get("max_alert_resolve_s", 0.0), 3),
            "remediations": result.metrics.get("remediations", 0),
            "partition_drops": result.metrics.get("partition_drops", 0),
        })
    for k, v in rows.items():
        print(f"soak,{k},{v}")
    for r in rep.recoveries:
        rec = "never" if r["recovery_s"] is None else f"{r['recovery_s']:.3f}"
        print(f"soak,recovery,{r['label']},{rec}")

    if recorder is not None:
        recorder.metric("tasks", rep.n_tasks, unit="tasks", gate=(">=", SMOKE_TASKS))
        recorder.metric("throughput_tps", result.throughput_tps, unit="tasks/s")
        recorder.metric("wall_s", result.wall_s, unit="s")
        recorder.metric("faults_fired", rep.faults_fired, unit="faults", gate=(">=", 4))
        recorder.metric("lost", rep.lost, unit="tasks", gate=("<=", 0))
        recorder.metric("exactly_once_violations", rep.exactly_once_violations,
                        unit="deliveries", gate=("<=", 0))
        recorder.metric("value_errors", rep.value_errors, unit="results", gate=("<=", 0))
        recorder.metric("order_violations", rep.order_violations, unit="tasks", gate=("<=", 0))
        recorder.metric("max_recovery_s", rep.max_recovery_s, unit="s",
                        gate=("<=", recovery_bound_s))
        recorder.metric("duplicates_suppressed", rep.duplicates_suppressed, unit="deliveries")
        recorder.metric("resubmits", rep.resubmits, unit="tasks")
        recorder.metric("failed_deliveries", rep.failed_deliveries, unit="deliveries")
        recorder.metric("site_kills", result.metrics.get("site_kills", 0), unit="kills")
        recorder.metric("pool_resizes", result.metrics.get("pool_resizes", 0), unit="resizes")
        if slo:
            recorder.metric("alerts_fired", result.metrics.get("alerts_fired", 0),
                            unit="alerts", gate=(">=", 1))
            recorder.metric("alerts_unresolved", result.metrics.get("alerts_unresolved", 0),
                            unit="alerts", gate=("<=", 0))
            recorder.metric("max_alert_resolve_s",
                            result.metrics.get("max_alert_resolve_s", 0.0),
                            unit="s", gate=("<=", 10.0))
            recorder.metric("remediations", result.metrics.get("remediations", 0),
                            unit="runs")

    if not rep.ok:
        raise AssertionError(
            "soak invariant gate FAILED: " + "; ".join(rep.violations[:10])
        )
    return rows


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--smoke", action="store_true",
                       help=f"{SMOKE_TASKS} tasks (the CI soak-chaos gate)")
    scale.add_argument("--full", action="store_true", help=f"{FULL_TASKS} tasks")
    scale.add_argument("--tasks", type=int, default=None, help="explicit task count")
    ap.add_argument("--record", nargs="?", const="bench_out", default=None, metavar="DIR",
                    help="write BENCH_soak.json to DIR (default bench_out/)")
    ap.add_argument("--chaos", default=None, metavar="FILE",
                    help="JSON ChaosSchedule overriding the default")
    ap.add_argument("--recovery-bound-s", type=float, default=10.0)
    ap.add_argument("--slo", action="store_true",
                    help="run the burn-rate SLO engine over the soak and "
                         "gate on alerts firing AND resolving")
    args = ap.parse_args()

    schedule = None
    if args.chaos:
        from repro.chaos import ChaosSchedule

        with open(args.chaos) as fh:
            schedule = ChaosSchedule.from_dict(json.load(fh))

    n_tasks = args.tasks if args.tasks is not None else (
        SMOKE_TASKS if args.smoke else (FULL_TASKS if args.full else QUICK_TASKS)
    )
    recorder = None
    if args.record is not None:
        from repro.observe import BenchRecorder

        recorder = BenchRecorder("soak", out_dir=args.record)
    try:
        main(quick=not args.full, recorder=recorder, n_tasks=n_tasks,
             schedule=schedule, recovery_bound_s=args.recovery_bound_s,
             slo=args.slo)
    except Exception as exc:
        if recorder is not None:
            print(f"suite,soak,recorded,{recorder.finish(ok=False, error=str(exc))}")
        print(f"suite,soak,FAILED,{type(exc).__name__}: {exc}")
        sys.exit(1)
    if recorder is not None:
        print(f"suite,soak,recorded,{recorder.finish(ok=True)}")
    print("suite,soak,ok")


if __name__ == "__main__":
    _cli()
