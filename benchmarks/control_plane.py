"""Control-plane gate: many campaigns, one fleet, a SIGKILLed daemon.

Starts a real ``python -m repro.control serve`` daemon over a shared
two-pool fleet, submits four campaigns over HTTP (three contending for
the ``default`` pool with weights 2/1/1, one alone on ``aux``), lets a
``ChaosSchedule`` SIGKILL the daemon mid-``running`` through the
``kill_control_plane`` primitive, restarts it on the same root, and
waits for auto-resume to finish everything. Hard gates (a violation
raises, so CI fails loudly):

* **exactly-once under crash** — every campaign's results journal holds
  each index exactly once (``InvariantChecker`` over a ledger
  reconstructed from the journals; zero lost, zero duplicated);
* **>= 3 campaigns were mid-flight** when the daemon died, and every
  one of them records ``resumed >= 1`` after the restart;
* **fair share** — each contended campaign's integrated slot-share
  stays within 20% of its weight entitlement (``FleetAccounting``,
  persisted across the crash);
* **remote-site elasticity** — a resize request round-trips to a
  spawned ``ProcessTaskServer`` (request -> ack -> ``pool_resize``
  event in the site's own log), including clamping to the spec band.

With ``--record DIR`` metrics land in ``BENCH_control.json`` via
``BenchRecorder`` (the CI ``control-smoke`` job records this).
"""

from __future__ import annotations

import argparse
import collections
import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time
import urllib.request
from typing import Any, Dict, List, Optional

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

SMOKE_W = 60      # light campaign task count; heavy = 2x, aux = 1.5x
FULL_W = 200
TASK_S = 0.05
KILL_AT_FRAC = 0.15   # min campaign progress when the SIGKILL fires


def _campaign_toml(n_tasks: int, weight: float, pool: str, pool_size: int,
                   n_parallel: int) -> str:
    return f"""
[[tasks]]
fn = "repro.control.workload.workload_task"
pool = "{pool}"

[pools.{pool}]
size = {pool_size}

[steering]
thinker = "repro.control.workload.make_workload"

[steering.kwargs]
n_tasks = {n_tasks}
n_parallel = {n_parallel}
task_s = {TASK_S}

[campaign]
checkpoint_interval_s = 0.2

[control]
weight = {weight}
min_slots = 1
"""


def _journal_indices(state_dir: str) -> List[int]:
    path = os.path.join(state_dir, "results.jsonl")
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(int(json.loads(line)["index"]))
            except (ValueError, KeyError):
                continue  # torn tail line from the SIGKILL mid-append
    return out


class _JournalLedger:
    """Duck-typed ``WorkLedger`` view over a results journal, so
    ``InvariantChecker`` gates the crash-resume run with the same
    exactly-once semantics as the soak tier: a journal line is an
    acceptance, so a missing index is *lost* and a repeated index is a
    duplicated delivery."""

    def __init__(self, n_tasks: int, indices: List[int]) -> None:
        self.n_tasks = n_tasks
        counts = collections.Counter(i for i in indices if 0 <= i < n_tasks)
        self.completed = len(counts)
        self._missing = [i for i in range(n_tasks) if i not in counts]
        self.exactly_once_violations = sorted(i for i, c in counts.items() if c > 1)
        self.value_errors: List[int] = []
        self.duplicates_suppressed = 0
        self.failed_deliveries = 0
        self.resubmits = 0

    def missing_indices(self, limit: int = 8) -> List[int]:
        return self._missing[:limit]


def _wait(predicate, timeout: float, msg: str, interval: float = 0.2) -> None:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return
        time.sleep(interval)
    raise AssertionError(f"control_plane benchmark timed out waiting for {msg}")


def _remote_resize_phase(workdir: str) -> Dict[str, Any]:
    """The cross-process elasticity gate: resize a spawned
    ``ProcessTaskServer`` over the control channel and observe the
    ``pool_resize`` event in the site's own log."""
    from repro.app import (
        AppSpec, ColmenaApp, ObserveSpec, PoolSpec, QueueSpec, ServerSpec, TaskDef,
    )
    from repro.control import workload_task

    parent_log = os.path.join(workdir, "resize_events.jsonl")
    child_log = os.path.join(workdir, "resize_events.server.jsonl")
    app = ColmenaApp(AppSpec(
        tasks=[TaskDef(fn=workload_task, method="workload_task")],
        queues=QueueSpec(backend="pipe"),
        pools={"default": PoolSpec("default", 2, min_size=1, max_size=6)},
        server=ServerSpec(in_process=False),
        observe=ObserveSpec(jsonl_path=parent_log),
    ))
    roundtrips = 0
    clamped_new = None
    with app.run(timeout=120) as handle:
        ack = handle.queues.request_resize("default", 4, timeout=60)
        if ack is not None and ack.ok and ack.detail == {"old": 2, "new": 4}:
            roundtrips += 1
        ack2 = handle.queues.request_resize("default", 99, timeout=60)
        if ack2 is not None and ack2.ok:
            roundtrips += 1
            clamped_new = ack2.detail.get("new")
        # the channel still delivers work after control traffic
        handle.queues.send_inputs(5, method="workload_task")
        r = handle.queues.get_result(timeout=60)
        delivered = bool(r is not None and r.success and r.value == 16)
    resize_events = 0
    if os.path.exists(child_log):
        with open(child_log) as f:
            for line in f:
                try:
                    ev = json.loads(line)
                except ValueError:
                    continue
                if ev.get("kind") == "pool_resize" and ev.get("value") == 4.0:
                    resize_events += 1
    return {
        "resize_roundtrips": roundtrips,
        "resize_clamped_new": clamped_new,
        "resize_events": resize_events,
        "resize_delivery_ok": delivered,
    }


def main(
    quick: bool = True,
    recorder=None,
    n_tasks: Optional[int] = None,
    keep_root: Optional[str] = None,
) -> dict:
    from repro.chaos import (
        ChaosAction, ChaosRunner, ChaosSchedule, InvariantChecker, kill_control_plane,
    )
    from repro.control import DONE, FleetAccounting, StateStore

    w = n_tasks if n_tasks is not None else (SMOKE_W if quick else FULL_W)
    # heavy gets 2x the weight AND 2x the tasks, so under a fair split
    # every default-pool campaign finishes around the same time and the
    # cleanly-contended three-way phase dominates the accounting.
    plan = {
        "heavy": {"n": 2 * w, "weight": 2.0, "pool": "default", "pool_size": 8},
        "light-a": {"n": w, "weight": 1.0, "pool": "default", "pool_size": 8},
        "light-b": {"n": w, "weight": 1.0, "pool": "default", "pool_size": 8},
        "aux-cam": {"n": (3 * w) // 2, "weight": 1.0, "pool": "aux", "pool_size": 2},
    }

    workdir = keep_root or tempfile.mkdtemp(prefix="bench_control_")
    root = os.path.join(workdir, "root")
    fleet_path = os.path.join(workdir, "fleet.toml")
    with open(fleet_path, "w") as f:
        f.write("[pools.default]\nsize = 8\n\n[pools.aux]\nsize = 2\n")
    port_file = os.path.join(workdir, "port")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")

    def serve() -> subprocess.Popen:
        return subprocess.Popen(
            [sys.executable, "-m", "repro.control", "serve",
             "--root", root, "--fleet", fleet_path,
             "--port-file", port_file, "--tick", "0.1"],
            env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
        )

    def url() -> str:
        with open(port_file) as f:
            return f"http://127.0.0.1:{f.read().strip()}"

    def get(path: str) -> dict:
        with urllib.request.urlopen(url() + path, timeout=30) as r:
            return json.loads(r.read())

    t0 = time.monotonic()
    proc = serve()
    runner = None
    try:
        _wait(lambda: os.path.exists(port_file), timeout=60, msg="daemon port file")
        ids: Dict[str, str] = {}
        for name, cfg in plan.items():
            body = _campaign_toml(cfg["n"], cfg["weight"], cfg["pool"],
                                  cfg["pool_size"], n_parallel=8).encode()
            req = urllib.request.Request(
                url() + f"/campaigns?name={name}", data=body, method="POST")
            with urllib.request.urlopen(req, timeout=30) as r:
                ids[name] = json.loads(r.read())["id"]

        store = StateStore(root)
        dirs = {name: store.state_dir(cid) for name, cid in ids.items()}

        def min_progress() -> float:
            return min(
                len(set(_journal_indices(dirs[name]))) / plan[name]["n"]
                for name in plan
            )

        kill_detail: Dict[str, Any] = {}

        def kill_daemon(params: Dict[str, Any]) -> Dict[str, Any]:
            fresh = StateStore(root)
            unfinished = [n for n, cid in ids.items() if fresh.get(cid).state != DONE]
            pid = proc.pid
            ok = kill_control_plane(proc) == pid
            kill_detail.update({"ok": ok, "pid": pid, "unfinished": unfinished})
            return dict(kill_detail)

        sched = ChaosSchedule([ChaosAction(
            kind="kill_control_plane", at_frac=KILL_AT_FRAC, scope="none",
            label="kill-control-plane")])
        runner = ChaosRunner(sched, handlers={"kill_control_plane": kill_daemon},
                             progress=min_progress, poll_s=0.1).start()
        _wait(lambda: runner.fired, timeout=300, msg="scheduled daemon SIGKILL")
        unfinished_at_kill = list(kill_detail.get("unfinished", []))

        os.remove(port_file)
        proc = serve()
        _wait(lambda: os.path.exists(port_file), timeout=60,
              msg="daemon restart port file")
        _wait(lambda: all(c["state"] == DONE
                          for c in get("/campaigns")["campaigns"]),
              timeout=180 if quick else 600, msg="all campaigns done after resume")
        campaigns = {c["name"]: c for c in get("/campaigns")["campaigns"]}
    finally:
        if runner is not None:
            runner.stop()
        if proc.poll() is None:
            proc.send_signal(signal.SIGTERM)
            try:
                proc.wait(timeout=20)
            except subprocess.TimeoutExpired:
                proc.kill()

    # -- exactly-once under crash: InvariantChecker over the journals ------
    checker = InvariantChecker(require_faults=1)
    lost = violations = completed = total = 0
    problems: List[str] = []
    for name, cfg in plan.items():
        ledger = _JournalLedger(cfg["n"], _journal_indices(dirs[name]))
        rep = checker.check(ledger, fired=runner.fired)
        lost += rep.lost
        violations += rep.exactly_once_violations
        completed += rep.completed
        total += cfg["n"]
        problems += [f"{name}: {v}" for v in rep.violations]

    resumed_min = min(
        (campaigns[name]["resumed"] for name in unfinished_at_kill), default=0)

    # -- fair share while contended, integrated across the crash -----------
    acct = FleetAccounting(os.path.join(root, "fleet_accounting.json")).report()
    by_id = {cid: name for name, cid in ids.items()}
    contended = {by_id[cid]: cell for cid, cell in acct.items()
                 if cid in by_id and cell["contended_s"] > 0.5}
    max_share_error = max((c["share_error"] for c in contended.values()
                           if c["share_error"] is not None), default=0.0)

    remote = _remote_resize_phase(workdir)
    wall_s = time.monotonic() - t0

    rows = {
        "campaigns": len(plan),
        "campaigns_done": sum(1 for c in campaigns.values() if c["state"] == DONE),
        "tasks": total,
        "completed": completed,
        "lost": lost,
        "exactly_once_violations": violations,
        "control_kills": len([f for f in runner.fired if f.ok]),
        "unfinished_at_kill": len(unfinished_at_kill),
        "resumed_min": resumed_min,
        "contended_campaigns": len(contended),
        "max_share_error": round(max_share_error, 4),
        "wall_s": round(wall_s, 3),
        **{k: v for k, v in remote.items()},
        "verdict": "PASS" if not problems else "FAIL",
    }
    for k, v in rows.items():
        print(f"control,{k},{v}")
    for name, cell in sorted(contended.items()):
        err = "n/a" if cell["share_error"] is None else f"{cell['share_error']:.4f}"
        print(f"control,share_error,{name},{err}")

    if recorder is not None:
        recorder.metric("campaigns_done", rows["campaigns_done"], unit="campaigns",
                        gate=(">=", 4))
        recorder.metric("lost", lost, unit="tasks", gate=("<=", 0))
        recorder.metric("exactly_once_violations", violations, unit="deliveries",
                        gate=("<=", 0))
        recorder.metric("control_kills", rows["control_kills"], unit="kills",
                        gate=(">=", 1))
        recorder.metric("unfinished_at_kill", rows["unfinished_at_kill"],
                        unit="campaigns", gate=(">=", 3))
        recorder.metric("resumed_min", resumed_min, unit="resumes", gate=(">=", 1))
        recorder.metric("contended_campaigns", len(contended), unit="campaigns",
                        gate=(">=", 2))
        recorder.metric("max_share_error", max_share_error, unit="fraction",
                        gate=("<=", 0.2))
        recorder.metric("resize_roundtrips", remote["resize_roundtrips"],
                        unit="acks", gate=(">=", 1))
        recorder.metric("resize_events", remote["resize_events"], unit="events",
                        gate=(">=", 1))
        recorder.metric("wall_s", wall_s, unit="s")

    if rows["campaigns_done"] < len(plan):
        problems.append(f"only {rows['campaigns_done']}/{len(plan)} campaigns done")
    if len(unfinished_at_kill) < 3:
        problems.append(
            f"only {len(unfinished_at_kill)} campaigns were mid-flight at the "
            "SIGKILL; the gate needs >= 3 actually crash-resumed")
    if resumed_min < 1:
        problems.append("a crashed campaign finished without recording a resume")
    if len(contended) < 2:
        problems.append("fewer than 2 campaigns ever contended the fleet")
    if max_share_error > 0.2:
        problems.append(
            f"fair-share error {max_share_error:.3f} > 0.2 while contended")
    if remote["resize_roundtrips"] < 1 or remote["resize_events"] < 1:
        problems.append(f"remote resize did not round-trip: {remote}")
    if not remote["resize_delivery_ok"]:
        problems.append("remote site stopped delivering work after control traffic")

    if keep_root is None:
        shutil.rmtree(workdir, ignore_errors=True)
    if problems:
        raise AssertionError(
            "control-plane gate FAILED: " + "; ".join(problems[:10]))
    return rows


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--smoke", action="store_true",
                       help="CI control-smoke scale (the default)")
    scale.add_argument("--full", action="store_true", help="longer campaigns")
    ap.add_argument("--tasks", type=int, default=None,
                    help="light-campaign task count (heavy = 2x, aux = 1.5x)")
    ap.add_argument("--record", nargs="?", const="bench_out", default=None,
                    metavar="DIR",
                    help="write BENCH_control.json to DIR (default bench_out/)")
    ap.add_argument("--root", default=None, metavar="DIR",
                    help="keep the daemon root at DIR for inspection")
    args = ap.parse_args()

    recorder = None
    if args.record is not None:
        from repro.observe import BenchRecorder

        recorder = BenchRecorder("control", out_dir=args.record)
    try:
        main(quick=not args.full, recorder=recorder, n_tasks=args.tasks,
             keep_root=args.root)
    except Exception as exc:
        if recorder is not None:
            print(f"suite,control,recorded,{recorder.finish(ok=False, error=str(exc))}")
        print(f"suite,control,FAILED,{type(exc).__name__}: {exc}")
        sys.exit(1)
    if recorder is not None:
        print(f"suite,control,recorded,{recorder.finish(ok=True)}")
    print("suite,control,ok")


if __name__ == "__main__":
    _cli()
