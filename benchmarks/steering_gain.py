"""Paper claim: '+20% more high-performing molecules from co-scheduling
simulation and AI' (Fig. 2 discussion).

Reproduction, generalized into a policy-comparison harness over the
``repro.surrogate`` subsystem: every acquisition policy in {random,
greedy, ucb, ei, thompson} runs the *same* active-learning campaign
(same budget, same candidate pool, same worker fleet, same online
deep-ensemble retraining cadence) on each scenario; the metric is
high-performing results found within the task budget (true value above
the scenario's quantile-calibrated threshold).

Acceptance gates (seeded):
  * on every scenario, the best surrogate-steered policy must find
    >= GAIN_X x the random baseline's hits (mirroring the paper's +20%);
  * the quadratic-scenario gate (steered >= random) is the CI smoke job.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.observe import render_text
from repro.surrogate import (
    campaign_ensemble_config,
    make_policy,
    make_scenario,
    run_active_campaign,
    warmup_jit,
)


def _warmup(budget: int) -> None:
    """One throwaway compile matching run_active_campaign's default
    ensemble shapes, so no campaign's first retrain stalls on XLA."""
    warmup_jit(DIM, campaign_ensemble_config(budget), predict_rows=N_CANDIDATES)

DIM = 4
N_CANDIDATES = 512
SIM_SLEEP_S = 0.004       # paces sub-ms landscapes so retrains interleave
GAIN_X = 1.2              # paper's +20% high-performers claim
STEERED = ("greedy", "ucb", "ei", "thompson")


def run_scenario(name: str, budget: int, seed: int = 0, verbose: bool = True) -> Dict[str, dict]:
    """Sweep every policy over one scenario; returns per-policy results."""
    scenario = make_scenario(name, dim=DIM)
    out: Dict[str, dict] = {}
    for policy_name in ("random",) + STEERED:
        res = run_active_campaign(
            scenario,
            make_policy(policy_name),
            budget=budget,
            n_candidates=N_CANDIDATES,
            seed=seed,
            sim_sleep_s=SIM_SLEEP_S,
        )
        out[policy_name] = res
        if verbose:
            print(f"steering_gain,{name},{policy_name},hits,{res['hits']}")
            print(f"steering_gain,{name},{policy_name},retrains,{res['retrains']}")
    return out


def check_gates(name: str, results: Dict[str, dict], gain_x: float = GAIN_X) -> float:
    """Best steered-to-random hit ratio; raises if below ``gain_x``."""
    rnd = max(results["random"]["hits"], 1)
    best_policy, best_hits = max(
        ((p, results[p]["hits"]) for p in STEERED), key=lambda kv: kv[1])
    ratio = best_hits / rnd
    print(f"steering_gain,{name},best_steered,{best_policy}")
    print(f"steering_gain,{name},gain_x,{ratio:.2f}")
    if ratio < gain_x:
        raise AssertionError(
            f"{name}: best steered policy ({best_policy}, {best_hits} hits) "
            f"< {gain_x}x random ({results['random']['hits']} hits)")
    return ratio


def main(quick: bool = True, recorder=None) -> Dict[str, Dict[str, dict]]:
    budget = 48 if quick else 160
    scenarios = ("quadratic", "multimodal", "needle") if quick else (
        "quadratic", "multimodal", "needle", "heteroscedastic")
    _warmup(budget)

    all_results: Dict[str, Dict[str, dict]] = {}
    for name in scenarios:
        t0 = time.monotonic()
        all_results[name] = run_scenario(name, budget)
        ratio = check_gates(name, all_results[name])
        print(f"steering_gain,{name},wall_s,{time.monotonic() - t0:.1f}")
        if recorder is not None:
            recorder.metric(f"{name}_gain_x", ratio, unit="x", gate=(">=", GAIN_X))
            recorder.metric(f"{name}_random_hits",
                            all_results[name]["random"]["hits"])

    # One full telemetry report: retrain cadence / rmse / regret for the
    # UCB campaign on the first scenario.
    first = scenarios[0]
    print(render_text(all_results[first]["ucb"]["report"]))
    return all_results


def main_ci_gate(budget: int = 48, seed: int = 0, recorder=None) -> None:
    """CI smoke: quadratic scenario only, steered must match or beat
    random (gain_x=1.0 — tighter 1.2x is enforced by the full run), and
    the thinker must have retrained online at least twice."""
    _warmup(budget)
    results = run_scenario("quadratic", budget, seed=seed)
    ratio = check_gates("quadratic", results, gain_x=1.0)
    best = max((results[p] for p in STEERED), key=lambda r: r["hits"])
    retrains = best["report"].get("surrogate", {}).get("retrains", 0)
    if recorder is not None:
        recorder.metric("quadratic_gain_x", ratio, unit="x", gate=(">=", 1.0))
        recorder.metric("online_retrains", retrains, gate=(">=", 2))
    assert retrains >= 2, f"expected >=2 online retrains, saw {retrains}"
    reallocs = best["report"].get("reallocations", [])
    assert any(m.get("dst") == "ml" for m in reallocs), (
        "expected a reallocation into the training pool during retrain")
    print("steering_gain,ci_gate,ok,1")


if __name__ == "__main__":
    main(quick=False)
