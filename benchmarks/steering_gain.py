"""Paper claim: '+20% more high-performing molecules from co-scheduling
simulation and AI' (Fig. 2 discussion).

Reproduction: a synthetic molecular property landscape; a fixed budget of
simulation tasks; compare (a) unsteered random search vs (b) the Colmena
AI-steered campaign (surrogate retrained online, sampling biased toward
predicted optima). Metric: number of 'high-performing' molecules found
(property above a fixed threshold) within the same task budget.
"""

from __future__ import annotations

import time
from typing import Tuple

import numpy as np

from repro.core import (
    BatchRetrainThinker,
    LocalColmenaQueues,
    TaskServer,
    WorkerPool,
)
from repro.observe import EventLog, build_report, render_text

DIM = 6
THRESHOLD = -0.5     # property above this = "high-performing"


def _landscape(x: np.ndarray) -> float:
    time.sleep(0.002)
    x = np.asarray(x)
    return float(-np.sum((x - 0.35) ** 2) + 0.1 * np.sin(5 * x).sum())


def _train(X, y):
    X = np.asarray(X); y = np.asarray(y)
    Xb = np.concatenate([X, np.ones((len(X), 1))], axis=1)
    w = np.linalg.lstsq(Xb, y, rcond=None)[0]
    return w


class Steered(BatchRetrainThinker):
    def __init__(self, queues, **kw):
        super().__init__(queues, **kw)
        self.rng = np.random.default_rng(0)
        self.w = None

    def simulate_args(self):
        if self.w is None:
            return (self.rng.uniform(-1, 1, DIM),)
        # ascend the surrogate gradient from a random start
        x = self.rng.uniform(-1, 1, DIM)
        x = np.clip(x + 0.8 * np.sign(self.w[:DIM]) * self.rng.uniform(0, 1, DIM), -1, 1)
        return (x,)

    def make_train_task(self):
        X = np.stack([np.asarray(r.args[0]) for r in self.database])
        y = np.asarray([r.value for r in self.database])
        return (X, y), {}

    def on_train(self, result):
        if result.success:
            self.w = np.asarray(result.value)


def run_steered(budget: int) -> Tuple[int, dict]:
    """AI-steered campaign; the event log supplies the per-task lifecycle
    trace (queue/compute/result overheads, utilization) instead of
    ad-hoc timestamp bookkeeping."""
    log = EventLog()
    q = LocalColmenaQueues(topics=["simulate", "train"], event_log=log)
    pool_sizes = {"simulate": 3, "ml": 1, "default": 1}
    pools = {name: WorkerPool(name, n) for name, n in pool_sizes.items()}
    thinker = Steered(q, n_slots=3, retrain_after=max(8, budget // 8),
                      max_results=budget, ml_slots=1)
    server = TaskServer(q, {"simulate": _landscape, "train": _train}, pools=pools).start()
    thinker.run(timeout=300)
    server.stop()
    hits = sum(1 for r in thinker.database if r.value > THRESHOLD)
    report = build_report(log, slots_by_pool=pool_sizes)
    return hits, report


def run_random(budget: int) -> int:
    rng = np.random.default_rng(0)
    hits = 0
    for _ in range(budget):
        x = rng.uniform(-1, 1, DIM)
        if _landscape(x) > THRESHOLD:
            hits += 1
    return hits


def main(quick: bool = True) -> Tuple[int, int]:
    budget = 60 if quick else 240
    rnd = run_random(budget)
    steered, report = run_steered(budget)
    gain = (steered - rnd) / max(rnd, 1) * 100
    print(f"steering_gain,budget,{budget}")
    print(f"steering_gain,random_hits,{rnd}")
    print(f"steering_gain,steered_hits,{steered}")
    print(f"steering_gain,gain_pct,{gain:.0f}")
    util = report["utilization"].get("simulate", 0.0)
    print(f"steering_gain,simulate_util,{util:.3f}")
    print(f"steering_gain,lifecycle_complete,{int(report['lifecycle']['complete'])}")
    print(render_text(report))
    return steered, rnd


if __name__ == "__main__":
    main(quick=False)
