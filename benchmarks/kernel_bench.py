"""Kernel microbenchmarks: us/call for the XLA paths (CPU-measurable) and
TPU roofline estimates for the Pallas kernels (derived, since this
container has no TPU).

For each kernel: bytes touched and flops are computed analytically from
the shapes; est_tpu_us = max(flops/197e12, bytes/819e9) * 1e6."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import decode_attention, flash_attention, rglru_scan, rmsnorm, wkv6


def _time(fn, *args, n: int = 5, event_log=None, name: str = "kernel") -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.monotonic()
    for _ in range(n):
        out = fn(*args)
    t1 = time.monotonic()
    jax.block_until_ready(out)
    t2 = time.monotonic()
    if event_log is not None:
        event_log.profile(
            f"kernel.{name}", t_start=t0, wall_s=t2 - t0, device_s=t2 - t1, n=n
        )
    return (t2 - t0) / n * 1e6


def _roofline_us(flops: float, bytes_: float) -> float:
    return max(flops / 197e12, bytes_ / 819e9) * 1e6


def main(quick: bool = True, recorder=None, event_log=None):
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention: B=1 H=8 S=1024 D=128 bf16
    B, H, S, D = 1, 8, (512 if quick else 2048), 128
    q = jax.random.normal(key, (B, H, S, D), jnp.bfloat16)
    k = jax.random.normal(key, (B, H, S, D), jnp.bfloat16)
    v = jax.random.normal(key, (B, H, S, D), jnp.bfloat16)
    fn = jax.jit(lambda q, k, v: flash_attention(q, k, v, impl="xla"))
    us = _time(fn, q, k, v, event_log=event_log, name="flash_attention_xla")
    flops = 4 * B * H * S * S * D          # qk + pv
    bytes_ = 3 * q.nbytes + q.nbytes
    rows.append(("flash_attention_xla", us, _roofline_us(flops, bytes_)))

    # decode attention: B=8 H=8 S=32768 D=128
    S2 = 8192 if quick else 32768
    q1 = jax.random.normal(key, (8, 8, 128), jnp.bfloat16)
    k1 = jax.random.normal(key, (8, 8, S2, 128), jnp.bfloat16)
    v1 = jax.random.normal(key, (8, 8, S2, 128), jnp.bfloat16)
    lens = jnp.full((8,), S2, jnp.int32)
    fn = jax.jit(lambda a, b, c, l: decode_attention(a, b, c, l, impl="ref"))
    us = _time(fn, q1, k1, v1, lens, event_log=event_log, name="decode_attention")
    rows.append(("decode_attention", us, _roofline_us(4 * 8 * 8 * S2 * 128, k1.nbytes * 2)))

    # rglru scan: B=4 S=2048 Dm=1024
    S3, Dm = (1024 if quick else 4096), 1024
    la = -jax.random.uniform(key, (4, S3, Dm), minval=0.01, maxval=2.0)
    bx = jax.random.normal(key, (4, S3, Dm))
    h0 = jnp.zeros((4, Dm))
    fn = jax.jit(lambda a, b, h: rglru_scan(a, b, h, impl="xla"))
    us = _time(fn, la, bx, h0, event_log=event_log, name="rglru_scan_xla")
    rows.append(("rglru_scan_xla", us, _roofline_us(6 * la.size, la.nbytes * 3)))

    # wkv6: B=1 H=8 S=1024 K=64
    S4, K = (512 if quick else 2048), 64
    r = jax.random.normal(key, (1, 8, S4, K)) * 0.5
    kk = jax.random.normal(key, (1, 8, S4, K)) * 0.5
    vv = jax.random.normal(key, (1, 8, S4, K)) * 0.5
    lw = -jax.random.uniform(key, (1, 8, S4, K), minval=0.1, maxval=3.0)
    u = jnp.zeros((8, K))
    s0 = jnp.zeros((1, 8, K, K))
    fn = jax.jit(lambda *a: wkv6(*a, impl="xla"))
    us = _time(fn, r, kk, vv, lw, u, s0, event_log=event_log, name="wkv6_xla")
    chunk = 64
    flops = (2 * S4 * K * K * 2 + S4 * chunk * K * 3) * 8   # per head approx
    rows.append(("wkv6_xla", us, _roofline_us(flops, r.nbytes * 4)))

    # rmsnorm: (8192, 4096)
    x = jax.random.normal(key, (4096 if quick else 8192, 4096), jnp.bfloat16)
    w = jnp.ones((4096,), jnp.bfloat16)
    fn = jax.jit(lambda x, w: rmsnorm(x, w, impl="ref"))
    us = _time(fn, x, w, event_log=event_log, name="rmsnorm")
    rows.append(("rmsnorm", us, _roofline_us(3 * x.size, 2 * x.nbytes)))

    for name, us, tpu_us in rows:
        print(f"kernel,{name},{us:.0f},{tpu_us:.1f}")
        if recorder is not None:
            recorder.metric(f"{name}_us", us, unit="us")
            recorder.metric(f"{name}_roofline_us", tpu_us, unit="us")
    return rows


if __name__ == "__main__":
    main(quick=False)
