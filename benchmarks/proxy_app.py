"""Paper Fig. 7: the proxy application for dynamic workflows.

Maintains a constant number of in-flight tasks; tasks sleep for a normal-
distributed duration and return a byte payload. Measures the three
latencies the paper decomposes — *reaction* (compute end -> thinker
notified), *decision* (thinker turn-around), *dispatch* (request ->
compute start) — as a function of worker count and payload size, with
and without the ProxyStore data fabric.

Scaled to this container: worker counts {4..64} (threads), 10 ms tasks,
payloads up to 1 MB. The paper's qualitative claims to reproduce:
  * latency grows with worker count and payload size when data rides the
    control channel;
  * proxying keeps reaction latency ~flat (completion notices beat data).
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.app import AppSpec, ColmenaApp, FabricSpec, SteeringSpec, TaskDef
from repro.core import ConstantInflightThinker


def _task(payload_bytes: int, sleep_s: float, payload=None) -> bytes:
    time.sleep(max(0.0, np.random.normal(sleep_s, sleep_s * 0.1)))
    return b"\0" * payload_bytes


@dataclass
class ProxyAppPoint:
    workers: int
    payload_kb: int
    proxied: bool
    reaction_ms: float
    decision_ms: float
    dispatch_ms: float
    rate_per_s: float


def run_point(workers: int, payload_kb: int, proxied: bool,
              n_tasks: int = 48, sleep_s: float = 0.01) -> ProxyAppPoint:
    payload = b"\0" * (payload_kb * 1024)
    work = [((payload_kb * 1024, sleep_s), {"payload": payload}) for _ in range(n_tasks)]
    app = ColmenaApp(AppSpec(
        tasks=[TaskDef(fn=_task, method="task")],
        pools={"default": workers},
        fabric=FabricSpec(connector="memory", threshold=10_000) if proxied else None,
        observe=None,  # latencies come from Result timestamps here
        steering=SteeringSpec(ConstantInflightThinker, dict(
            work=work, method="task", n_parallel=workers)),
    ))
    with app.run(timeout=120) as handle:
        t0 = time.monotonic()          # thinker-run window only, as the
        handle.wait()                  # paper figure measures — excludes
        elapsed = time.monotonic() - t0  # app start/stop overhead
        results = handle.thinker.results

    def ms(vals: List[Optional[float]]) -> float:
        vals = [v * 1000 for v in vals if v is not None]
        return statistics.median(vals) if vals else float("nan")

    timings = [r.finalize_timings() for r in results]
    return ProxyAppPoint(
        workers=workers, payload_kb=payload_kb, proxied=proxied,
        reaction_ms=ms([t.reaction for t in timings]),
        decision_ms=ms([t.decision for t in timings]),
        dispatch_ms=ms([(r.time.compute_started - r.time.queued)
                        for r, t in zip(results, timings)]),
        rate_per_s=len(results) / max(elapsed, 1e-9),
    )


def run(quick: bool = True):
    workers_list = [4, 16] if quick else [4, 8, 16, 32, 64]
    payloads = [1, 256] if quick else [1, 64, 256, 1024]
    rows = []
    for proxied in (False, True):
        for w in workers_list:
            for kb in payloads:
                p = run_point(w, kb, proxied, n_tasks=24 if quick else 64)
                rows.append(p)
    return rows


def main(quick: bool = True, recorder=None):
    rows = run(quick)
    print("proxy_app: workers,payload_kb,proxied,reaction_ms,decision_ms,dispatch_ms,rate_per_s")
    for p in rows:
        print(f"proxy_app,{p.workers},{p.payload_kb},{int(p.proxied)},"
              f"{p.reaction_ms:.3f},{p.decision_ms:.3f},{p.dispatch_ms:.3f},{p.rate_per_s:.1f}")
        if recorder is not None:
            tag = f"w{p.workers}_kb{p.payload_kb}_{'proxy' if p.proxied else 'ctl'}"
            recorder.metric(f"reaction_ms_{tag}", p.reaction_ms, unit="ms")
            recorder.metric(f"rate_per_s_{tag}", p.rate_per_s, unit="tasks/s")
    return rows


if __name__ == "__main__":
    main(quick=False)
