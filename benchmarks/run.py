"""Benchmark harness entry point: one benchmark per paper table/figure.

``python -m benchmarks.run`` runs the quick suite and prints
``name,...`` CSV rows per benchmark (plus a summary line per suite).
``--full`` runs the paper-scale sweeps; ``--smoke`` runs only the fast
dispatch-path benchmarks (the CI regression gate: ``overhead`` enforces
the warm-batched >= 2x acceptance bound and raises on regression).

Figure map:
  proxy_app      -> Fig. 7 (reaction/decision/dispatch latencies)
  weak_scaling   -> Fig. 3 (inference rate vs workers, fabric vs control)
  utilization    -> Figs. 2/5 (busy fractions, stateful-cache ablation,
                    static-vs-adaptive slots + elastic-vs-static fleet gate)
  multisite      -> Fig. 4 (local vs federated backends)
  steering_gain  -> '+20% high-performers' claim: scenario x acquisition
                    policy sweep over repro.surrogate (random vs greedy/
                    UCB/EI/Thompson, steered >= 1.2x random gate)
  overhead       -> warm-worker cache x batched dispatch (event-log
                    per-task overhead, cache hit-rate, batch occupancy)
  kernel_bench   -> kernels/ (XLA timings + TPU roofline estimates)
  soak           -> chaos tier (fault injection under 10^4-10^5-task
                    soak; exactly-once + bounded-recovery gate). Not in
                    --smoke: CI runs it as its own soak-chaos job via
                    ``python -m benchmarks.soak --smoke --record``.
  control_plane  -> multi-campaign control plane (N campaigns over one
                    fleet under daemon SIGKILL + auto-resume; fair-share
                    + remote-resize gates). Not in --smoke: CI runs it
                    as its own control-smoke job via
                    ``python -m benchmarks.control_plane --smoke --record``.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="fast dispatch-path subset (CI regression gate)")
    ap.add_argument("--only", default=None, help="run a single benchmark")
    ap.add_argument("--record", nargs="?", const="bench_out", default=None,
                    metavar="DIR",
                    help="write a BENCH_<suite>.json recording per suite to "
                         "DIR (default bench_out/); diff two runs with "
                         "`python -m repro.observe bench diff OLD NEW`")
    args = ap.parse_args()
    quick = not args.full

    from . import (
        control_plane, kernel_bench, multisite, overhead, proxy_app, soak,
        steering_gain, utilization, weak_scaling,
    )

    suites = {
        "overhead": overhead.main,
        "proxy_app": proxy_app.main,
        "weak_scaling": weak_scaling.main,
        "utilization": utilization.main,
        "multisite": multisite.main,
        "steering_gain": steering_gain.main,
        "kernel_bench": kernel_bench.main,
        "soak": soak.main,
        "control_plane": control_plane.main,
    }
    if args.smoke:
        # steering_gain's smoke form is the CI quadratic gate: steered
        # must find >= the random baseline's high-performers (seeded).
        suites = {name: suites[name] for name in ("overhead", "utilization")}
        suites["steering_gain"] = (
            lambda quick, recorder=None: steering_gain.main_ci_gate(recorder=recorder))
    if args.only:
        suites = {args.only: suites[args.only]}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        t0 = time.monotonic()
        recorder = None
        if args.record is not None:
            from repro.observe import BenchRecorder
            recorder = BenchRecorder(name, out_dir=args.record)
        try:
            fn(quick=quick, recorder=recorder)
            print(f"suite,{name},ok,{time.monotonic() - t0:.1f}s")
            if recorder is not None:
                print(f"suite,{name},recorded,{recorder.finish(ok=True)}")
        except Exception as exc:  # noqa: BLE001
            failures += 1
            print(f"suite,{name},FAILED,{type(exc).__name__}: {exc}")
            if recorder is not None:
                recorder.finish(ok=False, error=f"{type(exc).__name__}: {exc}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
