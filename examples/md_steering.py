"""DeepDriveMD-style steering of a molecular-dynamics ensemble (Fig. 6).

An ensemble of synthetic MD trajectories (overdamped Langevin walkers on
a double-well landscape) runs as continuous chunked tasks. The novelty
model is a ``repro.surrogate.DeepEnsemble`` trained asynchronously to
predict the potential energy of visited frames: where the walkers have
sampled densely the members agree, and the *epistemic disagreement*
(prediction std) is high exactly in under-sampled regions — so novelty
scoring and restart-bank selection run server-side on the warm-started
ensemble, and walkers judged stuck in already-sampled basins are
RESTARTED from the most novel frames — the paper's rare-event-sampling
loop.

Success metrics: state-space coverage (fraction of the reaction
coordinate explored — what outlier-driven sampling directly targets) and
well transitions, steered vs. unsteered.

Run:  PYTHONPATH=src python examples/md_steering.py
"""

import time
from typing import Dict, List

import numpy as np

from repro.app import AppSpec, ColmenaApp, SteeringSpec, TaskDef
from repro.core import (
    BaseThinker,
    ResourceCounter,
    ResourceRequest,
    agent,
    result_processor,
    stateful_task,
)
from repro.observe import render_text, run_pool_workload
from repro.surrogate import DeepEnsemble, EnsembleConfig, warmup_jit

DIM = 2
CHUNK = 40          # MD steps per task
BETA = 8.0          # inverse temperature (deep rare-event regime)

# Novelty-scorer ensemble: small + fixed pad so every retrain reuses one
# compiled fit/predict shape (see repro.surrogate.ensemble).
SCORER_CFG = EnsembleConfig(n_members=3, hidden=(16, 16), epochs=20, pad_to=512)


def _force(x):
    # double well along dim 0: V = (x0^2-1)^2 + 0.5*x1^2
    f0 = -4 * x[0] * (x[0] ** 2 - 1)
    return np.array([f0, -x[1]])


def md_chunk(x0: np.ndarray, seed: int) -> Dict:
    """Run CHUNK Langevin steps; return the trajectory."""
    rng = np.random.default_rng(seed)
    x = np.asarray(x0, float).copy()
    traj = np.empty((CHUNK, DIM))
    dt = 0.01
    for t in range(CHUNK):
        x = x + dt * _force(x) + np.sqrt(2 * dt / BETA) * rng.standard_normal(DIM)
        traj[t] = x
    time.sleep(0.005)
    return {"traj": traj, "x_final": x}


def _potential(frames: np.ndarray) -> np.ndarray:
    x0, x1 = frames[:, 0], frames[:, 1]
    return (x0 ** 2 - 1) ** 2 + 0.5 * x1 ** 2


@stateful_task
def train_scorer(frames: np.ndarray, registry=None) -> Dict:
    """Epistemic-novelty model: a warm-started ``DeepEnsemble`` learns to
    predict the potential at visited frames; member disagreement (the
    prediction std) is high precisely in under-sampled regions. Restart
    scores temper novelty by energy: pure novelty favors high-energy
    tails the walker immediately relaxes out of; the paper notes that
    'domain-specific biophysical calculations are still needed to guide
    AI-driven sampling properly' — here the potential plays that role,
    pointing restarts at under-sampled low-barrier states (the saddle).
    Novelty is scored on a fixed low-energy grid over the reaction
    domain, not on the visited frames themselves (those are in-
    distribution by construction, so members agree there); grid states
    the walkers never sampled are where the disagreement lives. The
    ensemble lives in the worker registry, so each retrain is a warm
    continuation; the task returns the restart bank, not the model."""
    X = np.asarray(frames)
    # Strided subsample across the whole history (ceil stride so the
    # newest frames are included): keeps every retrain at one compiled
    # shape (SCORER_CFG.pad_to) and ms-scale on CPU.
    X = X[:: max(1, -(-len(X) // 512))]
    ens = registry.get("ensemble")
    if ens is None:
        ens = registry["ensemble"] = DeepEnsemble(
            DIM, SCORER_CFG, seed=registry.get("seed", 0))
        g0, g1 = np.meshgrid(np.linspace(-1.8, 1.8, 32), np.linspace(-1.2, 1.2, 16))
        registry["grid"] = np.stack([g0.ravel(), g1.ravel()], axis=1)
    metrics = ens.fit(X, _potential(X), warm_start=True)
    grid = registry["grid"]
    _, std = ens.predict(grid)
    scores = np.where(_potential(grid) < 1.2, std, -np.inf)
    top = np.argsort(-scores)[:16]
    return {"bank": grid[top], "rmse": metrics["rmse"], "fit_count": ens.fit_count}


class MDThinker(BaseThinker):
    def __init__(self, queues, n_walkers=6, budget=120, steer=True, retrain_every=10):
        super().__init__(queues, ResourceCounter(n_walkers, pools=["md", "ml"]))
        self.rng = np.random.default_rng(0)
        self.budget = budget
        self.steer = steer
        self.retrain_every = retrain_every
        self.chunks_done = 0
        self.frames: List[np.ndarray] = []
        self.model = None
        self.transitions = 0
        self._last_well: Dict[int, int] = {}
        self._walker_pos = {i: np.array([-1.0, 0.0]) for i in range(n_walkers)}
        self._novel_bank: List[np.ndarray] = [np.array([-1.0, 0.0])]

    def _submit(self, walker: int):
        x0 = self._walker_pos[walker]
        self.queues.send_inputs(
            x0, int(self.rng.integers(1 << 30)),
            method="md_chunk", topic="default",
            task_info={"walker": walker},
            resources=ResourceRequest(pool="md"),
        )

    @agent(startup=True)
    def startup(self):
        for i in self._walker_pos:
            self._submit(i)

    @result_processor()
    def on_chunk(self, result):
        if result.method == "train_scorer":
            if result.success:
                self.model = result.value
                # the restart bank was ranked server-side on the warm
                # ensemble's epistemic disagreement
                self._novel_bank = list(result.value["bank"])
                log = getattr(self.queues, "event_log", None)
                if log is not None:
                    log.surrogate_event("retrain", value=result.value["rmse"],
                                        round=result.value["fit_count"])
            return
        if not result.success:
            self._submit(result.task_info["walker"])
            return
        w = result.task_info["walker"]
        traj = result.value["traj"]
        self.frames.append(traj)
        self.chunks_done += 1

        # transition bookkeeping (well = sign of x0)
        wells = np.sign(traj[:, 0])
        prev = self._last_well.get(w, wells[0])
        self.transitions += int(np.sum(np.abs(np.diff(np.concatenate([[prev], wells]))) > 0) // 2)
        self._last_well[w] = wells[-1]

        # steering: stuck walkers restart from the most novel frames
        x_next = result.value["x_final"]
        if self.steer and self.model is not None and self.rng.random() < 0.7:
            # DeepDriveMD round: restart ensemble members from outliers
            x_next = self._novel_bank[self.rng.integers(len(self._novel_bank))]
            x_next = x_next + self.rng.normal(0, 0.1, DIM)
        self._walker_pos[w] = x_next

        if self.steer and self.chunks_done % self.retrain_every == 0:
            frames = np.concatenate(self.frames)[-2000:]
            self.queues.send_inputs(frames, method="train_scorer", topic="default",
                                    resources=ResourceRequest(pool="ml"))
        if self.chunks_done >= self.budget:
            self.done.set()
            return
        self._submit(w)


def run(steer: bool, budget: int = 120) -> Dict:
    app = ColmenaApp(AppSpec(
        tasks=[
            TaskDef(fn=md_chunk, method="md_chunk", pool="md"),
            TaskDef(fn=train_scorer, method="train_scorer", pool="ml"),
        ],
        pools={"md": 4, "ml": 1, "default": 1},
        steering=SteeringSpec(MDThinker, dict(budget=budget, steer=steer)),
    ))
    report = app.execute(timeout=300)
    thinker = app.thinker
    allf = np.concatenate(thinker.frames)
    hist, _ = np.histogram(allf[:, 0], bins=48, range=(-1.8, 1.8))
    coverage = float((hist > 0).mean())
    return {"steered": steer, "transitions": thinker.transitions,
            "coverage": coverage, "chunks": thinker.chunks_done,
            "wall_s": report.wall_seconds, "report": app.observe_report()}


def reallocation_demo(n_slots: int = 6, n_md: int = 60, n_ml: int = 6) -> None:
    """AdaptiveReallocator on the real MD task mix.

    Many short ``md_chunk`` tasks plus a few ``train_scorer`` retrains,
    slots split evenly. The ML side drains early; the reallocator watches
    backlog telemetry and migrates its idle slots to the MD ensemble —
    the paper's utilization-maximizing steering in ~a second of runtime.
    """
    rng = np.random.default_rng(0)
    work = {
        "md": [((np.array([-1.0, 0.0]), int(rng.integers(1 << 30))), {})
               for _ in range(n_md)],
        "ml": [((rng.standard_normal((200, DIM)),), {}) for _ in range(n_ml)],
    }
    allocations = {"md": n_slots // 2, "ml": n_slots - n_slots // 2}
    methods = {"md": "md_chunk", "ml": "train_scorer"}
    fns = {"md_chunk": md_chunk, "train_scorer": train_scorer}

    results = {}
    for label, adaptive in (("static", False), ("adaptive", True)):
        report, _, thinker = run_pool_workload(
            allocations, work, methods, fns, adaptive=adaptive)
        results[label] = report
        moves = getattr(thinker.reallocator, "moves", [])
        print(f"{label:<9} utilization={report['utilization']['total']:.1%} "
              f"makespan={report['makespan_s']:.2f}s moves={len(moves)}")
        for _, src, dst, n in moves:
            print(f"          moved {n} slot(s) {src} -> {dst}")
    gain = (results["adaptive"]["utilization"]["total"]
            / max(results["static"]["utilization"]["total"], 1e-9))
    print(f"reallocation utilization gain: {gain:.2f}x")


def main():
    # Pre-compile the scorer's fit/predict graphs so the first in-run
    # retrain returns in ms instead of stalling on XLA.
    warmup_jit(DIM, SCORER_CFG, predict_rows=512)
    base = run(steer=False)
    steered = run(steer=True)
    for r in (base, steered):
        label = "steered  " if r["steered"] else "unsteered"
        print(f"{label}: coverage={r['coverage']:.2f} transitions={r['transitions']} "
              f"({r['chunks']} chunks)")
    print(f"coverage gain: {steered['coverage']/max(base['coverage'],1e-9):.2f}x")
    print("\n--- steered-run telemetry (event log) ---")
    print(render_text(steered["report"]))
    print("\n--- adaptive reallocation demo ---")
    reallocation_demo()


if __name__ == "__main__":
    main()
