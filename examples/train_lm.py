"""End-to-end driver: Colmena-steered LM training with fault recovery.

Wraps ``repro.launch.train``: trains a reduced gemma-family model for a
few hundred steps through the full steering stack (chunked train tasks on
stateful workers, async checkpoints, plateau monitor), then INJECTS a
node failure mid-run and shows the campaign recovering from the latest
checkpoint. ``--scale 4`` reaches the ~100M-param end-to-end config on
real hardware.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--scale 1]
"""

import argparse
import json
import tempfile

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--no-preempt", action="store_true")
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro-trainlm-")
    preempt_at = None if args.no_preempt else args.steps // 2
    report = run(
        arch=args.arch, steps=args.steps, scale=args.scale,
        ckpt_dir=ckpt_dir, ckpt_every=max(20, args.steps // 6),
        preempt_at=preempt_at,
    )
    print(json.dumps(report, indent=2))
    assert report["final_loss"] < report["first_loss"], "loss must decrease"
    if preempt_at is not None:
        assert report["workers_replaced"] >= 1, "recovery path not exercised"
        print(f"\nsurvived an injected node failure at step {preempt_at}: "
              f"{report['workers_replaced']} worker(s) replaced, "
              f"{report['tasks_retried']} task(s) retried, loss "
              f"{report['first_loss']:.2f} -> {report['final_loss']:.2f}")


if __name__ == "__main__":
    main()
