"""Molecular design campaign — the paper's flagship application (Fig. 2),
now steered by the ``repro.surrogate`` subsystem.

A synthetic molecular property landscape is searched under a fixed task
budget. The ``ActiveLearningThinker`` owns the paper's online loop: as
simulations land, it shifts worker slots to the training pool, retrains
a jit-compiled deep-ensemble surrogate (warm-started from the previous
round), re-ranks the candidate queue with an acquisition policy, and
shifts the slots back — with every retrain, re-rank, and reallocation
recorded in the ``repro.observe`` event log.

The campaign still runs through the batched dispatch path: simulate
tasks are coalesced into shared worker round-trips, so the run report
shows steering telemetry (retrain cadence, prediction error,
acquisition regret) next to dispatch telemetry (batch occupancy) from
one event log. (The proxystore fabric and warm-worker caches are
exercised by benchmarks/overhead.py — this campaign's payloads are
8-float candidates, far below any proxy threshold.)

``__main__`` compares an unsteered random baseline against a steered
policy on the same budget — the paper's '+20% high-performing
molecules' claim — then prints the steered run's full report.

Run:  PYTHONPATH=src python examples/molecular_design.py
"""

import time

import numpy as np

from repro.core import (
    BatchPolicy,
    LocalColmenaQueues,
    TaskServer,
    WorkerPool,
)
from repro.observe import EventLog, MetricsAggregator, build_report, render_text
from repro.surrogate import (
    ActiveLearningThinker,
    DeepEnsemble,
    EnsembleConfig,
    make_policy,
    SyntheticScenario,
    warmup_jit,
)

DIM = 8
N_CANDIDATES = 1024
BUDGET = 96


class MolecularLandscape(SyntheticScenario):
    """Stand-in for the quantum-chemistry property: a smooth basin with
    sinusoidal structure (the shape the paper's surrogate learns)."""

    name = "molecular"

    def true_batch(self, X: np.ndarray) -> np.ndarray:
        return -((X - 0.35) ** 2).sum(axis=1) + 0.05 * np.sin(4 * X).sum(axis=1)

    def evaluate(self, x: np.ndarray, seed: int = 0) -> float:
        time.sleep(0.008)  # the "expensive" simulation
        return self.true_value(x)


def run_campaign(policy_name: str, budget: int = BUDGET, seed: int = 0) -> dict:
    scenario = MolecularLandscape(dim=DIM)
    rng = np.random.default_rng(seed)
    candidates = scenario.sample(rng, N_CANDIDATES)

    log = EventLog()
    queues = LocalColmenaQueues(topics=["simulate", "train"], event_log=log)
    pools = {"simulate": WorkerPool("simulate", 4),
             "ml": WorkerPool("ml", 1),
             "default": WorkerPool("default", 1)}
    cfg = EnsembleConfig(pad_to=128)
    thinker = ActiveLearningThinker(
        queues,
        ensemble=DeepEnsemble(DIM, cfg, seed=seed),
        policy=make_policy(policy_name),
        candidates=candidates,
        n_slots=4,
        retrain_after=16,
        max_results=budget,
        ml_slots=1,
        optimum_value=scenario.optimum_value,
        seed=seed,
    )
    thinker.rec.event_log = log
    server = TaskServer(
        queues, {"simulate": scenario.evaluate},
        pools=pools,
        # Shallow batches: simulations are compute-bound, deep batches
        # would serialize them on one worker.
        batching=BatchPolicy(max_batch=2, linger_s=0.001, methods=("simulate",)),
        event_log=log,
    ).start()
    t0 = time.monotonic()
    thinker.run(timeout=300)
    wall = time.monotonic() - t0
    server.stop()

    X, y = thinker.observed
    X, y = X[:budget], y[:budget]
    hits = int(sum(scenario.true_value(x) > scenario.threshold for x in X))
    agg = MetricsAggregator(log)
    batches = agg.batch_stats()["total"]
    return {
        "policy": policy_name, "hits": hits,
        "best": float(y.max()) if len(y) else float("-inf"),
        "retrains": thinker.train_rounds, "wall_s": wall,
        "mean_batch_occupancy": batches.mean_occupancy,
        "report": build_report(log, slots_by_pool={"simulate": 4, "ml": 1}),
    }


def main():
    warmup_jit(DIM, EnsembleConfig(pad_to=128), predict_rows=N_CANDIDATES)
    random = run_campaign("random")
    steered = run_campaign("ucb")
    for r in (random, steered):
        print(f"[{r['policy']:>6}] {r['hits']} high-performing molecules, "
              f"best {r['best']:.3f}, {r['retrains']} retrains, "
              f"batch occupancy {r['mean_batch_occupancy']:.1f} "
              f"({r['wall_s']:.1f}s)")
    gain = (steered["hits"] - random["hits"]) / max(random["hits"], 1) * 100
    print(f"steering gain: {gain:+.0f}% high-performers within the same budget")
    print("\n--- steered-run telemetry (event log) ---")
    print(render_text(steered["report"]))
    return random, steered


if __name__ == "__main__":
    main()
