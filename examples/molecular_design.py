"""Molecular design campaign — the paper's flagship application (Fig. 2),
steered by ``repro.surrogate`` and composed through ``repro.app``.

A synthetic molecular property landscape is searched under a fixed task
budget. The ``ActiveLearningThinker`` owns the paper's online loop: as
simulations land, it shifts worker slots to the training pool, retrains
a jit-compiled deep-ensemble surrogate (warm-started from the previous
round), re-ranks the candidate queue with an acquisition policy, and
shifts the slots back — with every retrain, re-rank, and reallocation
recorded in the ``repro.observe`` event log.

The platform side is one ``AppSpec``: the simulate task rides the
batched dispatch path (``batch=True`` in the task registry), the worker
fleet is the ``pools`` mapping, and telemetry needs no wiring at all —
so the run report shows steering telemetry (retrain cadence, prediction
error, acquisition regret) next to dispatch telemetry (batch occupancy)
from one composed event log.

``__main__`` compares an unsteered random baseline against a steered
policy on the same budget — the paper's '+20% high-performing
molecules' claim — then prints the steered run's full report.

Run:  PYTHONPATH=src python examples/molecular_design.py [--smoke]
"""

import argparse
import time

import numpy as np

from repro.app import AppSpec, ColmenaApp, QueueSpec, ServerSpec, SteeringSpec, TaskDef
from repro.core import BatchPolicy
from repro.observe import MetricsAggregator, render_text
from repro.surrogate import (
    ActiveLearningThinker,
    DeepEnsemble,
    EnsembleConfig,
    make_policy,
    SyntheticScenario,
    warmup_jit,
)

DIM = 8
N_CANDIDATES = 1024
BUDGET = 96


class MolecularLandscape(SyntheticScenario):
    """Stand-in for the quantum-chemistry property: a smooth basin with
    sinusoidal structure (the shape the paper's surrogate learns)."""

    name = "molecular"

    def true_batch(self, X: np.ndarray) -> np.ndarray:
        return -((X - 0.35) ** 2).sum(axis=1) + 0.05 * np.sin(4 * X).sum(axis=1)

    def evaluate(self, x: np.ndarray, seed: int = 0) -> float:
        time.sleep(0.008)  # the "expensive" simulation
        return self.true_value(x)


def run_campaign(policy_name: str, budget: int = BUDGET, seed: int = 0,
                 retrain_after: int = 16) -> dict:
    scenario = MolecularLandscape(dim=DIM)
    rng = np.random.default_rng(seed)
    candidates = scenario.sample(rng, N_CANDIDATES)

    app = ColmenaApp(AppSpec(
        tasks=[TaskDef(fn=scenario.evaluate, method="simulate", pool="simulate")],
        queues=QueueSpec(topics=("simulate", "train")),
        pools={"simulate": 4, "ml": 1, "default": 1},
        # Shallow batches: simulations are compute-bound, deep batches
        # would serialize them on one worker.
        server=ServerSpec(batching=BatchPolicy(
            max_batch=2, linger_s=0.001, methods=("simulate",))),
        steering=SteeringSpec(ActiveLearningThinker, dict(
            ensemble=DeepEnsemble(DIM, EnsembleConfig(pad_to=128), seed=seed),
            policy=make_policy(policy_name),
            candidates=candidates,
            n_slots=4,
            retrain_after=retrain_after,
            max_results=budget,
            ml_slots=1,
            optimum_value=scenario.optimum_value,
            seed=seed,
        )),
    ))
    report = app.execute(timeout=300)
    thinker = app.thinker

    X, y = thinker.observed
    X, y = X[:budget], y[:budget]
    hits = int(sum(scenario.true_value(x) > scenario.threshold for x in X))
    batches = MetricsAggregator(app.event_log).batch_stats()["total"]
    return {
        "policy": policy_name, "hits": hits,
        "best": float(y.max()) if len(y) else float("-inf"),
        "retrains": thinker.train_rounds, "wall_s": report.wall_seconds,
        "mean_batch_occupancy": batches.mean_occupancy,
        "report": app.observe_report(),
    }


def main(budget: int = BUDGET):
    warmup_jit(DIM, EnsembleConfig(pad_to=128), predict_rows=N_CANDIDATES)
    retrain_after = max(8, budget // 6)
    random = run_campaign("random", budget=budget, retrain_after=retrain_after)
    steered = run_campaign("ucb", budget=budget, retrain_after=retrain_after)
    for r in (random, steered):
        print(f"[{r['policy']:>6}] {r['hits']} high-performing molecules, "
              f"best {r['best']:.3f}, {r['retrains']} retrains, "
              f"batch occupancy {r['mean_batch_occupancy']:.1f} "
              f"({r['wall_s']:.1f}s)")
    gain = (steered["hits"] - random["hits"]) / max(random["hits"], 1) * 100
    print(f"steering gain: {gain:+.0f}% high-performers within the same budget")
    print("\n--- steered-run telemetry (event log) ---")
    print(render_text(steered["report"]))
    return random, steered


def main_smoke():
    """CI entry point: one small steered run; the stack must compose,
    steer (>= 1 online retrain), and keep a complete lifecycle trace."""
    warmup_jit(DIM, EnsembleConfig(pad_to=128), predict_rows=N_CANDIDATES)
    out = run_campaign("ucb", budget=32, retrain_after=10)
    assert out["retrains"] >= 1, f"expected an online retrain, saw {out['retrains']}"
    # In-flight overshoot tasks may be dropped unread at budget shutdown;
    # any other lifecycle gap means the composed stack lost an event.
    gaps = out["report"]["lifecycle"]["gaps"]
    bad = {t: m for t, m in gaps.items() if m != ["result_received"]}
    assert not bad, f"lifecycle gaps beyond shutdown drops: {bad}"
    assert out["report"]["lifecycle"]["ordered"], "out-of-order lifecycle trace"
    print(f"smoke ok: {out['hits']} hits, {out['retrains']} retrains, "
          f"{out['wall_s']:.1f}s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run with composition assertions (CI)")
    args = ap.parse_args()
    main_smoke() if args.smoke else main()
