"""Molecular design campaign — the paper's flagship application (Fig. 2).

Three task types share a worker fleet:
  * simulate — evaluates a candidate 'molecule' (synthetic landscape),
  * train    — refits a JAX ridge surrogate on all results so far,
  * infer    — scores a large candidate pool with the surrogate
               (inputs shipped once through the ProxyStore fabric).

The Thinker reallocates resources between simulation and ML when
retraining triggers, steers further sampling toward surrogate optima,
and reports the outcome vs. an unsteered random baseline (the paper's
'+20% high-performing molecules' claim).

The campaign runs on the warm-worker data fabric: simulation tasks are
coalesced by batched dispatch, inference inputs stay warm in per-worker
caches, and the run report includes cache hit-rate and batch occupancy
from the event log. ``__main__`` runs the warm+batched and cold+unbatched
configurations back to back so both dispatch paths are exercised.

Run:  PYTHONPATH=src python examples/molecular_design.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BatchPolicy,
    BatchRetrainThinker,
    InMemoryConnector,
    LocalColmenaQueues,
    ResourceRequest,
    Store,
    TaskServer,
    WorkerPool,
    stateful_task,
)
from repro.observe import EventLog, MetricsAggregator

DIM = 8
THRESH = -1.0


def simulate(x: np.ndarray) -> float:
    time.sleep(0.01)
    x = np.asarray(x)
    return float(-np.sum((x - 0.35) ** 2) + 0.05 * np.sin(4 * x).sum())


def _features(X):
    """Quadratic features: the surrogate must capture curvature."""
    return jnp.concatenate([X, X ** 2, jnp.ones((X.shape[0], 1))], axis=1)


def train(X, y) -> np.ndarray:
    X = jnp.asarray(np.asarray(X))
    y = jnp.asarray(np.asarray(y))
    Xb = _features(X)
    w = jnp.linalg.solve(Xb.T @ Xb + 1e-3 * jnp.eye(Xb.shape[1]), Xb.T @ y)
    return np.asarray(w)


@stateful_task
def infer(w, pool, registry=None):
    """Score a candidate pool; the pool rides the fabric and is cached."""
    fn = registry.get("score_fn")
    if fn is None:
        fn = registry["score_fn"] = jax.jit(lambda w, X: _features(X) @ w)
    scores = fn(jnp.asarray(np.asarray(w)), jnp.asarray(np.asarray(pool)))
    return np.asarray(scores)


class MolecularDesign(BatchRetrainThinker):
    def __init__(self, queues, store, candidate_pool, **kw):
        super().__init__(queues, **kw)
        self.rng = np.random.default_rng(0)
        self.store = store
        # bulk ahead-of-time transfer: pool proxied ONCE, reused by every
        # inference task (the paper's manual-proxy optimization)
        self.pool_ref = store.proxy(candidate_pool)
        self.pool = candidate_pool
        self.w = None
        self.ranked = None

    def simulate_args(self):
        r = self.rng.random()
        if self.database and r < 0.6:
            # exploit: refine around the best simulations so far
            top = sorted(self.database, key=lambda rr: -rr.value)[:8]
            pick = top[self.rng.integers(len(top))]
            x = np.clip(np.asarray(pick.args[0]) + self.rng.normal(0, 0.15, DIM), -1, 1)
        elif self.ranked is not None and r < 0.85:
            # surrogate-ranked candidates from the proxied pool
            idx = self.ranked[self.rng.integers(0, 32)]
            x = np.clip(self.pool[idx] + self.rng.normal(0, 0.1, DIM), -1, 1)
        else:
            x = self.rng.uniform(-1, 1, DIM)
        return (x,)

    def make_train_task(self):
        X = np.stack([np.asarray(r.args[0]) for r in self.database])
        y = np.asarray([r.value for r in self.database])
        return (X, y), {}

    def on_train(self, result):
        if not result.success:
            return
        self.w = np.asarray(result.value)
        # act on new model: launch inference over the full candidate pool
        self.queues.send_inputs(
            self.w, self.pool_ref, method="infer", topic="train",
            resources=ResourceRequest(pool="ml"),
        )

    from repro.core import result_processor as _rp

    @_rp(topic="train")
    def receive_training(self, result):  # route infer results too
        if result.method == "infer":
            if result.success:
                self.ranked = np.argsort(-np.asarray(result.value))
            return
        # train results: base-class bookkeeping
        with self._state_lock:
            self._ml_inflight = max(0, self._ml_inflight - 1)
        self.train_rounds += 1
        self.on_train(result)
        self._maybe_finish()


def main(budget: int = 120, warm: bool = True, batch: bool = True):
    tag = f"{'warm' if warm else 'cold'}+{'batched' if batch else 'unbatched'}"
    rng = np.random.default_rng(1)
    candidate_pool = rng.uniform(-1, 1, (4096, DIM))

    # Warm up jax op compilation outside the campaign so the first retrain
    # (and cross-config comparisons under __main__) aren't dominated by it.
    w0 = train(np.zeros((4, DIM)), np.zeros(4))
    infer(w0, np.zeros((4, DIM)), registry={})

    log = EventLog()
    store = Store(f"moldesign-{tag}", InMemoryConnector())
    queues = LocalColmenaQueues(topics=["simulate", "train"],
                                proxystore=store, proxy_threshold=10_000,
                                event_log=log)
    warm_cap = 32 if warm else 0
    pools = {"simulate": WorkerPool("simulate", 4, warm_capacity=warm_cap),
             "ml": WorkerPool("ml", 1, warm_capacity=warm_cap),
             "default": WorkerPool("default", 1, warm_capacity=warm_cap)}
    thinker = MolecularDesign(
        queues, store, candidate_pool,
        n_slots=4, retrain_after=20, max_results=budget, ml_slots=1,
    )
    server = TaskServer(
        queues, {"simulate": simulate, "train": train, "infer": infer},
        pools=pools,
        # max_batch=2: simulations are compute-bound (10 ms each), so deep
        # batches would serialize them on one worker; a shallow batch still
        # halves the dispatch round-trips without costing parallelism.
        batching=BatchPolicy(max_batch=2, linger_s=0.001,
                             methods=("simulate", "infer")) if batch else None,
        event_log=log,
    ).start()
    t0 = time.monotonic()
    thinker.run(timeout=300)
    wall = time.monotonic() - t0
    server.stop()

    steered_hits = sum(1 for r in thinker.database if r.value > THRESH)
    base_hits = sum(1 for _ in range(budget)
                    if simulate(rng.uniform(-1, 1, DIM)) > THRESH)
    agg = MetricsAggregator(log)
    cache = agg.cache_stats()["total"]
    batches = agg.batch_stats()["total"]
    print(f"[{tag}] campaign: {len(thinker.database)} simulations, "
          f"{thinker.train_rounds} retrains in {wall:.1f}s")
    print(f"[{tag}] high-performing molecules: steered={steered_hits} random={base_hits} "
          f"({(steered_hits - base_hits) / max(base_hits, 1) * 100:+.0f}%)")
    print(f"[{tag}] fabric: {store.metrics.fabric_bytes_out/1e6:.2f} MB moved, "
          f"warm-cache hit rate {cache.hit_rate:.2f} "
          f"({cache.hits} hits / {cache.misses} misses), "
          f"mean batch occupancy {batches.mean_occupancy:.1f} "
          f"over {batches.batches} batches")
    return {"wall_s": wall, "cache_hit_rate": cache.hit_rate,
            "mean_batch_occupancy": batches.mean_occupancy,
            "steered_hits": steered_hits, "base_hits": base_hits}


if __name__ == "__main__":
    fast = main(warm=True, batch=True)
    slow = main(warm=False, batch=False)
    print(f"comparison: warm+batched {fast['wall_s']:.1f}s "
          f"(hit rate {fast['cache_hit_rate']:.2f}, "
          f"occupancy {fast['mean_batch_occupancy']:.1f}) vs "
          f"cold+unbatched {slow['wall_s']:.1f}s "
          f"(dispatch-path speedups are measured in benchmarks/overhead.py)")
