"""Quickstart: a 60-line Colmena application on the ``repro.app`` layer.

A Thinker steers a pool of workers computing a toy property; the
platform side — queues, task server, worker pools, telemetry — is
composed declaratively from one ``AppSpec``, so this file is agents +
science only.
Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

from repro.app import AppSpec, ColmenaApp, SteeringSpec, task
from repro.core import BaseThinker, ResourceCounter, agent, result_processor


@task
def simulate(x: np.ndarray) -> float:
    """An 'expensive' computation (the paper's quantum-chemistry stand-in)."""
    time.sleep(0.02)
    return float(np.sum(np.sin(x)))


class Quickstart(BaseThinker):
    """Submit an initial population, then one new task per completion —
    the Markov-chain pattern from the paper's Listing 1."""

    def __init__(self, queues, n_parallel=4, n_total=32):
        super().__init__(queues, ResourceCounter(n_parallel))
        self.rng = np.random.default_rng(0)
        self.n_total = n_total
        self.submitted = 0
        self.samples = []

    def _submit(self):
        self.queues.send_inputs(self.rng.normal(size=8), method="simulate")
        self.submitted += 1

    @agent(startup=True)
    def startup(self):
        for _ in range(self.rec.total_slots):
            self._submit()

    @result_processor()
    def step(self, result):
        self.samples.append(result.value)
        if self.submitted < self.n_total:
            self._submit()
        elif len(self.samples) >= self.n_total:
            self.done.set()

    # Checkpointable: a campaign launched from examples/quickstart.toml
    # with a [campaign] section resumes mid-collection after a kill.
    # Only samples are persisted; submitted is recomputed on resume so
    # tasks lost in flight at the kill are simply submitted again.
    def get_state(self):
        return {"samples": list(self.samples)}

    def set_state(self, state):
        self.samples = list(state.get("samples", []))
        self.submitted = len(self.samples)


def main():
    app = ColmenaApp(AppSpec(
        tasks=[simulate],
        pools={"default": 4},
        steering=SteeringSpec(Quickstart),
    ))
    t0 = time.monotonic()
    with app.run(timeout=60) as handle:
        handle.wait()
    samples = handle.thinker.samples
    print(f"collected {len(samples)} results in {time.monotonic()-t0:.2f}s "
          f"(best={max(samples):.3f})")
    assert app.report.completed and len(samples) >= 32


if __name__ == "__main__":
    main()
