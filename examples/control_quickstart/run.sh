#!/usr/bin/env bash
# Control-plane quickstart: one daemon, two campaigns, one shared fleet.
#
#   cd <repo root>
#   PYTHONPATH=src bash examples/control_quickstart/run.sh
#
# Starts `python -m repro.control serve` over fleet.toml, submits the
# screening (weight 2) and calibration (weight 1) campaigns over HTTP,
# polls until both reach `done`, and shuts the daemon down. The daemon
# is crash-safe: `kill -9` it mid-run, rerun this script with the same
# ROOT, and both campaigns auto-resume from their checkpoints.
set -euo pipefail

HERE="$(cd "$(dirname "${BASH_SOURCE[0]}")" && pwd)"
ROOT="${ROOT:-$HERE/.control-root}"
PORT_FILE="$ROOT/.port"

mkdir -p "$ROOT"
rm -f "$PORT_FILE"

python -m repro.control serve \
  --root "$ROOT" --fleet "$HERE/fleet.toml" \
  --port-file "$PORT_FILE" --tick 0.2 &
DAEMON=$!
trap 'kill "$DAEMON" 2>/dev/null || true; wait "$DAEMON" 2>/dev/null || true' EXIT

for _ in $(seq 100); do [ -s "$PORT_FILE" ] && break; sleep 0.1; done
URL="http://127.0.0.1:$(cat "$PORT_FILE")"
echo "daemon up at $URL (root: $ROOT)"

python -m repro.control submit "$HERE/screening.toml"   --url "$URL" --name screening
python -m repro.control submit "$HERE/calibration.toml" --url "$URL" --name calibration

echo "waiting for both campaigns to reach done..."
for _ in $(seq 300); do
  STATES=$(python -m repro.control status --url "$URL" \
    | python -c 'import json,sys; print(" ".join(sorted(c["name"]+"="+c["state"] for c in json.load(sys.stdin)["campaigns"])))')
  echo "  $STATES"
  [ "$STATES" = "calibration=done screening=done" ] && break
  sleep 1
done

python -m repro.control status --url "$URL"
[ "$STATES" = "calibration=done screening=done" ] || { echo "campaigns did not finish"; exit 1; }
echo "both campaigns done; journals under $ROOT/campaigns/<id>/state/results.jsonl"
