"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds-per-step on TPU v5e:

    compute    = HLO_flops_per_device / 197e12
    memory     = HLO_bytes_per_device / 819e9
    collective = wire_bytes_per_device / 50e9

``cost_analysis()`` is per-device post-SPMD (verified empirically on this
jax build). Collective bytes are NOT in cost_analysis: we parse the
post-optimization HLO and apply ring-algorithm wire-byte conventions:

    all-gather       S_out * (n-1)/n
    reduce-scatter   S_in  * (n-1)/n      (S_in = unreduced input)
    all-reduce       2 * S * (n-1)/n
    all-to-all       S * (n-1)/n
    collective-permute  S

with n = replica-group size parsed per op. MODEL_FLOPS (6*N_active*D for
training, 2*N_active*D for decode/prefill) measures how much compiled
compute is "useful" — remat and redundant-compute waste shows up as
MODEL_FLOPS / (chips * HLO_flops) << 1.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .mesh import V5E_HBM_BW, V5E_ICI_LINK_BW, V5E_PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# result types of an HLO op: "bf16[128,4096]{1,0}" or tuple "(f32[2], f32[4])"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|[\w\[\],{}]+)\s+"
    r"(?P<op>all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\b"
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    result_bytes: Dict[str, int] = field(default_factory=dict)
    wire_bytes: float = 0.0                     # per-device, ring model
    cross_pod_wire_bytes: float = 0.0           # collectives spanning pods
    details: List[dict] = field(default_factory=list)


def parse_collectives(hlo_text: str, n_devices: int, pod_size: Optional[int] = None) -> CollectiveStats:
    """Sum per-device wire bytes of every collective in post-opt HLO."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op").replace("-start", "")
        rbytes = _type_bytes(m.group("rtype"))
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            group_size = int(gm.group(2))
            n_groups = int(gm.group(1))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            group_size = len(gl.group(1).split(",")) if gl else n_devices
            n_groups = n_devices // max(group_size, 1)
        n = max(group_size, 1)
        frac = (n - 1) / n
        # result_bytes is the per-device output size in SPMD HLO.
        if op == "all-gather":
            wire = rbytes * frac                     # gathered result streams in
        elif op == "reduce-scatter":
            wire = rbytes * n * frac                 # input = n * output
        elif op == "all-reduce":
            wire = 2 * rbytes * frac
        elif op == "all-to-all":
            wire = rbytes * frac
        else:  # collective-permute
            wire = rbytes
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.result_bytes[op] = stats.result_bytes.get(op, 0) + rbytes
        stats.wire_bytes += wire
        crosses_pod = bool(pod_size) and group_size > pod_size
        if crosses_pod:
            stats.cross_pod_wire_bytes += wire
        stats.details.append(
            {"op": op, "result_bytes": rbytes, "group_size": group_size,
             "wire_bytes": wire, "cross_pod": crosses_pod}
        )
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_total: float
    useful_flops_ratio: float        # MODEL_FLOPS / (chips * HLO_flops)
    roofline_fraction: float         # compute_s / max(all terms)
    peak_memory_bytes: int
    collective_counts: Dict[str, int]
    note: str = ""

    @staticmethod
    def build(arch, shape, mesh_name, n_devices, cost, memory_stats,
              coll: CollectiveStats, model_flops_total: float, note: str = ""):
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        compute_s = flops / V5E_PEAK_FLOPS_BF16
        memory_s = bytes_acc / V5E_HBM_BW
        collective_s = coll.wire_bytes / V5E_ICI_LINK_BW
        terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
        bottleneck = max(terms, key=terms.get)
        denom = n_devices * flops
        useful = model_flops_total / denom if denom else 0.0
        tmax = max(terms.values()) or 1.0
        return RooflineReport(
            arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
            flops_per_device=flops, bytes_per_device=bytes_acc,
            wire_bytes_per_device=coll.wire_bytes,
            compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
            bottleneck=bottleneck, model_flops_total=model_flops_total,
            useful_flops_ratio=useful,
            roofline_fraction=compute_s / tmax,
            peak_memory_bytes=memory_stats,
            collective_counts=dict(coll.counts),
            note=note,
        )

    def to_dict(self) -> dict:
        return dict(self.__dict__)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N_active*tokens (train), 2*N_active*tokens (fwd-only)."""
    n = cfg.n_active_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
