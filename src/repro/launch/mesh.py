"""Production mesh construction (TPU v5e pods).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — required because the
dry run must set XLA_FLAGS before the first jax initialization.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = ("data", "model") — 256 chips (v5e-256).
    Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: Optional[int] = None, model_axis: int = 2):
    """Small mesh over whatever devices exist (unit tests)."""
    n = n_devices or len(jax.devices())
    model_axis = min(model_axis, n)
    data_axis = n // model_axis
    return jax.make_mesh((data_axis, model_axis), ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
V5E_PEAK_FLOPS_BF16 = 197e12       # FLOP/s
V5E_HBM_BW = 819e9                 # B/s
V5E_ICI_LINK_BW = 50e9             # B/s per link (~; see EXPERIMENTS.md)
V5E_HBM_BYTES = 16 * 1024 ** 3     # 16 GiB
