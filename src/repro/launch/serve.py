"""Serving driver: continuous-batching engine + Colmena request steering.

A Thinker-side policy watches tokens as they stream (the paper's
multi-fidelity lesson: stop evaluating low-performing candidates early)
and cancels generations whose running score falls below a threshold.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import smoke_config
from ..models import build_model
from ..serve import Request, ServingEngine


def run(arch: str = "gemma-2b", n_requests: int = 12, n_slots: int = 4,
        max_new: int = 16, steer: bool = True):
    cfg = smoke_config(arch).with_(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def on_token(req: Request, tok: int) -> bool:
        # steering policy: abandon degenerate generations (repeated token)
        if steer and len(req.generated) >= 4:
            if len(set(req.generated[-4:])) == 1:
                return True
        return False

    finished = []
    engine = ServingEngine(model, params, n_slots=n_slots, max_len=128,
                           on_token=on_token, on_finish=finished.append)
    t0 = time.monotonic()
    for i in range(n_requests):
        prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(2, 6)).astype(np.int32)
        engine.submit(Request(request_id=i, prompt=prompt, max_new_tokens=max_new))
    stats = engine.run_until_drained()
    wall = time.monotonic() - t0
    ttft = [r.first_token_at - r.submitted_at for r in finished if r.first_token_at]
    return {
        "requests": stats.requests_finished,
        "cancelled_by_steering": stats.requests_cancelled,
        "tokens": stats.tokens_generated,
        "tokens_per_s": stats.tokens_generated / wall,
        "mean_occupancy": stats.mean_occupancy,
        "median_ttft_s": float(np.median(ttft)) if ttft else None,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--no-steer", action="store_true")
    args = ap.parse_args()
    print(json.dumps(run(args.arch, args.requests, args.slots, args.max_new,
                         steer=not args.no_steer), indent=2))


if __name__ == "__main__":
    main()
