import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
# The dry run — and ONLY the dry run — builds the 512-chip production mesh
# on CPU placeholder devices; smoke tests and benches see 1 device.

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (SPMD partitioning succeeds),
  * the program fits (memory_analysis per device),
  * and it yields the roofline terms (cost_analysis + HLO collectives).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, get_config
from ..configs.base import SHAPES, shape_applicable
from ..models import mesh_context
from ..models.layers import axis_rules, param_pspecs, resolve_pspec
from ..models.model_api import build_model
from ..serve.decode import make_dryrun_serve_step
from ..train.optimizer import OptimizerConfig, init_opt_state
from ..train.train_step import make_train_step
from .mesh import make_production_mesh
from .roofline import RooflineReport, model_flops, parse_collectives


def _sds(tree: Any, pspecs: Any, mesh) -> Any:
    """ShapeDtypeStruct tree with shardings from a pspec tree."""
    return jax.tree_util.tree_map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=NamedSharding(mesh, s)),
        tree, pspecs,
    )


def _opt_pspecs(params_specs: Any, opt_shapes: Dict[str, Any], oc: OptimizerConfig) -> Dict[str, Any]:
    """PartitionSpecs for optimizer state mirroring the param specs.

    Specs are padded to the param rank before dropping dims, because
    PartitionSpec strips trailing Nones (a P('data',) on a rank-4 param
    means dim0 only)."""

    out: Dict[str, Any] = {"step": P()}
    out["m"] = params_specs
    if oc.name == "adafactor":
        def build(shape_node, spec):
            if isinstance(shape_node, dict) and "vr" in shape_node:
                rank = len(shape_node["vr"].shape) + 1
                parts = list(spec) + [None] * (rank - len(list(spec)))
                return {
                    "vr": P(*parts[:-1]),                    # mean over last dim
                    "vc": P(*(parts[:-2] + parts[-1:])),     # mean over 2nd-last
                }
            if isinstance(shape_node, dict) and "v" in shape_node:
                return {"v": spec}
            raise TypeError(shape_node)

        out["v"] = jax.tree_util.tree_map(
            build, opt_shapes["v"], params_specs,
            is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x),
        )
    else:
        out["v"] = params_specs
    return out


def _cost_of(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return cost


def _analysis_cost(cfg, shape, mesh, multi_pod: bool) -> Dict[str, Any]:
    """Trip-count-corrected cost: XLA's cost_analysis counts a scan body
    ONCE, so the scanned full-depth compile under-reports flops by ~L x
    grad_accum. We re-lower an UNROLLED, single-microbatch variant at two
    depths (La, Lb), take the per-layer slope, and extrapolate:

        per_step = accum * (const + slope * L_full) + (analytic optimizer)

    Layers are homogeneous so the extrapolation is exact up to fusion
    differences at the stack boundary. Collective wire bytes get the same
    treatment. The real (scanned) compile remains the memory/fit proof.
    """
    period = cfg.attn_every if cfg.attn_every > 0 else 1
    la, lb = 1 * period, 2 * period
    accum = cfg.grad_accum if shape.kind == "train" else 1
    micro_batch = max(shape.global_batch // accum, 1)
    pod_size = 256 if multi_pod else None

    def cost_at(n_layers: int):
        c = cfg.with_(
            n_layers=n_layers, scan_layers=False, grad_accum=1,
            encoder_layers=n_layers if cfg.family == "whisper" else cfg.encoder_layers,
        )
        model = build_model(c)
        with mesh_context(mesh, c):
            p_specs = model.pspecs(mesh)
            p_sds = _sds(model.shapes(), p_specs, mesh)
            if shape.kind == "train":
                def grads_only(params, batch):
                    loss, _ = model.loss(params, batch)
                    return loss

                micro_shape = type(shape)(shape.name, shape.seq_len, micro_batch, "train")
                batch_sds = model.input_specs(micro_shape, mesh)
                lowered = jax.jit(jax.grad(grads_only)).lower(p_sds, batch_sds)
            elif shape.kind == "prefill":
                batch_sds = model.input_specs(shape, mesh)

                def prefill_step(params, batch):
                    logits, _ = model.forward(params, batch, last_only=True)
                    return jnp.argmax(logits[:, -1], axis=-1)

                lowered = jax.jit(prefill_step).lower(p_sds, batch_sds)
            else:
                cache_shapes = model.cache_shapes(shape.global_batch, shape.seq_len)
                cache_specs = model.cache_pspecs(mesh, shape.global_batch, shape.seq_len)
                c_sds = _sds(cache_shapes, cache_specs, mesh)
                io_sds = model.input_specs(shape, mesh)
                serve = make_dryrun_serve_step(model)
                lowered = jax.jit(serve).lower(p_sds, c_sds, io_sds["tokens"], io_sds["lengths"])
            compiled = lowered.compile()
        cost = _cost_of(compiled)
        coll = parse_collectives(compiled.as_text(), mesh.size, pod_size=pod_size)
        return (float(cost.get("flops", 0.0)), float(cost.get("bytes accessed", 0.0)),
                coll.wire_bytes, coll.cross_pod_wire_bytes)

    fa, ba, wa, xa = cost_at(la)
    fb, bb, wb, xb = cost_at(lb)
    L = cfg.n_layers

    def extrap(a, b):
        slope = (b - a) / (lb - la)
        const = a - slope * la
        return max(const + slope * L, 0.0)

    flops = accum * extrap(fa, fb)
    bytes_acc = accum * extrap(ba, bb)
    wire = accum * extrap(wa, wb)
    cross = accum * extrap(xa, xb)
    if shape.kind == "train":
        # optimizer update: ~12 flops/param, touches params+grads+state once
        n_local = cfg.n_params / mesh.size
        flops += 12.0 * n_local
        state_mult = {"adamw": 4, "adafactor": 2}.get(cfg.optimizer, 4)
        bytes_acc += n_local * (2 + 4 + state_mult * 2) * 2
    return {"flops": flops, "bytes accessed": bytes_acc,
            "wire_bytes": wire, "cross_pod_wire_bytes": cross,
            "points": {"la": la, "lb": lb, "fa": fa, "fb": fb}}


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               cfg_overrides: Optional[dict] = None, verbose: bool = True,
               opt: bool = False) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh) cell; return the report dict.

    ``opt=True`` applies the post-hillclimb per-shape policies on top of
    the per-arch configs: decode cells of attention-cache families use
    the tp2d (weight-resident) sharding; llama training drops to
    grad_accum=8 on the multi-pod mesh (see EXPERIMENTS.md §Perf)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if opt:
        if shape.kind == "decode" and cfg.family in ("dense", "moe", "vlm", "whisper"):
            cfg = cfg.with_(sharding="tp2d")
        if arch == "llama3-405b" and shape.kind == "train" and multi_pod:
            cfg = cfg.with_(grad_accum=8)
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    mesh_name = "pod2x256" if multi_pod else "pod256"

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.size
    model = build_model(cfg)
    rules = axis_rules(cfg)

    with mesh_context(mesh, cfg):
        if shape.kind == "train":
            oc = OptimizerConfig(name=cfg.optimizer, state_dtype=cfg.opt_state_dtype)
            p_specs = model.pspecs(mesh)
            p_sds = _sds(model.shapes(), p_specs, mesh)
            opt_shapes = jax.eval_shape(lambda p: init_opt_state(p, oc), p_sds)
            o_specs = _opt_pspecs(p_specs, opt_shapes, oc)
            o_sds = _sds(opt_shapes, o_specs, mesh)
            batch_sds = model.input_specs(shape, mesh)
            step = make_train_step(model, oc, mesh)
            jitted = jax.jit(step, donate_argnums=(0, 1))
            lowered = jitted.lower(p_sds, o_sds, batch_sds)
        elif shape.kind == "prefill":
            p_specs = model.pspecs(mesh)
            p_sds = _sds(model.shapes(), p_specs, mesh)
            batch_sds = model.input_specs(shape, mesh)

            def prefill_step(params, batch):
                logits, _ = model.forward(params, batch, last_only=True)
                return jnp.argmax(logits[:, -1], axis=-1)

            lowered = jax.jit(prefill_step).lower(p_sds, batch_sds)
        else:  # decode
            p_specs = model.pspecs(mesh)
            p_sds = _sds(model.shapes(), p_specs, mesh)
            cache_shapes = model.cache_shapes(shape.global_batch, shape.seq_len)
            cache_specs = model.cache_pspecs(mesh, shape.global_batch, shape.seq_len)
            c_sds = _sds(cache_shapes, cache_specs, mesh)
            io_sds = model.input_specs(shape, mesh)
            serve = make_dryrun_serve_step(model)
            jitted = jax.jit(serve, donate_argnums=(1,))
            lowered = jitted.lower(p_sds, c_sds, io_sds["tokens"], io_sds["lengths"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    pod_size = 256 if multi_pod else None
    coll = parse_collectives(hlo, n_devices, pod_size=pod_size)

    # trip-count-corrected flops/bytes/wire (see _analysis_cost docstring)
    corrected = _analysis_cost(cfg, shape, mesh, multi_pod)
    coll.wire_bytes = corrected["wire_bytes"]
    coll.cross_pod_wire_bytes = corrected["cross_pod_wire_bytes"]

    peak_bytes = int(
        mem.argument_size_in_bytes + mem.temp_size_in_bytes
        + mem.output_size_in_bytes - mem.alias_size_in_bytes
    )
    report = RooflineReport.build(
        arch, shape_name, mesh_name, n_devices, corrected, peak_bytes, coll,
        model_flops(cfg, shape),
    ).to_dict()
    report.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        argument_bytes=int(mem.argument_size_in_bytes),
        temp_bytes=int(mem.temp_size_in_bytes),
        output_bytes=int(mem.output_size_in_bytes),
        alias_bytes=int(mem.alias_size_in_bytes),
        cross_pod_wire_bytes=coll.cross_pod_wire_bytes,
        scanned_compile_flops=float(_cost_of(compiled).get("flops", 0.0)),
        extrap_points=corrected["points"],
    )
    if verbose:
        gb = peak_bytes / 2**30
        print(f"[{arch} x {shape_name} x {mesh_name}] OK "
              f"compile={t_compile:.0f}s peak={gb:.2f}GiB/dev "
              f"compute={report['compute_s']:.3f}s memory={report['memory_s']:.3f}s "
              f"collective={report['collective_s']:.3f}s -> {report['bottleneck']}-bound "
              f"useful={report['useful_flops_ratio']:.2f}", flush=True)
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--override", default=None, help="JSON dict of ModelConfig overrides")
    ap.add_argument("--opt", action="store_true", help="apply post-hillclimb per-shape policies")
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    os.makedirs(args.out, exist_ok=True)
    overrides = json.loads(args.override) if args.override else None
    failures = 0
    for arch, shape_name, multi in cells:
        tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
        if overrides:
            tag += "__" + "-".join(f"{k}={v}" for k, v in sorted(overrides.items()))
        out_path = os.path.join(args.out, tag + ".json")
        if os.path.exists(out_path) and not overrides:
            print(f"[{tag}] cached", flush=True)
            continue
        try:
            report = lower_cell(arch, shape_name, multi, cfg_overrides=overrides, opt=args.opt)
        except Exception as exc:  # noqa: BLE001
            failures += 1
            report = {"arch": arch, "shape": shape_name,
                      "mesh": "pod2x256" if multi else "pod256",
                      "status": "error", "error": f"{type(exc).__name__}: {exc}",
                      "traceback": traceback.format_exc()[-2000:]}
            print(f"[{tag}] FAILED: {exc}", flush=True)
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2, default=str)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
