"""Render the dry-run/roofline JSON cells into the EXPERIMENTS.md tables."""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List


def load_cells(directory: str) -> List[dict]:
    cells = []
    for name in sorted(os.listdir(directory)):
        if name.endswith(".json"):
            with open(os.path.join(directory, name)) as f:
                r = json.load(f)
            r["_file"] = name
            cells.append(r)
    return cells


def fmt_bytes(n) -> str:
    return f"{n / 2**30:.2f}"


def roofline_table(cells: List[dict], mesh: str = "pod256") -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "peak GiB/dev | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in cells:
        if r.get("mesh") != mesh and r.get("status") != "skipped":
            continue
        if r.get("status") == "skipped":
            if mesh == "pod256" and "single" in r["_file"]:
                rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                            f"skipped | — | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['bottleneck']} | "
            f"{fmt_bytes(r['peak_memory_bytes'])} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} |"
        )
    return "\n".join(rows)


def dryrun_table(cells: List[dict]) -> str:
    rows = [
        "| arch | shape | mesh | status | compile s | args GiB | temp GiB | collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in cells:
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped (sub-quadratic rule) | | | | |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} | ERROR | | | | |")
            continue
        colls = ", ".join(f"{k}:{v}" for k, v in sorted(r.get("collective_counts", {}).items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {r['compile_s']} | "
            f"{fmt_bytes(r['argument_bytes'])} | {fmt_bytes(r['temp_bytes'])} | {colls} |"
        )
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--table", choices=["roofline", "dryrun"], default="roofline")
    ap.add_argument("--mesh", default="pod256")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    if args.table == "roofline":
        print(roofline_table(cells, args.mesh))
    else:
        print(dryrun_table(cells))


if __name__ == "__main__":
    main()
