"""End-to-end training driver: Colmena-steered LM training.

The Thinker steers a training campaign the way the paper steers
simulation campaigns: the unit task is a *chunk* of K optimizer steps
executed by a stateful worker (params/optimizer live in the worker
registry — the paper's "intelligent initialization"); the steering
agents monitor the loss stream, trigger asynchronous checkpoints,
early-stop on plateau, and recover from (optionally injected) worker
preemptions by restoring from the latest checkpoint.

CPU-sized by default (a few-M-param model); ``--scale`` raises width
toward the ~100M end-to-end config for real hardware.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 120 \
      --preempt-at 50 --ckpt-dir /tmp/ckpt     # survives a mid-run kill
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import numpy as np

from ..configs import get_config, smoke_config
from ..core import (
    BaseThinker,
    FailureInjector,
    LocalColmenaQueues,
    ResourceRequest,
    RetryPolicy,
    TaskServer,
    WorkerPool,
    agent,
    result_processor,
    stateful_task,
)
from ..core.thinker import ResourceCounter


def train_config(arch: str, scale: int = 1, seq: int = 64):
    cfg = smoke_config(arch).with_(
        dtype="float32",
        d_model=64 * scale,
        n_heads=4 * scale if 64 * scale % (4 * scale) == 0 else 4,
        head_dim=16,
        d_ff=128 * scale,
        vocab_size=2048,
        grad_accum=1,
    )
    return cfg


@stateful_task
def train_chunk(arch: str, scale: int, start_step: int, k: int, seq: int,
                batch: int, lr: float, ckpt_dir: Optional[str] = None,
                registry: Optional[dict] = None) -> Dict[str, Any]:
    """Run K optimizer steps; worker registry caches the full train state."""
    import jax

    from ..models import build_model
    from ..train import (CheckpointManager, OptimizerConfig, SyntheticLM,
                         init_train_state, make_train_step)

    state = registry.get("train_state")
    if state is None or state["arch"] != arch:
        cfg = train_config(arch, scale, seq)
        model = build_model(cfg)
        oc = OptimizerConfig(lr=lr, warmup_steps=20, total_steps=10_000)
        ck = CheckpointManager(ckpt_dir) if ckpt_dir else None
        params = opt = None
        resume_step = 0
        if ck and ck.latest_step() is not None:
            # fault recovery: restore the newest checkpoint
            params, opt = init_train_state(model, oc, jax.random.PRNGKey(0))
            restored, extra = ck.restore(ck.latest_step(), {"p": params, "o": opt})
            params, opt = restored["p"], restored["o"]
            resume_step = int(extra.get("step", ck.latest_step()))
        else:
            params, opt = init_train_state(model, oc, jax.random.PRNGKey(0))
        state = registry["train_state"] = {
            "arch": arch,
            "cfg": cfg,
            "model": model,
            "params": params,
            "opt": opt,
            "step_fn": jax.jit(make_train_step(model, oc)),
            "data": SyntheticLM(cfg, seq_len=seq, batch=batch),
            "ck": ck,
            "step": resume_step,
        }

    import jax.numpy as jnp

    losses = []
    t0 = time.monotonic()
    for _ in range(k):
        b = {kk: jnp.asarray(v) for kk, v in state["data"].batch_at(state["step"]).items()}
        state["params"], state["opt"], metrics = state["step_fn"](state["params"], state["opt"], b)
        state["step"] += 1
        losses.append(float(metrics["loss"]))
    return {
        "start_step": state["step"] - k,
        "end_step": state["step"],
        "losses": losses,
        "steps_per_s": k / (time.monotonic() - t0),
    }


@stateful_task
def save_checkpoint(registry: Optional[dict] = None) -> Dict[str, Any]:
    """Async sharded checkpoint of the worker-resident train state."""
    state = registry.get("train_state")
    if state is None or state["ck"] is None:
        return {"saved": False}
    state["ck"].save_async(state["step"], {"p": state["params"], "o": state["opt"]},
                           extra={"step": state["step"]})
    return {"saved": True, "step": state["step"]}


class TrainingThinker(BaseThinker):
    """Steers the campaign: chunk submission, loss tracking, checkpoint
    cadence, plateau early-stop."""

    def __init__(self, queues, *, arch: str, scale: int, total_steps: int,
                 chunk: int, seq: int, batch: int, lr: float,
                 ckpt_dir: Optional[str], ckpt_every: int,
                 preempt_at: Optional[int] = None, server=None):
        super().__init__(queues, ResourceCounter(1))
        self.arch, self.scale = arch, scale
        self.total_steps, self.chunk = total_steps, chunk
        self.seq, self.batch, self.lr = seq, batch, lr
        self.ckpt_dir, self.ckpt_every = ckpt_dir, ckpt_every
        self.preempt_at = preempt_at
        self.server = server
        self.losses: List[float] = []
        self.next_step = 0
        self.last_ckpt = 0
        self.preempted = False

    def _submit_chunk(self):
        k = min(self.chunk, self.total_steps - self.next_step)
        self.queues.send_inputs(
            self.arch, self.scale, self.next_step, k, self.seq, self.batch,
            self.lr, self.ckpt_dir,
            method="train_chunk", topic="default",
            resources=ResourceRequest(pool="default"),
        )

    @agent(startup=True)
    def kickoff(self):
        self._submit_chunk()

    @result_processor()
    def on_chunk(self, result):
        if result.method == "save_checkpoint":
            return
        if not result.success:
            self.logger.warning("chunk failed (%s); resubmitting", result.failure_info)
            self._submit_chunk()
            return
        out = result.value
        self.losses.extend(out["losses"])
        self.next_step = out["end_step"]

        # simulated preemption: kill the training node mid-campaign once
        if (self.preempt_at is not None and not self.preempted
                and self.next_step >= self.preempt_at):
            self.preempted = True
            pool = self.server.pools["default"]
            for w in pool.worker_states():
                pool.kill_worker(w.worker_id)
            self.logger.warning("injected preemption at step %d", self.next_step)

        if self.ckpt_dir and self.next_step - self.last_ckpt >= self.ckpt_every:
            self.last_ckpt = self.next_step
            self.queues.send_inputs(method="save_checkpoint")

        if self.next_step >= self.total_steps:
            self.done.set()
            return
        self._submit_chunk()


def run(arch: str = "gemma-2b", steps: int = 100, chunk: int = 10, scale: int = 1,
        seq: int = 64, batch: int = 8, lr: float = 3e-3,
        ckpt_dir: Optional[str] = None, ckpt_every: int = 40,
        preempt_at: Optional[int] = None) -> Dict[str, Any]:
    queues = LocalColmenaQueues()
    server = TaskServer(
        queues,
        {"train_chunk": train_chunk, "save_checkpoint": save_checkpoint},
        n_workers=1,
        retry=RetryPolicy(max_retries=4),
        heartbeat_timeout_s=2.0,
        straggler=None,
    )
    thinker = TrainingThinker(
        queues, arch=arch, scale=scale, total_steps=steps, chunk=chunk,
        seq=seq, batch=batch, lr=lr, ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
        preempt_at=preempt_at, server=server,
    )
    server.start()
    t0 = time.monotonic()
    thinker.run(timeout=3600)
    wall = time.monotonic() - t0
    server.stop()
    losses = thinker.losses
    return {
        "arch": arch,
        "steps": len(losses),
        "first_loss": losses[0] if losses else None,
        "final_loss": float(np.mean(losses[-10:])) if losses else None,
        "wall_s": wall,
        "preempted": thinker.preempted,
        "workers_replaced": server.metrics.workers_replaced,
        "tasks_retried": server.metrics.tasks_retried,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--chunk", type=int, default=10)
    ap.add_argument("--scale", type=int, default=1, help="width multiplier (4 ~= 100M params)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=40)
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="inject a node failure at this step (tests recovery)")
    args = ap.parse_args()
    report = run(arch=args.arch, steps=args.steps, chunk=args.chunk, scale=args.scale,
                 seq=args.seq, batch=args.batch, lr=args.lr, ckpt_dir=args.ckpt_dir,
                 ckpt_every=args.ckpt_every, preempt_at=args.preempt_at)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
