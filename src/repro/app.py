"""repro.app — the public face of the declarative composition layer.

``AppSpec`` declares a whole Colmena application (task registry, queue
backend, worker-pool specs, data fabric, observe, steering, campaign
persistence); ``ColmenaApp`` composes and runs it. Specs serialize to
TOML/JSON campaign files (``AppSpec.save``/``AppSpec.load``,
``repro.core.specfile``) and this module doubles as the launch CLI::

    python -m repro.app run campaign.toml [--smoke] [--fresh]
    python -m repro.app show campaign.toml

See ``repro.core.app`` for the implementation and the README quickstart
for usage; the low-level constructors in ``repro.core`` remain supported
underneath.
"""

from repro.core.app import (
    AppSpec,
    CampaignSpec,
    ColmenaApp,
    FabricSpec,
    ObserveSpec,
    PoolSpec,
    ProcessTaskServer,
    QueueSpec,
    ServerSpec,
    SteeringSpec,
    TaskDef,
    task,
)
from repro.core.specfile import load_spec, save_spec, spec_from_dict, spec_to_dict

__all__ = [
    "AppSpec",
    "CampaignSpec",
    "ColmenaApp",
    "FabricSpec",
    "ObserveSpec",
    "PoolSpec",
    "ProcessTaskServer",
    "QueueSpec",
    "ServerSpec",
    "SteeringSpec",
    "TaskDef",
    "load_spec",
    "save_spec",
    "spec_from_dict",
    "spec_to_dict",
    "task",
]


if __name__ == "__main__":
    import sys

    from repro.core.specfile import main

    sys.exit(main())
