"""repro.app — the public face of the declarative composition layer.

``AppSpec`` declares a whole Colmena application (task registry, queue
backend, data fabric, observe, steering, campaign persistence);
``ColmenaApp`` composes and runs it. See ``repro.core.app`` for the
implementation and the README quickstart for usage; the low-level
constructors in ``repro.core`` remain supported underneath.
"""

from repro.core.app import (
    AppSpec,
    CampaignSpec,
    ColmenaApp,
    FabricSpec,
    ObserveSpec,
    ProcessTaskServer,
    QueueSpec,
    ServerSpec,
    SteeringSpec,
    TaskDef,
    task,
)

__all__ = [
    "AppSpec",
    "CampaignSpec",
    "ColmenaApp",
    "FabricSpec",
    "ObserveSpec",
    "ProcessTaskServer",
    "QueueSpec",
    "ServerSpec",
    "SteeringSpec",
    "TaskDef",
    "task",
]
