"""ActiveLearningThinker: the online train -> infer -> reprioritize loop.

This is the steering pattern the paper's Fig. 2 campaign runs: simulate
continuously; once enough new results land, shift worker slots to the
training pool, retrain the surrogate ensemble on everything observed,
re-rank the candidate queue with an acquisition policy, and shift the
slots back. Built on ``repro.core.steering.BatchRetrainThinker`` — the
base class supplies the simulate/drain/finish machinery; this class owns
the retrain-agent lifecycle:

  * **resource shift** — ``ResourceCounter.reallocate("simulate", "ml")``
    for the duration of each retrain (and back after), emitted as
    ``realloc`` events so utilization reports integrate the move;
  * **online ensemble retrain** — ``DeepEnsemble.fit(..., warm_start=
    True)``, a short jitted continuation, run inside the responder while
    the shifted slots are held;
  * **re-ranking** — the acquisition policy jointly selects the next
    batch of candidates from the ensemble's (mean, std) over the
    unvisited pool;
  * **telemetry** — ``surrogate_event``s (retrain rmse/cadence, rerank
    regret) land in the same ``repro.observe`` log as task lifecycles,
    so one report shows compute utilization *and* steering quality;
  * **checkpointability** — ``get_state``/``set_state`` round-trip the
    observed data, queue position, and full ensemble state through
    ``repro.core.Campaign`` checkpoints, so a killed campaign resumes
    from its last retrain instead of from scratch.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.queues import ColmenaQueues
from repro.core.result import ResourceRequest
from repro.core.steering import BatchRetrainThinker
from repro.core.thinker import event_responder, task_submitter

from .acquisition import AcquisitionPolicy
from .ensemble import DeepEnsemble, EnsembleConfig, _pad_pow2


def adaptive_retrain_after(
    current: int,
    duration_s: float,
    throughput_tps: float,
    budget: float,
    lo: int = 4,
    hi: int = 4096,
) -> int:
    """Retrain cadence (results between retrains) that pins the fraction
    of wall time spent training at ``budget``.

    With retrains costing ``duration_s`` and simulations landing at
    ``throughput_tps``, one train/simulate cycle spends
    ``duration_s / (duration_s + cadence/throughput)`` of its wall time
    training; solving that for ``budget`` gives
    ``cadence = duration_s * throughput * (1 - budget) / budget``.
    Invalid observations (no throughput yet, instant retrain) keep the
    current cadence; the result is clamped to ``[lo, hi]``.
    """
    if not (0.0 < budget < 1.0) or duration_s <= 0.0 or throughput_tps <= 0.0:
        return current
    target = duration_s * throughput_tps * (1.0 - budget) / budget
    return max(lo, min(hi, int(round(target)) or lo))


class ActiveLearningThinker(BatchRetrainThinker):
    """Steer a fixed candidate pool with an online-retrained ensemble.

    Parameters beyond ``BatchRetrainThinker``'s:

    :param ensemble: the ``DeepEnsemble`` retrained online.
    :param policy: acquisition policy ranking unvisited candidates.
    :param candidates: [N, D] pool the campaign selects from.
    :param train_slots: simulate-slots shifted to the ``ml`` pool for
        the duration of each retrain (the paper's node shift).
    :param select_horizon: batch size of each joint re-rank (defaults to
        2x ``retrain_after`` so the queue never starves between retrains).
    :param optimum_value: optional known/approximate optimum, enabling
        acquisition-regret telemetry.
    :param retrain_budget: optional target fraction (0, 1) of wall time
        spent retraining; when set, ``retrain_after`` adapts after every
        retrain from its observed cost vs. simulate throughput
        (``adaptive_retrain_after``), and the observed fraction is
        gauged as ``retrain_budget``. ``None`` keeps the fixed cadence.
    :param stream_dir: when set, campaign checkpoints stream the
        ensemble's ``state_dict`` as asynchronous delta steps into this
        directory (``EnsembleStreamCheckpointer``) and the pickle
        carries only a small marker; ``None`` keeps the full-pickle
        inline format. ``set_state`` accepts both formats.
    """

    def __init__(
        self,
        queues: ColmenaQueues,
        *,
        ensemble: DeepEnsemble,
        policy: AcquisitionPolicy,
        candidates: np.ndarray,
        n_slots: int,
        retrain_after: int,
        max_results: Optional[int] = None,
        simulate_method: str = "simulate",
        ml_slots: int = 1,
        train_slots: int = 1,
        select_horizon: Optional[int] = None,
        optimum_value: Optional[float] = None,
        retrain_budget: Optional[float] = None,
        stream_dir: Optional[str] = None,
        seed: int = 0,
    ) -> None:
        super().__init__(
            queues,
            n_slots=n_slots,
            retrain_after=retrain_after,
            simulate_method=simulate_method,
            ml_slots=ml_slots,
            max_results=max_results,
        )
        self.ensemble = ensemble
        self.policy = policy
        self.candidates = np.asarray(candidates, np.float32)
        self.train_slots = train_slots
        self.select_horizon = select_horizon or 2 * retrain_after
        self.optimum_value = optimum_value
        if retrain_budget is not None and not (0.0 < retrain_budget < 1.0):
            raise ValueError(f"retrain_budget must be in (0, 1), got {retrain_budget}")
        self.retrain_budget = retrain_budget
        self.stream_dir = stream_dir
        self._stream = None
        if stream_dir is not None:
            from .stream import EnsembleStreamCheckpointer

            self._stream = EnsembleStreamCheckpointer(stream_dir)
        self._first_result_t: Optional[float] = None
        self._train_seconds = 0.0
        self._rng = np.random.default_rng(seed)
        self._al_lock = threading.Lock()
        self._visited: set = set()
        self._selected: "deque[int]" = deque()
        self._X: List[np.ndarray] = []
        self._y: List[float] = []
        self._best: float = -np.inf

    # ---------------------------------------------------------------- helpers
    def _event_log(self) -> Optional[Any]:
        return getattr(self.queues, "event_log", None)

    @property
    def best_observed(self) -> float:
        with self._al_lock:
            return self._best

    @property
    def observed(self) -> Tuple[np.ndarray, np.ndarray]:
        with self._al_lock:
            if not self._y:
                return np.empty((0, self.candidates.shape[1])), np.empty((0,))
            return np.stack(self._X), np.asarray(self._y)

    def _next_index(self) -> Optional[int]:
        """Highest-priority unvisited candidate: the re-ranked queue
        first, a uniform-random unvisited fallback before the first
        retrain (or when the queue drains)."""
        with self._al_lock:
            while self._selected:
                idx = self._selected.popleft()
                if idx not in self._visited:
                    self._visited.add(idx)
                    return idx
            unvisited = np.setdiff1d(
                np.arange(len(self.candidates)), np.fromiter(self._visited, int, len(self._visited)),
            )
            if not len(unvisited):
                return None
            idx = int(self._rng.choice(unvisited))
            self._visited.add(idx)
            return idx

    # ------------------------------------------------------------------ hooks
    @task_submitter(task_type="simulate", n_slots=1)
    def submit_simulation(self) -> None:
        """Base-class submitter plus candidate-pool exhaustion: when every
        candidate has been visited, drain instead of submitting junk."""
        if self._drain.is_set():
            self.rec.release("simulate", 1)
            self.done.wait()
            return
        idx = self._next_index()
        if idx is None:  # pool exhausted: stop feeding, let ML finish
            self.rec.release("simulate", 1)
            self._drain.set()
            self._maybe_finish()
            self.done.wait()
            return
        self.queues.send_inputs(
            self.candidates[idx], int(self._rng.integers(1 << 31)),
            method=self.simulate_method, topic="simulate",
            resources=ResourceRequest(pool="simulate"),
        )

    def on_simulation(self, result) -> None:
        x = np.asarray(result.args[0], np.float32)
        y = float(result.value)
        with self._al_lock:
            if self._first_result_t is None:
                self._first_result_t = time.monotonic()
            self._X.append(x)
            self._y.append(y)
            self._best = max(self._best, y)

    # ------------------------------------------------------------ retrain agent
    def make_train_task(self):  # pragma: no cover - retraining is in-agent
        raise NotImplementedError("ActiveLearningThinker retrains in-agent")

    @event_responder(event_name="retrain")
    def run_training(self) -> None:
        """Shift slots to the training pool, retrain, re-rank, shift back."""
        if self.done.is_set():
            return
        log = self._event_log()
        # Attach the log to the ensemble so fit/predict emit ``profile``
        # spans (wall + device time) alongside the surrogate events.
        self.ensemble.event_log = log
        moved = False
        if self.train_slots:
            moved = self.rec.reallocate(
                "simulate", "ml", self.train_slots, stop_event=self.done)
            if moved and log is not None:
                log.realloc("simulate", "ml", self.train_slots, reason="retrain")
        t0 = time.monotonic()
        try:
            X, y = self.observed
            if not len(y):
                return
            metrics = self.ensemble.fit(X, y, warm_start=True)
            self.train_rounds += 1
            duration = time.monotonic() - t0
            self._train_seconds += duration
            if log is not None:
                log.surrogate_event(
                    "retrain", value=metrics["rmse"], round=self.train_rounds,
                    n=metrics["n"], duration_s=round(duration, 6),
                )
            self._adapt_cadence(duration, len(y), log)
            self._rerank(log)
        finally:
            if moved:
                self.rec.reallocate("ml", "simulate", self.train_slots,
                                    stop_event=self.done)
                if log is not None:
                    log.realloc("ml", "simulate", self.train_slots,
                                reason="retrain_done")

    def _adapt_cadence(self, duration_s: float, n_results: int,
                       log: Optional[Any]) -> None:
        """Budget-aware cadence: after each retrain, re-derive
        ``retrain_after`` from the observed retrain cost and simulate
        throughput so training stays near its wall-time budget."""
        if self.retrain_budget is None:
            return
        with self._al_lock:
            first_t = self._first_result_t
        elapsed = time.monotonic() - first_t if first_t is not None else 0.0
        throughput = n_results / elapsed if elapsed > 0 else 0.0
        self.retrain_after = adaptive_retrain_after(
            self.retrain_after, duration_s, throughput, self.retrain_budget)
        if log is not None and elapsed > 0:
            log.gauge("retrain_budget", self._train_seconds / elapsed)
            log.gauge("retrain_after", float(self.retrain_after))

    def _rerank(self, log: Optional[Any] = None) -> None:
        """Jointly select the next batch of candidates from the freshly
        retrained ensemble's (mean, std). The predict always covers the
        full (fixed-shape) pool — one compile for the whole campaign —
        and visited candidates are excluded at selection time."""
        with self._al_lock:
            visited = set(self._visited)
            best = self._best
        k = min(self.select_horizon, len(self.candidates) - len(visited))
        if k <= 0:
            return
        members = self.ensemble.predict_members(self.candidates)
        mean, std = members.mean(axis=0), members.std(axis=0) + 1e-9
        ranked = self.policy.select(
            k, mean, std, best_f=best, rng=self._rng, members=members,
            exclude=visited, X=self.candidates)
        with self._al_lock:
            self._selected = deque(ranked)
        if log is not None:
            regret = (
                self.optimum_value - best
                if self.optimum_value is not None and np.isfinite(best) else None
            )
            log.surrogate_event(
                "rerank", value=regret, policy=self.policy.name, k=len(ranked))

    # ------------------------------------------------------------- checkpoint
    def get_state(self) -> Dict[str, Any]:
        """Campaign-checkpoint payload: everything needed to resume from
        the last retrain (observed data, queue position, ensemble)."""
        with self._al_lock, self._state_lock:
            state = {
                "X": [np.asarray(x) for x in self._X],
                "y": list(self._y),
                "best": self._best,
                "visited": sorted(self._visited),
                "selected": list(self._selected),
                "train_rounds": self.train_rounds,
                "new_since_train": self._new_since_train,
                "total": self._total,
                "retrain_after": self.retrain_after,
                "train_seconds": self._train_seconds,
                "rng": self._rng.bit_generator.state,
            }
            if self._stream is not None:
                # Stream the (large) ensemble state as an async delta
                # step; the pickle carries only a pointer to it.
                step = self._stream.save(self.ensemble)
                state["ensemble_stream"] = {"dir": self.stream_dir, "step": step}
            else:
                state["ensemble"] = self.ensemble.state_dict()
            return state

    def set_state(self, state: Dict[str, Any]) -> None:
        if not state:
            return
        with self._al_lock, self._state_lock:
            self._X = [np.asarray(x) for x in state["X"]]
            self._y = list(state["y"])
            self._best = state["best"]
            self._visited = set(state["visited"])
            self._selected = deque(state["selected"])
            self.train_rounds = state["train_rounds"]
            self._new_since_train = state["new_since_train"]
            self._total = state["total"]
            # Adapted cadence survives resume (older checkpoints lack it).
            self.retrain_after = state.get("retrain_after", self.retrain_after)
            self._train_seconds = state.get("train_seconds", self._train_seconds)
            self._rng.bit_generator.state = state["rng"]
        if "ensemble" in state:
            self.ensemble.load_state_dict(state["ensemble"])
        elif "ensemble_stream" in state:
            from .stream import EnsembleStreamCheckpointer

            marker = state["ensemble_stream"]
            stream = self._stream
            if stream is None or self.stream_dir != marker["dir"]:
                stream = EnsembleStreamCheckpointer(marker["dir"])
            # Walks back from the marker step when its async write never
            # landed (e.g. SIGKILL between pickle publish and npz flush).
            self.ensemble.load_state_dict(stream.restore(marker["step"]))


# --------------------------------------------------------------------------
# One-call campaign runner (benchmarks, examples, tests)
# --------------------------------------------------------------------------


def campaign_ensemble_config(budget: int, **overrides) -> EnsembleConfig:
    """The ensemble config ``run_active_campaign`` defaults to for a
    given budget: ``pad_to`` = the budget's power of two, so every
    retrain in the campaign (and every campaign in a same-budget sweep)
    shares one compiled fit/predict shape. Warmup callers use this same
    helper so pre-compiled shapes can never drift from the campaign's."""
    return EnsembleConfig(pad_to=_pad_pow2(budget), **overrides)


def run_active_campaign(
    scenario,
    policy: AcquisitionPolicy,
    budget: int = 48,
    *,
    n_slots: int = 4,
    retrain_after: Optional[int] = None,
    retrain_budget: Optional[float] = None,
    n_candidates: int = 512,
    seed: int = 0,
    ensemble: Optional[DeepEnsemble] = None,
    event_log: Optional[Any] = None,
    sim_sleep_s: float = 0.0,
    timeout: float = 300.0,
    state_dir: Optional[str] = None,
) -> Dict[str, Any]:
    """Run one surrogate-steered campaign over a ``Scenario``.

    ``sim_sleep_s`` paces each simulation (the paper's tasks are
    minutes-long; a few ms here lets retrains interleave with the
    simulate stream instead of racing a sub-ms landscape evaluation).
    Returns hits (candidates whose *noiseless* value clears the
    scenario threshold), the best observation, retrain count, and the
    observe report (with its surrogate section).

    A thin wrapper over ``repro.app``: the whole stack (queues, worker
    pools, task server, telemetry, steering) is composed from one
    ``AppSpec``; ``state_dir`` adds campaign checkpoints + resume.
    """
    from repro.core.app import (
        AppSpec, CampaignSpec, ColmenaApp, ObserveSpec, QueueSpec, SteeringSpec, TaskDef,
    )

    rng = np.random.default_rng(seed)
    candidates = scenario.sample(rng, n_candidates)
    ens = ensemble or DeepEnsemble(
        scenario.dim, campaign_ensemble_config(budget), seed=seed)

    def simulate(x, seed=0):
        if sim_sleep_s:
            time.sleep(sim_sleep_s)
        return scenario.evaluate(x, seed)

    app = ColmenaApp(AppSpec(
        tasks=[TaskDef(fn=simulate, method="simulate", pool="simulate")],
        queues=QueueSpec(topics=("simulate", "train")),
        pools={"simulate": max(n_slots - 1, 1), "ml": 1, "default": 1},
        observe=ObserveSpec(log=event_log),
        steering=SteeringSpec(ActiveLearningThinker, dict(
            ensemble=ens,
            policy=policy,
            candidates=candidates,
            n_slots=n_slots,
            retrain_after=retrain_after or max(8, budget // 5),
            retrain_budget=retrain_budget,
            max_results=budget,
            ml_slots=1,
            optimum_value=scenario.optimum_value,
            seed=seed,
        )),
        campaign=CampaignSpec(state_dir=state_dir) if state_dir else None,
    ))
    app.execute(timeout=timeout)
    thinker = app.thinker

    X, y = thinker.observed
    # In-flight overshoot can deliver a result or two past max_results;
    # score exactly ``budget`` observations so policy comparisons are fair.
    X, y = X[:budget], y[:budget]
    hits = int(sum(scenario.true_value(x) > scenario.threshold for x in X))
    report = app.observe_report()
    return {
        "scenario": scenario.name,
        "policy": policy.name,
        "hits": hits,
        "n": len(y),
        "best": float(y.max()) if len(y) else float("-inf"),
        "retrains": thinker.train_rounds,
        "report": report,
        "thinker": thinker,
    }


__all__ = [
    "ActiveLearningThinker",
    "adaptive_retrain_after",
    "campaign_ensemble_config",
    "run_active_campaign",
]
