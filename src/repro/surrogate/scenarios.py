"""Synthetic optimization landscapes for surrogate-steering benchmarks.

A ``Scenario`` bundles everything a steering benchmark sweeps over: a
bounded search domain, an (optionally noisy) expensive ``evaluate``
standing in for the simulation task, the noiseless ``true_value`` used
for scoring, and a calibrated high-performer ``threshold``.

Thresholds are set by quantile over a large seeded uniform sample, so
"high performer" means the same thing (top ``1 - quantile`` fraction of
the domain) across otherwise wildly different landscapes, and an
unsteered random search has the same expected hit-rate everywhere —
steering gain is then directly comparable across scenarios, as in the
paper's +20%-more-top-molecules framing.

The four stock landscapes cover the failure modes that separate
acquisition policies:

  * ``quadratic``        — separable smooth bowl; pure exploitation wins.
  * ``multimodal``       — sinusoid over an envelope; many local optima,
                           exploration must escape them.
  * ``needle``           — deceptive: the broad slope points *away* from
                           a narrow needle of mass; greedy gets trapped.
  * ``heteroscedastic``  — noisy observations whose noise grows away
                           from the optimum; robustness to label noise.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Protocol, runtime_checkable

import numpy as np

__all__ = [
    "DeceptiveNeedle",
    "Heteroscedastic",
    "make_scenario",
    "MultimodalSinusoid",
    "Scenario",
    "SCENARIOS",
    "SeparableQuadratic",
    "SyntheticScenario",
]


@runtime_checkable
class Scenario(Protocol):
    """Protocol every steering benchmark sweeps over."""

    name: str
    dim: int
    lo: float
    hi: float
    threshold: float      # true_value above this = "high performer"
    optimum_value: float  # (approximate) max of true_value on the domain

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Draw n candidate points, shape [n, dim]."""
        ...

    def evaluate(self, x: np.ndarray, seed: int = 0) -> float:
        """The expensive simulation (may be noisy; seeded)."""
        ...

    def true_value(self, x: np.ndarray) -> float:
        """Noiseless objective, used for scoring hits."""
        ...


class SyntheticScenario:
    """Base: uniform box domain + quantile-calibrated threshold."""

    name = "synthetic"

    def __init__(self, dim: int = 4, lo: float = -1.0, hi: float = 1.0,
                 quantile: float = 0.92, calibration_n: int = 20_000) -> None:
        self.dim = dim
        self.lo = lo
        self.hi = hi
        rng = np.random.default_rng(12345)
        sample = self.sample(rng, calibration_n)
        vals = self.true_batch(sample)
        self.threshold = float(np.quantile(vals, quantile))
        self.optimum_value = float(vals.max())

    # ----------------------------------------------------------- domain
    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return rng.uniform(self.lo, self.hi, (n, self.dim))

    # -------------------------------------------------------- objective
    def true_batch(self, X: np.ndarray) -> np.ndarray:
        """Vectorized noiseless objective, [n, dim] -> [n]."""
        raise NotImplementedError

    def true_value(self, x: np.ndarray) -> float:
        return float(self.true_batch(np.asarray(x, float).reshape(1, -1))[0])

    def evaluate(self, x: np.ndarray, seed: int = 0) -> float:
        return self.true_value(x)


class SeparableQuadratic(SyntheticScenario):
    """Smooth separable bowl centered off-origin: the easy case."""

    name = "quadratic"

    def true_batch(self, X: np.ndarray) -> np.ndarray:
        return -((X - 0.3) ** 2).sum(axis=1)


class MultimodalSinusoid(SyntheticScenario):
    """Sinusoidal ripples over a quadratic envelope: many local maxima,
    one global basin near x = 0.2."""

    name = "multimodal"

    def true_batch(self, X: np.ndarray) -> np.ndarray:
        return np.sin(3.0 * X).sum(axis=1) - 0.7 * ((X - 0.2) ** 2).sum(axis=1)


class DeceptiveNeedle(SyntheticScenario):
    """A broad hill at one corner plus a taller, narrow Gaussian needle
    elsewhere; the global gradient leads away from the needle."""

    name = "needle"

    def true_batch(self, X: np.ndarray) -> np.ndarray:
        hill = -0.4 * ((X + 0.5) ** 2).sum(axis=1)
        needle = 3.0 * np.exp(-((X - 0.55) ** 2).sum(axis=1) / (2 * 0.18 ** 2))
        return hill + needle


class Heteroscedastic(SyntheticScenario):
    """Quadratic objective observed under state-dependent noise: the
    noise floor grows away from the optimum, so the surrogate must
    average out unreliable labels exactly where exploration happens."""

    name = "heteroscedastic"

    def true_batch(self, X: np.ndarray) -> np.ndarray:
        return -((X - 0.1) ** 2).sum(axis=1)

    def evaluate(self, x: np.ndarray, seed: int = 0) -> float:
        x = np.asarray(x, float).reshape(-1)
        sigma = 0.05 + 0.25 * np.abs(x - 0.1).mean()
        noise = np.random.default_rng(seed).normal(0.0, sigma)
        return self.true_value(x) + float(noise)


SCENARIOS: Dict[str, type] = {
    cls.name: cls
    for cls in (SeparableQuadratic, MultimodalSinusoid, DeceptiveNeedle, Heteroscedastic)
}


def make_scenario(name: str, dim: int = 4, **kwargs) -> SyntheticScenario:
    try:
        return SCENARIOS[name](dim=dim, **kwargs)
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}") from None
