"""Acquisition policies: turn surrogate (mean, std) into task choices.

Every policy answers the steering question "which k candidates should
the campaign simulate next?" given the ensemble's mean prediction and
epistemic std over a candidate pool. All policies are **batch-aware**:
``select`` returns ``k`` *distinct* candidate indices chosen jointly —
for the score-based policies that is top-k without replacement, for
Thompson sampling it is k independent posterior draws (each draw's
argmax), which spreads a batch across plausible optima instead of
hammering one point k times.

Policies (maximization convention — larger objective is better):

  * ``Greedy``               — pure exploitation: score = mean.
  * ``UCB(beta)``            — mean + beta * std.
  * ``ExpectedImprovement``  — analytic EI over the incumbent best.
  * ``Thompson``             — posterior-sample argmaxes (uses per-member
                               predictions when available, else a
                               Gaussian N(mean, std) draw).
  * ``EpsilonRandom(eps)``   — eps-mix of random and greedy; ``eps=1``
                               is the unsteered random-search baseline.

``make_policy(name)`` resolves the registry used by benchmark sweeps.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

__all__ = [
    "AcquisitionPolicy",
    "EpsilonRandom",
    "ExpectedImprovement",
    "Greedy",
    "KrigingBeliever",
    "make_policy",
    "POLICIES",
    "Thompson",
    "UCB",
]


def _topk_unique(scores: np.ndarray, k: int, exclude: Optional[set] = None) -> List[int]:
    """Indices of the k best scores, descending, skipping ``exclude``."""
    order = np.argsort(-scores, kind="stable")
    out: List[int] = []
    for i in order:
        if exclude and int(i) in exclude:
            continue
        out.append(int(i))
        if len(out) == k:
            break
    return out


class AcquisitionPolicy:
    """Base policy. Subclasses implement ``scores`` (vector of per-
    candidate desirabilities) or override ``select`` for joint logic."""

    name = "base"

    def scores(
        self,
        mean: np.ndarray,
        std: np.ndarray,
        *,
        best_f: float,
        rng: np.random.Generator,
        members: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        raise NotImplementedError

    def select(
        self,
        k: int,
        mean: np.ndarray,
        std: np.ndarray,
        *,
        best_f: float = -math.inf,
        rng: Optional[np.random.Generator] = None,
        members: Optional[np.ndarray] = None,
        exclude: Optional[set] = None,
        X: Optional[np.ndarray] = None,
    ) -> List[int]:
        """Jointly pick ``k`` distinct candidate indices. ``X`` (the
        candidate coordinates) is advisory — only geometry-aware
        policies (``KrigingBeliever``) read it."""
        rng = rng or np.random.default_rng()
        s = self.scores(np.asarray(mean), np.asarray(std),
                        best_f=best_f, rng=rng, members=members)
        return _topk_unique(s, k, exclude)


class Greedy(AcquisitionPolicy):
    name = "greedy"

    def scores(self, mean, std, *, best_f, rng, members=None):
        return mean


class UCB(AcquisitionPolicy):
    """Upper confidence bound: optimism proportional to uncertainty."""

    name = "ucb"

    def __init__(self, beta: float = 2.0) -> None:
        self.beta = float(beta)

    def scores(self, mean, std, *, best_f, rng, members=None):
        return mean + self.beta * std


def _norm_pdf(z: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    # erf-based CDF; vectorized without scipy.
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / math.sqrt(2.0)))


class ExpectedImprovement(AcquisitionPolicy):
    """Analytic EI against the incumbent ``best_f``.

    The std -> 0 limit is ``max(mean - best_f - xi, 0)``, so a
    zero-uncertainty prediction at the incumbent scores exactly 0.
    """

    name = "ei"

    def __init__(self, xi: float = 0.0) -> None:
        self.xi = float(xi)

    def scores(self, mean, std, *, best_f, rng, members=None):
        if not np.isfinite(best_f):  # no incumbent yet: EI reduces to mean
            return mean
        impr = mean - best_f - self.xi
        out = np.maximum(impr, 0.0)
        pos = std > 0
        if np.any(pos):
            z = impr[pos] / std[pos]
            out = out.astype(float)
            out[pos] = impr[pos] * _norm_cdf(z) + std[pos] * _norm_pdf(z)
        return out


class Thompson(AcquisitionPolicy):
    """Batch Thompson sampling: one posterior draw per batch slot.

    Each of the ``k`` slots draws an independent function sample — a
    randomly chosen ensemble member's prediction vector when ``members``
    is provided, otherwise an independent N(mean, std) draw — and takes
    its argmax among not-yet-selected candidates. Repeated draws that
    agree fall through to their next-best candidate, so the batch stays
    distinct while concentration still reflects posterior confidence.
    """

    name = "thompson"

    def scores(self, mean, std, *, best_f, rng, members=None):
        if members is not None and len(members):
            return members[rng.integers(len(members))]
        return rng.normal(mean, std)

    def select(self, k, mean, std, *, best_f=-math.inf, rng=None,
               members=None, exclude=None, X=None):
        rng = rng or np.random.default_rng()
        mean = np.asarray(mean)
        std = np.asarray(std)
        chosen: List[int] = []
        taken = set(exclude or ())
        for _ in range(min(k, mean.shape[0] - len(taken))):
            draw = self.scores(mean, std, best_f=best_f, rng=rng, members=members)
            idx = _topk_unique(draw, 1, taken)
            if not idx:
                break
            chosen.append(idx[0])
            taken.add(idx[0])
        return chosen


class EpsilonRandom(AcquisitionPolicy):
    """Each batch slot is random w.p. ``eps``, else greedy next-best.
    ``eps=1.0`` is the pure random-search baseline benchmarks compare
    every steered policy against."""

    name = "random"

    def __init__(self, eps: float = 1.0) -> None:
        self.eps = float(eps)
        self.name = "random" if eps >= 1.0 else f"eps{eps:g}"

    def select(self, k, mean, std, *, best_f=-math.inf, rng=None,
               members=None, exclude=None, X=None):
        rng = rng or np.random.default_rng()
        mean = np.asarray(mean)
        n = mean.shape[0]
        taken = set(exclude or ())
        chosen: List[int] = []
        greedy_order = iter(_topk_unique(mean, n, taken))
        avail = [i for i in range(n) if i not in taken]
        rng.shuffle(avail)
        avail_iter = iter(avail)
        for _ in range(min(k, len(avail))):
            if rng.random() < self.eps:
                pick = next(i for i in avail_iter if i not in taken)
            else:
                pick = next(i for i in greedy_order if i not in taken)
            chosen.append(pick)
            taken.add(pick)
        return chosen


class KrigingBeliever(AcquisitionPolicy):
    """Hallucinated (kriging-believer) batch selection over a base policy.

    Score-based policies pick a batch as top-k of one frozen score
    vector, so all k picks pile onto the same optimistic peak — the
    degenerate repeated-argmax batch. The kriging believer instead
    selects the batch *sequentially*, and after each pick pretends the
    pick's prediction is already observed ("believes" it): the incumbent
    ``best_f`` absorbs the hallucinated value and the epistemic std of
    nearby candidates collapses by a squared-exponential factor in
    normalized candidate space, so the next pick is pushed toward
    genuinely different regions. With no candidate coordinates (``X``)
    the geometry term is unavailable and selection degrades gracefully
    to the base policy's exclusion-only batch.

    ``lengthscale`` is the shrink radius as a fraction of the candidate
    cloud's span per dimension (isotropic in normalized coordinates).
    """

    name = "kriging"

    def __init__(self, base: Any = "ucb", lengthscale: float = 0.1, **base_kwargs: Any) -> None:
        self.base = make_policy(base, **base_kwargs) if isinstance(base, str) else base
        if lengthscale <= 0:
            raise ValueError(f"lengthscale must be > 0, got {lengthscale}")
        self.lengthscale = float(lengthscale)
        self.name = f"kriging[{self.base.name}]"

    def scores(self, mean, std, *, best_f, rng, members=None):
        return self.base.scores(mean, std, best_f=best_f, rng=rng, members=members)

    def select(self, k, mean, std, *, best_f=-math.inf, rng=None,
               members=None, exclude=None, X=None):
        rng = rng or np.random.default_rng()
        mean = np.asarray(mean, dtype=float)
        std = np.asarray(std, dtype=float).copy()
        if X is None:
            return self.base.select(k, mean, std, best_f=best_f, rng=rng,
                                    members=members, exclude=exclude)
        Xn = np.asarray(X, dtype=float)
        if Xn.ndim == 1:
            Xn = Xn[:, None]
        # Normalize each dimension to the candidate cloud's span so one
        # lengthscale works across anisotropic pools.
        span = Xn.max(axis=0) - Xn.min(axis=0)
        span[span <= 0] = 1.0
        Xn = Xn / span
        ell2 = self.lengthscale * self.lengthscale
        taken = set(exclude or ())
        chosen: List[int] = []
        best = float(best_f)
        for _ in range(min(k, mean.shape[0] - len(taken))):
            idx = self.base.select(1, mean, std, best_f=best, rng=rng,
                                   members=members, exclude=taken)
            if not idx:
                break
            i = idx[0]
            chosen.append(i)
            taken.add(i)
            # Believe the prediction: the incumbent absorbs it and the
            # neighborhood's epistemic std collapses.
            best = max(best, float(mean[i]))
            d2 = np.sum((Xn - Xn[i]) ** 2, axis=1)
            std *= 1.0 - np.exp(-0.5 * d2 / ell2)
        return chosen


POLICIES: Dict[str, Callable[[], AcquisitionPolicy]] = {
    "greedy": Greedy,
    "ucb": UCB,
    "ei": ExpectedImprovement,
    "thompson": Thompson,
    "random": EpsilonRandom,
    "kriging": KrigingBeliever,
}


def make_policy(name: str, **kwargs) -> AcquisitionPolicy:
    try:
        return POLICIES[name](**kwargs)
    except KeyError:
        raise ValueError(f"unknown acquisition policy {name!r}; "
                         f"known: {sorted(POLICIES)}") from None
