"""Streaming (delta) ensemble checkpoints over ``repro.train.checkpoint``.

Campaign checkpoints used to pickle the ensemble's **entire** state dict
— params *and* full optimizer state — every interval, even when a
retrain had not touched most of it. ``EnsembleStreamCheckpointer``
writes ``DeepEnsemble.state_dict()`` as a step stream instead:

* every array leaf is content-hashed (sha256); a **delta step** stores
  only the leaves that changed since they were last stored and records
  ``reused: {leaf: base_step}`` pointers for the rest;
* every ``full_interval``-th step is a **full snapshot**, bounding every
  delta chain to the window the manager retains (``keep =
  full_interval + 2``), so GC can never orphan a base;
* writes go through :class:`repro.train.checkpoint.CheckpointManager`
  — atomic publish, async I/O off the steering thread, shard + manifest
  layout;
* non-array state (config, normalization scalars, rng) rides in the
  manifest's JSON ``extra``.

``restore()`` walks steps newest -> oldest and materializes the first
chain whose bases all verify by hash, returning a dict with exactly the
``DeepEnsemble.state_dict()`` shape — ``load_state_dict`` cannot tell
the difference from the full-pickle path (the parity the campaign
resume test asserts).
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.train.checkpoint import CheckpointManager, _flatten_with_paths, _unflatten_into

from .ensemble import DeepEnsemble, EnsembleConfig


def _leaf_hash(v: np.ndarray) -> str:
    v = np.ascontiguousarray(v)
    h = hashlib.sha256()
    h.update(str(v.dtype).encode())
    h.update(str(v.shape).encode())
    h.update(v.tobytes())
    return h.hexdigest()


def _structure(node: Any) -> Any:
    """JSON-able structural template of a pytree (dict/list/tuple of
    array leaves) so restore can unflatten without pickling anything."""
    if isinstance(node, dict):
        return {"t": "dict", "items": {k: _structure(v) for k, v in node.items()}}
    if isinstance(node, (list, tuple)):
        return {"t": "tuple" if isinstance(node, tuple) else "list",
                "items": [_structure(v) for v in node]}
    return {"t": "leaf"}


def _template(struct: Any) -> Any:
    if struct["t"] == "dict":
        return {k: _template(v) for k, v in struct["items"].items()}
    if struct["t"] in ("list", "tuple"):
        items = [_template(v) for v in struct["items"]]
        return tuple(items) if struct["t"] == "tuple" else items
    return None


def _config_to_json(cfg: EnsembleConfig) -> Dict[str, Any]:
    return dataclasses.asdict(cfg)


def _config_from_json(d: Dict[str, Any]) -> EnsembleConfig:
    from repro.train.optimizer import OptimizerConfig

    d = dict(d)
    if isinstance(d.get("hidden"), list):
        d["hidden"] = tuple(d["hidden"])
    if isinstance(d.get("opt"), dict):
        d["opt"] = OptimizerConfig(**d["opt"])
    return EnsembleConfig(**d)


class EnsembleStreamCheckpointer:
    """Write/restore ``DeepEnsemble.state_dict()`` as a delta stream."""

    def __init__(self, directory: str, full_interval: int = 4, async_writes: bool = True) -> None:
        if full_interval < 1:
            raise ValueError(f"full_interval must be >= 1, got {full_interval}")
        self.full_interval = full_interval
        self.async_writes = async_writes
        # keep > full_interval: a delta's bases are never older than the
        # last full snapshot, which this window always retains.
        self.manager = CheckpointManager(directory, keep=full_interval + 2)
        # leaf -> (hash, step it was last *stored* at). Starts empty after
        # a restart, so the first post-restart save is a full snapshot.
        self._last: Dict[str, Tuple[str, int]] = {}
        latest = self.manager.latest_step()
        self._next_step = 0 if latest is None else latest + 1

    # ------------------------------------------------------------------ save
    def save(self, ensemble: DeepEnsemble) -> int:
        """Write one step (async by default); returns its step number."""
        state = ensemble.state_dict()
        arrays_tree = {
            "params": state["params"],
            "opt_state": state["opt_state"],
            "x_mu": state["x_mu"],
            "x_sd": state["x_sd"],
        }
        flat = {k: np.asarray(v) for k, v in _flatten_with_paths(arrays_tree).items()}
        step = self._next_step
        self._next_step += 1
        full = (step % self.full_interval == 0) or not self._last
        changed: Dict[str, np.ndarray] = {}
        reused: Dict[str, int] = {}
        hashes: Dict[str, str] = {}
        for key, v in flat.items():
            h = _leaf_hash(v)
            hashes[key] = h
            prev = self._last.get(key)
            if full or prev is None or prev[0] != h:
                changed[key] = v
                self._last[key] = (h, step)
            else:
                reused[key] = prev[1]
                self._last[key] = (h, prev[1])
        meta = {
            "in_dim": int(state["in_dim"]),
            "config": _config_to_json(state["config"]),
            "y_mu": float(state["y_mu"]),
            "y_sd": float(state["y_sd"]),
            "norm_frozen": bool(state["norm_frozen"]),
            "fit_count": int(state["fit_count"]),
            "rng": state["rng"],
            "structure": _structure(arrays_tree),
        }
        extra = {"stream": 1, "full": full, "meta": meta,
                 "reused": reused, "hashes": hashes}
        if self.async_writes:
            self.manager.save_async(step, changed, extra)
        else:
            self.manager.save(step, changed, extra)
        return step

    def wait(self) -> None:
        """Block until the in-flight async write (if any) lands."""
        self.manager.wait()

    def all_steps(self) -> List[int]:
        return self.manager.all_steps()

    def latest_step(self) -> Optional[int]:
        return self.manager.latest_step()

    # --------------------------------------------------------------- restore
    def _load_flat(self, step: int) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        path = os.path.join(self.manager.dir, f"step_{step:08d}")
        import json

        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "shard_0.npz"))
        return {k: data[k] for k in data.files}, manifest.get("extra", {})

    def _materialize(self, step: int) -> Dict[str, Any]:
        flat, extra = self._load_flat(step)
        if not extra.get("stream"):
            raise ValueError(f"step {step} is not a stream checkpoint")
        hashes: Dict[str, str] = extra["hashes"]
        base_cache: Dict[int, Dict[str, np.ndarray]] = {}
        for key, base_step in extra.get("reused", {}).items():
            base = base_cache.get(base_step)
            if base is None:
                base = base_cache[base_step] = self._load_flat(int(base_step))[0]
            flat[key] = base[key]
        for key, h in hashes.items():
            if key not in flat:
                raise ValueError(f"step {step}: leaf {key} missing from its chain")
            if _leaf_hash(np.asarray(flat[key])) != h:
                raise ValueError(f"step {step}: leaf {key} failed its content hash")
        meta = extra["meta"]
        tree = _unflatten_into(_template(meta["structure"]),
                               {k: np.asarray(v) for k, v in flat.items()})
        return {
            "in_dim": meta["in_dim"],
            "config": _config_from_json(meta["config"]),
            "params": tree["params"],
            "opt_state": tree["opt_state"],
            "x_mu": np.asarray(tree["x_mu"]),
            "x_sd": np.asarray(tree["x_sd"]),
            "y_mu": meta["y_mu"],
            "y_sd": meta["y_sd"],
            "norm_frozen": meta["norm_frozen"],
            "fit_count": meta["fit_count"],
            "rng": meta["rng"],
        }

    def restore(self, step: Optional[int] = None) -> Dict[str, Any]:
        """State dict from ``step`` (default: newest), falling back to
        older steps when a chain is torn (a SIGKILL mid-write, a GC'd
        base). Raises ``FileNotFoundError`` when nothing materializes."""
        steps = self.all_steps()
        if step is not None:
            steps = [s for s in steps if s <= step]
        last_err: Optional[Exception] = None
        for s in reversed(steps):
            try:
                return self._materialize(s)
            except Exception as exc:  # noqa: BLE001 - fall back to an older step
                last_err = exc
        raise FileNotFoundError(
            f"no restorable ensemble stream step in {self.manager.dir!r}"
            + (f" (last error: {last_err})" if last_err else "")
        )

    def restore_into(self, ensemble: DeepEnsemble, step: Optional[int] = None) -> int:
        state = self.restore(step)
        ensemble.load_state_dict(state)
        return state["fit_count"]


__all__ = ["EnsembleStreamCheckpointer"]
