"""Deep-ensemble MLP surrogate: the workflow's online-trainable "AI".

The paper steers campaigns with a model that is retrained as results
arrive and whose predictions re-prioritize the task queue. This module
supplies that model as a *deep ensemble* (Lakshminarayanan et al.): K
independently-initialized MLPs trained jointly, whose prediction spread
is the epistemic uncertainty the acquisition policies in
``repro.surrogate.acquisition`` consume.

Implementation notes:

  * **single-dispatch batched train/predict** — member parameters are
    stacked along a leading ensemble axis and the forward pass is
    ``vmap``-ed over it, so one jitted call trains/evaluates every
    member (no per-member Python loop on the hot path).
  * **optimizer reuse** — updates come from ``repro.train.optimizer``
    (``init_opt_state``/``apply_updates``); the stacked parameter tree
    is just another pytree to AdamW.
  * **incremental fit** — ``fit(X, y, warm_start=True)`` keeps params
    and optimizer moments between retrains, so each online retrain is a
    short continuation rather than training from scratch.
  * **bounded recompiles** — training rows are padded to the next power
    of two (padding rows carry zero bootstrap weight), so a campaign
    that grows its database by one result per task triggers O(log N)
    recompiles, not O(N). Jitted steps are module-level functions keyed
    on (shapes, config), so every ensemble instance in a policy sweep
    shares one compile cache.
  * **diversity** — besides distinct inits, each member trains under a
    fixed per-fit Poisson bootstrap weighting of the rows.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import OptimizerConfig, apply_updates, init_opt_state


@dataclass(frozen=True)
class EnsembleConfig:
    n_members: int = 4
    hidden: Tuple[int, ...] = (32, 32)
    epochs: int = 60                  # gradient steps per fit() call
    bootstrap: bool = True            # Poisson row-weights per member
    # Fixed row padding: when set, every fit/predict call up to this many
    # rows compiles exactly once (a campaign sets it to its budget's
    # power-of-two); beyond it, pow2 padding takes over.
    pad_to: Optional[int] = None
    # Constant learning rate (warmup 0, min_lr_frac 1.0 disables the
    # cosine schedule): online retrains are short continuations, not a
    # single scheduled run.
    opt: OptimizerConfig = field(
        default_factory=lambda: OptimizerConfig(
            name="adamw", lr=3e-3, warmup_steps=0, total_steps=1,
            min_lr_frac=1.0, weight_decay=1e-4, clip_norm=1.0,
        )
    )


# --------------------------------------------------------------------------
# Pure functions (module-level so jit caches are shared across instances)
# --------------------------------------------------------------------------


def _init_member(key: jax.Array, in_dim: int, hidden: Tuple[int, ...]) -> Dict[str, jax.Array]:
    sizes = (in_dim,) + hidden + (1,)
    params: Dict[str, jax.Array] = {}
    for i, (a, b) in enumerate(zip(sizes, sizes[1:])):
        key, wk = jax.random.split(key)
        params[f"w{i}"] = jax.random.normal(wk, (a, b)) * jnp.sqrt(2.0 / a)
        params[f"b{i}"] = jnp.zeros((b,))
    return params


def _apply_member(params: Dict[str, jax.Array], x: jax.Array, n_layers: int) -> jax.Array:
    h = x
    for i in range(n_layers):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            h = jnp.tanh(h)
    return h[..., 0]


@partial(jax.jit, static_argnames=("in_dim", "hidden", "n_members"))
def _init_stacked(key: jax.Array, in_dim: int, hidden: Tuple[int, ...], n_members: int):
    keys = jax.random.split(key, n_members)
    return jax.vmap(lambda k: _init_member(k, in_dim, hidden))(keys)


@partial(jax.jit, static_argnames=("n_layers",))
def _predict_members(params: Any, x: jax.Array, n_layers: int) -> jax.Array:
    """(K-stacked params, [N, D]) -> [K, N] member predictions."""
    return jax.vmap(lambda p: _apply_member(p, x, n_layers))(params)


@partial(jax.jit, static_argnames=("n_layers", "oc", "epochs"))
def _fit_epochs(params, opt_state, x, y, w, n_layers: int, oc: OptimizerConfig, epochs: int):
    """Run ``epochs`` full-batch steps of per-member weighted MSE."""

    def loss_fn(p):
        preds = _predict_members(p, x, n_layers)          # [K, N]
        err = (preds - y[None, :]) ** 2                   # [K, N]
        per_member = (err * w).sum(axis=1) / jnp.maximum(w.sum(axis=1), 1.0)
        return per_member.sum(), per_member.mean()

    def step(carry, _):
        p, s = carry
        (_, mse), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
        p, s, _ = apply_updates(p, grads, s, oc)
        return (p, s), mse

    (params, opt_state), mses = jax.lax.scan(step, (params, opt_state), None, length=epochs)
    return params, opt_state, mses[-1]


def _pad_pow2(n: int, floor: int = 16) -> int:
    out = floor
    while out < n:
        out *= 2
    return out


# --------------------------------------------------------------------------
# DeepEnsemble
# --------------------------------------------------------------------------


class DeepEnsemble:
    """K MLPs over a common input space; predictions expose (mean, std).

    ``std`` is the member disagreement — the epistemic signal that is
    high where the campaign has not yet sampled — plus a small floor so
    acquisition math never divides by zero.
    """

    def __init__(self, in_dim: int, config: Optional[EnsembleConfig] = None, seed: int = 0) -> None:
        self.in_dim = in_dim
        self.config = config or EnsembleConfig()
        self._n_layers = len(self.config.hidden) + 1
        self._rng = np.random.default_rng(seed)
        key = jax.random.PRNGKey(seed)
        self.params = _init_stacked(key, in_dim, self.config.hidden, self.config.n_members)
        self.opt_state = init_opt_state(self.params, self.config.opt)
        # Input/target normalization, frozen at first fit so warm-started
        # parameters keep a stable target between retrains.
        self._x_mu = np.zeros(in_dim)
        self._x_sd = np.ones(in_dim)
        self._y_mu = 0.0
        self._y_sd = 1.0
        self._norm_frozen = False
        self.fit_count = 0
        # Optional repro.observe.EventLog: when attached, fit/predict emit
        # ``profile`` spans (wall + post-block_until_ready device time) so
        # surrogate costs appear in traces next to task lifecycle spans.
        self.event_log: Optional[Any] = None

    # ------------------------------------------------------------------- fit
    def fit(self, X: np.ndarray, y: np.ndarray, warm_start: bool = True,
            epochs: Optional[int] = None) -> Dict[str, float]:
        """Train every member on (X, y); returns training metrics.

        ``warm_start=False`` reinitializes parameters and optimizer state
        (a from-scratch fit); the default continues from the last fit.
        """
        X = np.asarray(X, np.float32).reshape(len(y), self.in_dim)
        y = np.asarray(y, np.float32).reshape(-1)
        cfg = self.config
        if not warm_start:
            key = jax.random.PRNGKey(int(self._rng.integers(1 << 31)))
            self.params = _init_stacked(key, self.in_dim, cfg.hidden, cfg.n_members)
            self.opt_state = init_opt_state(self.params, cfg.opt)
            self._norm_frozen = False
        if not self._norm_frozen:
            self._x_mu = X.mean(axis=0)
            self._x_sd = X.std(axis=0) + 1e-6
            self._y_mu = float(y.mean())
            self._y_sd = float(y.std() + 1e-6)
            self._norm_frozen = True

        xn = (X - self._x_mu) / self._x_sd
        yn = (y - self._y_mu) / self._y_sd
        n = len(y)
        n_pad = self._padded(n)
        xp = np.zeros((n_pad, self.in_dim), np.float32)
        yp = np.zeros((n_pad,), np.float32)
        xp[:n], yp[:n] = xn, yn
        if cfg.bootstrap:
            w = self._rng.poisson(1.0, size=(cfg.n_members, n)).astype(np.float32)
            w[w.sum(axis=1) == 0] = 1.0  # a member must see some data
        else:
            w = np.ones((cfg.n_members, n), np.float32)
        wp = np.zeros((cfg.n_members, n_pad), np.float32)
        wp[:, :n] = w

        log = self.event_log
        t0 = time.monotonic()
        self.params, self.opt_state, mse = _fit_epochs(
            self.params, self.opt_state, jnp.asarray(xp), jnp.asarray(yp),
            jnp.asarray(wp), self._n_layers, cfg.opt,
            int(epochs if epochs is not None else cfg.epochs),
        )
        if log is not None:
            t1 = time.monotonic()          # dispatch returned (async)
            jax.block_until_ready(mse)     # device actually finished
            t2 = time.monotonic()
            log.profile(
                "ensemble.fit", t_start=t0, wall_s=t2 - t0, device_s=t2 - t1,
                n=n, n_pad=n_pad, fit_count=self.fit_count + 1,
            )
        self.fit_count += 1
        pred, _ = self.predict(X)
        rmse = float(np.sqrt(np.mean((pred - y) ** 2)))
        return {"mse_norm": float(mse), "rmse": rmse, "n": n, "fit_count": self.fit_count}

    def _padded(self, n: int) -> int:
        return max(self.config.pad_to or 0, _pad_pow2(n))

    # --------------------------------------------------------------- predict
    def predict_members(self, X: np.ndarray) -> np.ndarray:
        """Per-member predictions, shape [K, N] (Thompson sampling input).
        Rows are padded to the fit shapes so predicts share compiles."""
        X = np.asarray(X, np.float32).reshape(-1, self.in_dim)
        n = len(X)
        xn = np.zeros((self._padded(n), self.in_dim), np.float32)
        xn[:n] = (X - self._x_mu) / self._x_sd
        log = self.event_log
        t0 = time.monotonic()
        preds = _predict_members(self.params, jnp.asarray(xn), self._n_layers)
        if log is not None:
            t1 = time.monotonic()
            jax.block_until_ready(preds)
            t2 = time.monotonic()
            log.profile("ensemble.predict", t_start=t0, wall_s=t2 - t0,
                        device_s=t2 - t1, n=n)
        return np.asarray(preds)[:, :n] * self._y_sd + self._y_mu

    def predict(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Mean prediction and epistemic std (member disagreement), [N]."""
        preds = self.predict_members(X)
        return preds.mean(axis=0), preds.std(axis=0) + 1e-9

    # ------------------------------------------------------------ checkpoint
    def state_dict(self) -> Dict[str, Any]:
        """Numpy-ified state for campaign checkpoints (pickle-friendly)."""
        to_np = lambda tree: jax.tree_util.tree_map(np.asarray, tree)
        return {
            "in_dim": self.in_dim,
            "config": self.config,
            "params": to_np(self.params),
            "opt_state": to_np(self.opt_state),
            "x_mu": self._x_mu, "x_sd": self._x_sd,
            "y_mu": self._y_mu, "y_sd": self._y_sd,
            "norm_frozen": self._norm_frozen,
            "fit_count": self.fit_count,
            "rng": self._rng.bit_generator.state,
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        if state["in_dim"] != self.in_dim:
            raise ValueError(
                f"checkpoint in_dim {state['in_dim']} != ensemble in_dim {self.in_dim}")
        to_j = lambda tree: jax.tree_util.tree_map(jnp.asarray, tree)
        self.params = to_j(state["params"])
        self.opt_state = to_j(state["opt_state"])
        self._x_mu, self._x_sd = state["x_mu"], state["x_sd"]
        self._y_mu, self._y_sd = state["y_mu"], state["y_sd"]
        self._norm_frozen = state["norm_frozen"]
        self.fit_count = state["fit_count"]
        self._rng.bit_generator.state = state["rng"]


def warmup_jit(in_dim: int, config: EnsembleConfig, predict_rows: int = 0) -> None:
    """Pre-compile the fit/predict graphs a campaign will use (on a
    throwaway ensemble — jit caches are module-level, keyed on shapes +
    config, so the real campaign's first retrain starts warm instead of
    stalling its reallocated slots on XLA compilation)."""
    ens = DeepEnsemble(in_dim, config, seed=0)
    ens.fit(np.zeros((2, in_dim), np.float32), np.zeros(2, np.float32), epochs=config.epochs)
    if predict_rows:
        ens.predict(np.zeros((predict_rows, in_dim), np.float32))


__all__ = ["DeepEnsemble", "EnsembleConfig", "warmup_jit"]
