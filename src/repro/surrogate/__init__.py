"""repro.surrogate — the active-learning steering engine.

The paper's headline loop: a surrogate model is retrained *during* the
campaign and its predictions bias which tasks run next, yielding ~20%
more high-performing results per task budget (Fig. 2). This package is
that loop as a reusable subsystem:

  * ``ensemble``    — jit-compiled deep-ensemble MLP surrogate (vmapped
                      members, warm-start incremental ``fit``, mean +
                      epistemic std ``predict``) reusing the
                      ``repro.train`` optimizer substrate;
  * ``acquisition`` — pluggable batch-aware policies (greedy, UCB,
                      expected improvement, Thompson sampling, and the
                      epsilon-random baseline) over (mean, std);
  * ``scenarios``   — quantile-calibrated synthetic landscapes
                      (quadratic / multimodal / deceptive needle /
                      heteroscedastic) behind a common ``Scenario``
                      protocol so benchmarks sweep scenario x policy;
  * ``thinker``     — ``ActiveLearningThinker``: the retrain-agent
                      lifecycle (slot reallocation to the training pool,
                      online ensemble retrain, joint re-rank of the
                      candidate queue, ``surrogate_event`` telemetry
                      into ``repro.observe``), plus the one-call
                      ``run_active_campaign`` harness.

Quick start::

    from repro.surrogate import (
        DeepEnsemble, make_policy, make_scenario, run_active_campaign,
    )

    scenario = make_scenario("quadratic", dim=4)
    out = run_active_campaign(scenario, make_policy("ucb"), budget=48)
    print(out["hits"], "high performers;", out["retrains"], "retrains")
"""

from .acquisition import (
    AcquisitionPolicy,
    EpsilonRandom,
    ExpectedImprovement,
    Greedy,
    KrigingBeliever,
    make_policy,
    POLICIES,
    Thompson,
    UCB,
)
from .ensemble import DeepEnsemble, EnsembleConfig, warmup_jit
from .scenarios import (
    DeceptiveNeedle,
    Heteroscedastic,
    make_scenario,
    MultimodalSinusoid,
    Scenario,
    SCENARIOS,
    SeparableQuadratic,
    SyntheticScenario,
)
from .stream import EnsembleStreamCheckpointer
from .thinker import ActiveLearningThinker, campaign_ensemble_config, run_active_campaign

__all__ = [
    "AcquisitionPolicy",
    "ActiveLearningThinker",
    "campaign_ensemble_config",
    "DeceptiveNeedle",
    "DeepEnsemble",
    "EnsembleConfig",
    "EnsembleStreamCheckpointer",
    "EpsilonRandom",
    "ExpectedImprovement",
    "Greedy",
    "Heteroscedastic",
    "KrigingBeliever",
    "make_policy",
    "make_scenario",
    "MultimodalSinusoid",
    "POLICIES",
    "run_active_campaign",
    "Scenario",
    "SCENARIOS",
    "SeparableQuadratic",
    "SyntheticScenario",
    "Thompson",
    "UCB",
    "warmup_jit",
]
