"""repro.chaos — declarative chaos injection + soak harness.

The chaos tier proves the stack's fault-tolerance claims under fire
instead of asserting them in unit tests: a ``ChaosSchedule`` (pure
data, JSON-round-trippable) fires timed faults — SIGKILL a federated
site, drop/delay queue messages, doom worker cohorts, corrupt a
checkpoint, flood an elastic pool — against a live soak of 10^4–10^6
tasks, and an ``InvariantChecker`` gates the run on exactly-once
delivery, payload integrity, lifecycle-order cleanliness, and bounded
recovery after every fault.

Quick start::

    from repro.chaos import SoakConfig, default_chaos_schedule, run_soak

    result = run_soak(SoakConfig(n_tasks=10_000))
    assert result.report.ok, result.report.violations

See ``benchmarks/soak.py`` for the recorded (``BENCH_soak.json``)
entry point the CI ``soak-chaos`` job runs.
"""

from .faults import (
    ChaosLink,
    ChaosLocalQueues,
    ChaosPipeQueues,
    corrupt_file,
    kill_control_plane,
    kill_server_process,
    truncate_file,
)
from .invariants import InvariantChecker, InvariantReport, RecoveryProbe
from .schedule import ChaosAction, ChaosRunner, ChaosSchedule, FiredAction
from .soak import (
    SoakConfig,
    SoakHarness,
    SoakResult,
    WorkLedger,
    default_chaos_schedule,
    expected_value,
    run_soak,
    soak_task,
)

__all__ = [
    "ChaosAction",
    "ChaosLink",
    "ChaosLocalQueues",
    "ChaosPipeQueues",
    "ChaosRunner",
    "ChaosSchedule",
    "FiredAction",
    "InvariantChecker",
    "InvariantReport",
    "RecoveryProbe",
    "SoakConfig",
    "SoakHarness",
    "SoakResult",
    "WorkLedger",
    "corrupt_file",
    "default_chaos_schedule",
    "kill_control_plane",
    "expected_value",
    "kill_server_process",
    "run_soak",
    "soak_task",
    "truncate_file",
]
