"""Soak harness: 10^4–10^6 lightweight tasks through a federated
two-site deployment while a ``ChaosSchedule`` fires faults at it.

Topology (one ``WorkLedger`` drives two sites, mirroring the paper's
multi-site deployments):

* ``local``  — in-process ``TaskServer`` over an **elastic** worker
  fleet (``ElasticScaler`` resizes inside the PoolSpec band; the
  ``burst`` fault floods it to force resize thrash) plus a runtime
  ``FailureInjector`` for zombie-cohort storms;
* ``proc``   — a spawned ``ProcessTaskServer`` over **multi-pool**
  ``PoolSpec``s (cpu + accel) behind ``ChaosPipeQueues``; its injector
  carries spec-time storms across the process boundary; the
  ``kill_site`` fault SIGKILLs it mid-campaign and the driver restarts
  it on fresh transport after a down window.

Delivery contract: the driver is **at-least-once with dedup at
acceptance** = exactly-once to the application. The ledger registers a
deadline per submitted index; work presumed lost (killed site, dropped
request) is resubmitted when overdue; the first delivery per index is
accepted, a later delivery of a *different* attempt is suppressed and
counted, and a second delivery of the *same* attempt is an
exactly-once violation. Campaign checkpointing is real (a
``Campaign`` snapshots the ledger through a thinker shim), which is
what the ``corrupt_checkpoint`` fault attacks: it damages the newest
checkpoint on disk, then runs a resume drill proving ``try_resume``
falls back to the previous retained checkpoint with a consistent
(subset) ledger state.
"""

from __future__ import annotations

import collections
import logging
import os
import tempfile
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Set, Tuple

from repro.core import (
    BaseThinker,
    BatchPolicy,
    Campaign,
    FailureInjector,
    LocalColmenaQueues,
    PoolSpec,
    ResourceRequest,
    Result,
    RetryPolicy,
    StragglerPolicy,
    TaskServer,
)
from repro.core.app import ProcessTaskServer

from .faults import ChaosLink, ChaosPipeQueues, corrupt_file, kill_server_process, truncate_file
from .invariants import InvariantChecker, InvariantReport, RecoveryProbe
from .schedule import ChaosAction, ChaosRunner, ChaosSchedule

logger = logging.getLogger("repro.chaos.soak")


def soak_task(x: int) -> int:
    """The soak payload: trivially cheap, but its output is a checkable
    function of its input so the invariant gate can verify payload
    integrity end to end (module-level: must pickle into spawned
    sites)."""
    return x * 3 + 1


def expected_value(index: int) -> int:
    return index * 3 + 1


# --------------------------------------------------------------------------
# Work ledger
# --------------------------------------------------------------------------


class WorkLedger:
    """Exactly-once acceptance over an at-least-once driver.

    Tracks ``n_tasks`` integer work items. ``take`` hands out indices
    (resubmissions first), ``on_submitted`` arms a per-index deadline,
    ``overdue`` recycles indices presumed lost, and ``accept``
    deduplicates deliveries. Memory stays O(n_tasks) bytes + O(resubmitted)
    dicts, so million-task soaks fit comfortably.
    """

    def __init__(self, n_tasks: int, resubmit_after_s: float = 3.0) -> None:
        self.n_tasks = n_tasks
        self.resubmit_after_s = resubmit_after_s
        self.done = bytearray(n_tasks)           # accepted-delivery flag per index
        self.completed = 0
        self.next_fresh = 0
        self.retry_q: Deque[int] = collections.deque()
        self.inflight: Dict[int, Tuple[str, float]] = {}   # index -> (site, deadline)
        self.inflight_by_site: collections.Counter = collections.Counter()
        # Only resubmitted indices can produce benign duplicates, so only
        # they pay for per-attempt task-id bookkeeping.
        self.resubmitted: Set[int] = set()
        self.delivered_tids: Dict[int, Set[str]] = {}
        self.resubmits = 0
        self.duplicates_suppressed = 0
        self.failed_deliveries = 0
        self.exactly_once_violations: List[int] = []
        self.value_errors: List[int] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------- dispatch
    def take(self, k: int, fresh_floor: int = 0) -> List[int]:
        """Up to ``k`` indices to submit now: recycled work first, then
        fresh indices in order. ``fresh_floor`` leaves at least that many
        fresh indices unclaimed — the driver reserves a tail of work for
        a recovering site so its recovery probe has deliveries to resolve
        against (otherwise a fast surviving site drains the whole run
        before the restarted one gets a single task)."""
        out: List[int] = []
        with self._lock:
            while self.retry_q and len(out) < k:
                out.append(self.retry_q.popleft())
            while (
                self.next_fresh < self.n_tasks - fresh_floor and len(out) < k
            ):
                out.append(self.next_fresh)
                self.next_fresh += 1
        return out

    def on_submitted(self, index: int, site: str, task_id: str, now: float) -> None:
        with self._lock:
            prev = self.inflight.get(index)
            if prev is not None:
                self.inflight_by_site[prev[0]] -= 1
            self.inflight[index] = (site, now + self.resubmit_after_s)
            self.inflight_by_site[site] += 1
            if index in self.resubmitted:
                self.delivered_tids.setdefault(index, set())

    def inflight_at(self, site: str) -> int:
        with self._lock:
            return self.inflight_by_site[site]

    def overdue(self, now: float) -> int:
        """Recycle indices whose deadline passed (their site died, their
        request was dropped, or they are just slow — a late duplicate
        will be suppressed at accept)."""
        with self._lock:
            late = [i for i, (_, deadline) in self.inflight.items() if deadline <= now]
            for i in late:
                site, _ = self.inflight.pop(i)
                self.inflight_by_site[site] -= 1
                self.resubmitted.add(i)
                self.delivered_tids.setdefault(i, set())
                self.retry_q.append(i)
                self.resubmits += 1
        return len(late)

    def expedite(self, site: str) -> int:
        """Zero the deadline of everything in flight at ``site`` so the
        next ``overdue`` sweep recycles it immediately — the remediation
        a firing delivery-stall alert runs. At-least-once safe: if the
        stalled site delivers after all, the duplicate is suppressed at
        ``accept`` exactly like any other resubmission."""
        with self._lock:
            mine = [i for i, (s, _) in self.inflight.items() if s == site]
            for i in mine:
                self.inflight[i] = (site, 0.0)
        return len(mine)

    def requeue_site(self, site: str) -> int:
        """Immediately recycle everything in flight at a site (it was
        just killed; no point waiting out the deadline)."""
        with self._lock:
            mine = [i for i, (s, _) in self.inflight.items() if s == site]
            for i in mine:
                self.inflight.pop(i)
                self.inflight_by_site[site] -= 1
                self.resubmitted.add(i)
                self.delivered_tids.setdefault(i, set())
                self.retry_q.append(i)
                self.resubmits += 1
        return len(mine)

    # -------------------------------------------------------------- deliver
    def accept(self, result: Result) -> str:
        """Classify one delivery: ``accepted`` | ``duplicate`` |
        ``violation`` | ``failed`` | ``foreign``."""
        index = result.task_info.get("index")
        if not isinstance(index, int) or not (0 <= index < self.n_tasks):
            return "foreign"
        tid = result.task_id
        with self._lock:
            entry = self.inflight.pop(index, None)
            if entry is not None:
                self.inflight_by_site[entry[0]] -= 1
            if not result.success:
                # Server-side retries exhausted (e.g. a storm killed every
                # attempt): recycle, it still owes us a success.
                if not self.done[index]:
                    self.resubmitted.add(index)
                    self.delivered_tids.setdefault(index, set())
                    self.retry_q.append(index)
                self.failed_deliveries += 1
                return "failed"
            if self.done[index]:
                if index in self.resubmitted and tid not in self.delivered_tids[index]:
                    # A different attempt of deliberately resubmitted work:
                    # the at-least-once tax, suppressed by design.
                    self.delivered_tids[index].add(tid)
                    self.duplicates_suppressed += 1
                    return "duplicate"
                # Same attempt delivered twice, or a dup of work we only
                # ever submitted once: the server broke exactly-once.
                self.exactly_once_violations.append(index)
                return "violation"
            self.done[index] = 1
            self.completed += 1
            if index in self.resubmitted:
                self.delivered_tids[index].add(tid)
            if result.value != expected_value(index):
                self.value_errors.append(index)
        return "accepted"

    def missing_indices(self, limit: int = 8) -> List[int]:
        with self._lock:
            out = []
            for i, flag in enumerate(self.done):
                if not flag:
                    out.append(i)
                    if len(out) >= limit:
                        break
            return out

    # ------------------------------------------------------------ checkpoint
    def get_state(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "n_tasks": self.n_tasks,
                "done": bytes(self.done),
                "completed": self.completed,
                "next_fresh": self.next_fresh,
            }

    def set_state(self, state: Dict[str, Any]) -> None:
        if state.get("n_tasks") != self.n_tasks:
            raise ValueError(
                f"checkpoint is for a {state.get('n_tasks')}-task soak, this one has {self.n_tasks}"
            )
        with self._lock:
            self.done = bytearray(state["done"])
            self.completed = self.n_tasks - self.done.count(0)
            self.next_fresh = state["next_fresh"]
            self.inflight.clear()
            self.inflight_by_site.clear()
            self.retry_q = collections.deque(
                i for i in range(self.next_fresh) if not self.done[i]
            )


class _LedgerThinker(BaseThinker):
    """Thinker shim so the real ``Campaign`` machinery checkpoints the
    ledger (the soak drives queues directly; no agents ever run)."""

    def __init__(self, queues: Any, ledger: WorkLedger) -> None:
        super().__init__(queues)
        self.ledger = ledger

    def get_state(self) -> Dict[str, Any]:
        return self.ledger.get_state()

    def set_state(self, state: Dict[str, Any]) -> None:
        self.ledger.set_state(state)


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------


@dataclass
class SoakConfig:
    n_tasks: int = 100_000
    # Per-site inflight caps double as the routing split: the proc site
    # (pipe serialization + process hop) takes the smaller share.
    max_inflight_local: int = 384
    max_inflight_proc: int = 160
    resubmit_after_s: float = 3.0
    recovery_bound_s: float = 10.0
    checkpoint_every_s: float = 0.5
    site_down_s: float = 0.75         # how long a killed site stays dark before restart
    # Fresh work held back for a site with an unresolved recovery probe
    # (see WorkLedger.take): recovery must be *observable*, not raced away.
    probe_reserve: int = 96
    deadline_s: float = 600.0
    seed: int = 0
    state_dir: Optional[str] = None   # default: fresh tempdir
    out_dir: Optional[str] = None     # JSONL sinks; default: fresh tempdir
    record_events: bool = True        # parent JSONL sink (full order-check coverage)
    log_capacity: int = 1 << 17
    local_pool: PoolSpec = field(default_factory=lambda: PoolSpec("sim", size=4, min_size=2, max_size=10))
    proc_pools: Dict[str, PoolSpec] = field(default_factory=lambda: {
        "cpu": PoolSpec("cpu", size=4),
        "accel": PoolSpec("accel", size=2),
    })
    # Spec-time zombie storms carried into the spawned site's injector:
    # (seconds after its first task, workers to kill).
    proc_storms: List[Tuple[float, int]] = field(default_factory=lambda: [(0.5, 2)])
    retry: RetryPolicy = field(default_factory=lambda: RetryPolicy(max_retries=4, backoff_s=0.02))
    batching: BatchPolicy = field(default_factory=lambda: BatchPolicy(max_batch=32, linger_s=0.001))
    # Straggler speculation stays on but conservative: sub-millisecond
    # medians would otherwise speculate half the backlog.
    straggler: StragglerPolicy = field(default_factory=lambda: StragglerPolicy(factor=50.0, min_history=20))
    heartbeat_timeout_s: float = 2.0
    # SLO mode: a streaming burn-rate engine watches the run (delivery
    # stall on the proc link, local backlog) and auto-remediates firing
    # alerts (expedite resubmission / pre-grow the elastic fleet). The
    # invariant gate then requires chaos to have driven >=1 alert through
    # fire AND resolve, within the resolve bound.
    slo: bool = False
    slo_settle_s: float = 6.0          # post-run grace for firing alerts to resolve
    alert_resolve_bound_s: float = 10.0


def default_chaos_schedule() -> ChaosSchedule:
    """The stock soak schedule: eight faults spread over the run —
    a zombie storm, two site kills, a full network partition, a drop
    window, a delay window, a checkpoint corruption + resume drill, and
    a burst."""
    return ChaosSchedule([
        ChaosAction(kind="doom_workers", at_frac=0.10, params={"n": 3}, scope="local"),
        ChaosAction(kind="kill_site", at_frac=0.22, params={"site": "proc"}, scope="proc"),
        ChaosAction(kind="partition", at_frac=0.33, params={"duration_s": 0.6}, scope="proc"),
        ChaosAction(kind="drop_requests", at_frac=0.40, params={"rate": 0.3, "duration_s": 0.6}, scope="proc"),
        ChaosAction(kind="delay_results", at_frac=0.50, params={"delay_s": 0.01, "duration_s": 0.6}, scope="proc"),
        ChaosAction(kind="corrupt_checkpoint", at_frac=0.60, params={"mode": "bitflip"}, scope="none"),
        ChaosAction(kind="burst", at_frac=0.70, params={"n": 256}, scope="local"),
        ChaosAction(kind="kill_site", at_frac=0.82, params={"site": "proc"}, scope="proc"),
    ])


@dataclass
class SoakResult:
    report: InvariantReport
    wall_s: float
    throughput_tps: float
    fired: List[Any]
    metrics: Dict[str, Any] = field(default_factory=dict)


# --------------------------------------------------------------------------
# Harness
# --------------------------------------------------------------------------


class _Site:
    def __init__(self, name: str, queues: Any) -> None:
        self.name = name
        self.queues = queues
        self.server: Any = None
        self.down = False
        self.down_until = 0.0
        self.kills = 0
        self.generation = 0
        self.jsonl_paths: List[str] = []


class SoakHarness:
    def __init__(self, config: Optional[SoakConfig] = None, schedule: Optional[ChaosSchedule] = None) -> None:
        self.cfg = config or SoakConfig()
        self.schedule = schedule if schedule is not None else default_chaos_schedule()
        self.ledger = WorkLedger(self.cfg.n_tasks, resubmit_after_s=self.cfg.resubmit_after_s)
        self.probes: List[RecoveryProbe] = []
        self._probe_lock = threading.Lock()
        self._ckpt_lock = threading.Lock()   # serializes checkpoints vs. the corruption drill
        self.drill_results: List[Dict[str, Any]] = []

    # ------------------------------------------------------------------ build
    def _build(self) -> None:
        cfg = self.cfg
        from repro.observe import ElasticPolicy, ElasticScaler, EventLog

        self._tmp = None
        if cfg.state_dir is None or cfg.out_dir is None:
            self._tmp = tempfile.TemporaryDirectory(prefix="repro-soak-")
            cfg.state_dir = cfg.state_dir or os.path.join(self._tmp.name, "state")
            cfg.out_dir = cfg.out_dir or os.path.join(self._tmp.name, "logs")
        os.makedirs(cfg.state_dir, exist_ok=True)
        os.makedirs(cfg.out_dir, exist_ok=True)

        jsonl = os.path.join(cfg.out_dir, "soak-driver.jsonl") if cfg.record_events else None
        self.log = EventLog(capacity=cfg.log_capacity, jsonl_path=jsonl)
        self._driver_jsonl = jsonl

        # -- local site: elastic in-process server --------------------------
        self.local_injector = FailureInjector(seed=cfg.seed)
        pool = cfg.local_pool.build(event_log=self.log, injector=self.local_injector)
        local_q = LocalColmenaQueues(event_log=self.log)
        self.local = _Site("local", local_q)
        self.local.server = TaskServer(
            local_q, {"soak": soak_task}, pools={cfg.local_pool.name: pool},
            retry=cfg.retry, straggler=cfg.straggler, batching=cfg.batching,
            heartbeat_timeout_s=cfg.heartbeat_timeout_s, event_log=self.log,
        )
        self.scaler = ElasticScaler(
            pools={cfg.local_pool.name: pool}, specs={cfg.local_pool.name: cfg.local_pool},
            policy=ElasticPolicy(interval=0.05), event_log=self.log,
        )

        # -- proc site: spawned multi-pool server over chaos pipes ----------
        self.link = ChaosLink(seed=cfg.seed + 1)
        proc_q = ChaosPipeQueues(chaos=self.link, event_log=self.log)
        self.proc = _Site("proc", proc_q)
        self._proc_injector = FailureInjector(seed=cfg.seed + 2, storms=list(cfg.proc_storms))
        self._spawn_proc_server()

        # -- campaign checkpointing over the ledger -------------------------
        self.thinker = _LedgerThinker(local_q, self.ledger)
        self.campaign = Campaign(
            self.thinker, self.local.server, state_dir=cfg.state_dir,
            checkpoint_interval_s=cfg.checkpoint_every_s, name="soak",
        )

        # -- SLO engine: burn-rate alerts + auto-remediation ----------------
        self.slo_engine = None
        self._last_proc_delivery = time.monotonic()
        self._pending_partition = 0.0
        self._scheduled_kills = sum(
            1 for a in self.schedule.actions if a.kind == "kill_site"
        )
        if cfg.slo:
            from repro.observe import MetricsAggregator, SLOEngine, SLOObjective, SLOSpec

            # Windows are soak-sized (sub-second faults), not production-
            # sized: fast/slow at 0.25s/0.6s with a 50ms tick keeps the
            # multi-window logic intact while letting a 0.6s partition —
            # which the next scheduled fault may cut short — still drive
            # pending -> firing -> resolved inside a smoke run.
            objectives = [
                SLOObjective(
                    name="proc-delivery-stall", signal="gauge",
                    gauge="delivery_stall_s", pool="proc",
                    threshold=0.15, kind="ceiling", budget=0.25,
                    fast_window_s=0.25, slow_window_s=0.6, min_samples=3,
                    severity="page",
                ),
                SLOObjective(
                    name="local-backlog", signal="backlog",
                    pool=cfg.local_pool.name,
                    threshold=float(cfg.max_inflight_local + 96),
                    kind="ceiling", budget=0.25,
                    fast_window_s=0.25, slow_window_s=0.6, min_samples=3,
                    severity="ticket",
                ),
            ]
            self.slo_engine = SLOEngine(
                self.log,
                spec=SLOSpec(objectives=objectives, interval_s=0.05),
                aggregator=MetricsAggregator(self.log),
            )
            self.slo_engine.on_fire(
                "proc-delivery-stall",
                lambda alert: {"expedited": self.ledger.expedite("proc")},
                label="expedite_proc",
            )
            self.slo_engine.on_fire(
                "local-backlog",
                lambda alert: {"grown": self.scaler.pre_grow(cfg.local_pool.name)},
                label="elastic_pre_grow",
            )

    def _proc_server_kwargs(self) -> Dict[str, Any]:
        cfg = self.cfg
        path = os.path.join(cfg.out_dir, f"soak-proc-{self.proc.generation}.jsonl")
        self.proc.jsonl_paths.append(path)
        specs = {
            name: PoolSpec(
                name=s.name, size=s.size, min_size=s.min_size, max_size=s.max_size,
                warm_capacity=s.warm_capacity, prefetch=s.prefetch,
                injector=self._proc_injector,
            )
            for name, s in cfg.proc_pools.items()
        }
        return dict(
            pool_specs=specs, retry=cfg.retry, straggler=cfg.straggler,
            batching=cfg.batching, heartbeat_timeout_s=cfg.heartbeat_timeout_s,
            jsonl_path=path,
        )

    def _spawn_proc_server(self) -> None:
        self.proc.server = ProcessTaskServer(
            self.proc.queues, {"soak": soak_task}, **self._proc_server_kwargs()
        ).start()

    # ----------------------------------------------------------------- probes
    def _add_probe(self, label: str, scope: str) -> None:
        if scope == "none":
            return
        with self._probe_lock:
            self.probes.append(RecoveryProbe(label=label, scope=scope, t0=time.monotonic()))

    def _resolve_probes(self, site: str, t: float) -> None:
        with self._probe_lock:
            for p in self.probes:
                if p.resolved_t is None and p.matches(site):
                    p.resolve(t)

    def _unresolved_scopes(self) -> Set[str]:
        with self._probe_lock:
            return {p.scope for p in self.probes if p.resolved_t is None}

    # --------------------------------------------------------------- handlers
    def _handle_kill_site(self, params: Dict[str, Any]) -> Dict[str, Any]:
        site = self.proc  # only the spawned site can be SIGKILLed
        pid = kill_server_process(site.server)
        site.down = True
        site.down_until = time.monotonic() + self.cfg.site_down_s
        site.kills += 1
        self._add_probe(f"kill_site#{site.kills}", scope=site.name)
        requeued = self.ledger.requeue_site(site.name)
        return {"ok": pid is not None, "pid": pid, "requeued": requeued}

    def _handle_doom_workers(self, params: Dict[str, Any]) -> Dict[str, Any]:
        n = int(params.get("n", 2))
        self.local_injector.doom_cohort(n)
        self._add_probe(f"doom_workers({n})", scope="local")
        return {"ok": True, "doomed": n}

    def _kills_remaining(self) -> bool:
        """True while the schedule still owes a ``kill_site`` fault."""
        return self.proc.kills < self._scheduled_kills

    def _handle_partition(self, params: Dict[str, Any]) -> Dict[str, Any]:
        """Full bidirectional blackout on the proc link: requests are
        dropped AND buffered results stop being delivered until it heals
        (results submitted before the cut arrive late, not lost). At
        smoke scale the schedule compresses, so a partition landing while
        the site is SIGKILL-dark would black out a link nobody is using,
        and one landing just *before* a SIGKILL gets its stall signal
        wiped when the kill requeues the site's inflight work. Either
        way the cut would be indistinguishable from the kill outage, so
        the partition is deferred until the restart after the *last*
        scheduled kill: it always hits a live link with a clean runway,
        making it observable by (and attributable to) the SLO engine."""
        dur = float(params.get("duration_s", 0.5))
        deferred = self.proc.down or self._kills_remaining()
        if deferred:
            self._pending_partition = dur
        else:
            self.link.enable_partition(dur)
        self._add_probe(f"partition({dur:.2f}s)", scope="proc")
        return {"ok": True, "duration_s": dur, "deferred": deferred}

    def _handle_drop_requests(self, params: Dict[str, Any]) -> Dict[str, Any]:
        rate = float(params.get("rate", 0.3))
        dur = float(params.get("duration_s", 0.5))
        self.link.enable_drop(rate, dur)
        self._add_probe(f"drop_requests({rate:.0%})", scope="proc")
        return {"ok": True, "rate": rate, "duration_s": dur}

    def _handle_delay_results(self, params: Dict[str, Any]) -> Dict[str, Any]:
        delay = float(params.get("delay_s", 0.01))
        dur = float(params.get("duration_s", 0.5))
        self.link.enable_delay(delay, dur)
        self._add_probe(f"delay_results({delay * 1e3:.0f}ms)", scope="proc")
        return {"ok": True, "delay_s": delay, "duration_s": dur}

    def _handle_burst(self, params: Dict[str, Any]) -> Dict[str, Any]:
        """Flood the elastic site past its steady-state inflight cap so the
        scaler must grow, then (when the flood drains) shrink back."""
        n = int(params.get("n", 256))
        indices = self.ledger.take(n)
        now = time.monotonic()
        for i in indices:
            self._submit(self.local, i, now)
        self._add_probe(f"burst({len(indices)})", scope="local")
        return {"ok": True, "submitted": len(indices)}

    def _handle_corrupt_checkpoint(self, params: Dict[str, Any]) -> Dict[str, Any]:
        """Damage the newest checkpoint on disk, then prove resume falls
        back: a *fresh* campaign over a fresh ledger must resume from the
        previous retained checkpoint with a consistent (subset) state."""
        mode = params.get("mode", "bitflip")
        with self._ckpt_lock:
            # Guarantee a fallback target exists: two good checkpoints.
            self.campaign.checkpoint()
            self.campaign.checkpoint()
            newest = self.campaign.latest_checkpoint()
            if newest is None:
                return {"ok": False, "error": "no checkpoint to corrupt"}
            if mode == "truncate":
                truncate_file(newest, keep_fraction=0.4)
            else:
                corrupt_file(newest, n_bytes=32, seed=self.cfg.seed)
            drill_ledger = WorkLedger(self.cfg.n_tasks)
            drill = Campaign(
                _LedgerThinker(self.local.queues, drill_ledger), self.local.server,
                state_dir=self.cfg.state_dir, name="soak",
            )
            resumed = drill.try_resume()
            live = self.ledger.get_state()
        fell_back = drill.resume_fallbacks >= 1
        # The restored frontier must be a subset of live progress: nothing
        # in the older checkpoint may claim work the live ledger has not done.
        subset = resumed and drill_ledger.completed <= self.ledger.completed and not any(
            r and not l for r, l in zip(drill_ledger.done, live["done"])
        )
        detail = {
            "ok": bool(resumed and fell_back and subset),
            "mode": mode, "corrupted": os.path.basename(newest),
            "resumed": resumed, "fell_back": fell_back, "subset": subset,
            "restored_completed": drill_ledger.completed,
            "resumed_from": os.path.basename(drill._resumed_from or "") or None,
        }
        self.drill_results.append(detail)
        return detail

    # ----------------------------------------------------------------- driver
    def _submit(self, site: _Site, index: int, now: float) -> None:
        if site is self.proc:
            # Federated multi-pool routing: spread across the site's pools.
            pools = list(self.cfg.proc_pools)
            pool = pools[index % len(pools)]
        else:
            pool = self.cfg.local_pool.name
        tid = site.queues.send_inputs(
            index, method="soak", task_info={"index": index},
            resources=ResourceRequest(pool=pool),
        )
        self.ledger.on_submitted(index, site.name, tid, now)

    def _top_up(self, now: float) -> None:
        cfg = self.cfg
        sites: List[Tuple[_Site, int]] = []
        if not self.proc.down:
            sites.append((self.proc, cfg.max_inflight_proc))
        sites.append((self.local, cfg.max_inflight_local))
        # A proc-scope recovery probe still open means the proc site owes
        # us a post-fault delivery; hold fresh work back from local so the
        # recovering site has something left to prove itself with.
        proc_pending = "proc" in self._unresolved_scopes()
        for site, cap in sites:
            room = cap - self.ledger.inflight_at(site.name)
            if room <= 0:
                continue
            floor = cfg.probe_reserve if (site is self.local and proc_pending) else 0
            for i in self.ledger.take(room, fresh_floor=floor):
                self._submit(site, i, now)

    def _drain(self, now: float, budget: int = 4096) -> int:
        got = 0
        for site in (self.local, self.proc):
            while got < budget:
                r = site.queues.get_result(timeout=0)
                if r is None:
                    break
                if site is self.proc:
                    self._last_proc_delivery = time.monotonic()
                status = self.ledger.accept(r)
                if status == "accepted":
                    self._resolve_probes(site.name, time.monotonic())
                got += 1
        return got

    def _restart_down_sites(self, now: float) -> None:
        site = self.proc
        if site.down and now >= site.down_until:
            # The killed child may have died holding a queue lock; rebuild
            # the transport before spawning its replacement (leftover
            # results were drained every loop while it was dark).
            self._drain(now)
            site.queues.renew_transport()
            site.generation += 1
            self._spawn_proc_server()
            # The down window is a known outage, not a delivery stall; the
            # stall clock restarts with the new incarnation (before the
            # down flag flips, so the sampler never sees a stale clock).
            self._last_proc_delivery = time.monotonic()
            site.down = False
            if self._pending_partition and not self._kills_remaining():
                self.link.enable_partition(self._pending_partition)
                self._pending_partition = 0.0
                logger.warning("chaos: deferred partition applied post-restart")
            logger.warning("chaos: proc site restarted (generation %d)", site.generation)

    def _progress(self) -> float:
        return self.ledger.completed / max(1, self.cfg.n_tasks)

    def _stall_sampler(self, stop: threading.Event) -> None:
        """Gauge how long the proc link has gone without delivering while
        it still owes work — the partition detector the SLO engine's
        ``delivery_stall_s`` objective watches. Runs on its own thread so
        the signal keeps flowing while the driver loop blocks in a site
        respawn (which is precisely when stalls happen)."""
        while not stop.is_set():
            stall = (
                time.monotonic() - self._last_proc_delivery
                if not self.proc.down and self.ledger.inflight_at("proc")
                else 0.0
            )
            self.log.gauge("delivery_stall_s", stall, pool="proc")
            # Deliberate 50 Hz sampler: the gauge must keep flowing at a fixed
            # rate while the driver blocks in a site respawn, and there is no
            # producer to subscribe to for "time passed without a delivery".
            stop.wait(0.02)  # analyze: ignore[busy-wait]

    # -------------------------------------------------------------------- run
    def run(self) -> SoakResult:
        cfg = self.cfg
        self._build()
        handlers: Dict[str, Callable[[Dict[str, Any]], Any]] = {
            "kill_site": self._handle_kill_site,
            "doom_workers": self._handle_doom_workers,
            "drop_requests": self._handle_drop_requests,
            "delay_results": self._handle_delay_results,
            "corrupt_checkpoint": self._handle_corrupt_checkpoint,
            "burst": self._handle_burst,
            "partition": self._handle_partition,
        }
        runner = ChaosRunner(self.schedule, handlers, progress=self._progress, event_log=self.log)

        t0 = time.monotonic()
        self.local.server.start()
        self.scaler.emit_baseline()
        self.scaler.start()
        stall_stop = threading.Event()
        if self.slo_engine is not None:
            self._last_proc_delivery = t0
            self.slo_engine.start()
            # Dedicated sampler: the driver loop blocks for >1s inside a
            # site respawn, which is exactly when the stall signal
            # matters — the gauge must keep flowing regardless.
            threading.Thread(
                target=self._stall_sampler, args=(stall_stop,),
                daemon=True, name="soak-stall-gauge",
            ).start()
        runner.start()
        last_ckpt = t0
        deadline = t0 + cfg.deadline_s
        try:
            while self.ledger.completed < cfg.n_tasks:
                now = time.monotonic()
                if now >= deadline:
                    logger.error("soak deadline reached at %d/%d", self.ledger.completed, cfg.n_tasks)
                    break
                self._restart_down_sites(now)
                self._top_up(now)
                got = self._drain(now)
                self.ledger.overdue(now)
                if now - last_ckpt >= cfg.checkpoint_every_s:
                    with self._ckpt_lock:
                        self.campaign.checkpoint()
                    last_ckpt = now
                if got == 0:
                    # Nothing landed: block briefly on the local site
                    # instead of spinning the driver core.
                    r = self.local.queues.get_result(timeout=0.01)
                    if r is not None and self.ledger.accept(r) == "accepted":
                        self._resolve_probes("local", time.monotonic())
        finally:
            runner.stop()
            stall_stop.set()
            if self.slo_engine is not None:
                # The run is over: heal the stall gauge (no deliveries are
                # coming) and give firing alerts their settle window to
                # observe recovery before teardown freezes the engine.
                self.log.gauge("delivery_stall_s", 0.0, pool="proc")
                self.slo_engine.settle(cfg.slo_settle_s)
                self.slo_engine.stop()
            self.scaler.stop()
            with self._ckpt_lock:
                self.campaign.final_checkpoint()
            try:
                self.local.server.stop()
            except Exception:  # noqa: BLE001
                logger.exception("local server stop failed")
            try:
                if self.proc.server is not None:
                    self.proc.server.stop()
            except Exception:  # noqa: BLE001
                logger.exception("proc server stop failed")
            # Dead-feeder teardown: without it a SIGKILLed child's queues
            # hang the harness process at interpreter exit.
            self.proc.queues.close_transport()
        wall = time.monotonic() - t0

        # -- end-of-run resume audit: the final checkpoint must round-trip --
        audit_ledger = WorkLedger(cfg.n_tasks)
        audit = Campaign(_LedgerThinker(self.local.queues, audit_ledger), self.local.server,
                         state_dir=cfg.state_dir, name="soak")
        audit_ok = audit.try_resume() and audit_ledger.completed == self.ledger.completed

        extra: List[str] = []
        if not audit_ok:
            extra.append("final checkpoint failed its resume round-trip")
        if self.slo_engine is not None:
            extra.extend(self._slo_violations(runner))
        report = self._check(runner, extra_violations=extra)
        metrics = self._metrics(runner, wall)
        if self.log is not None:
            self.log.close()
        if self._tmp is not None:
            self._tmp.cleanup()
            self._tmp = None
        return SoakResult(
            report=report, wall_s=wall,
            throughput_tps=self.ledger.completed / wall if wall > 0 else 0.0,
            fired=list(runner.fired), metrics=metrics,
        )

    # ------------------------------------------------------------------ check
    def _merged_events(self) -> Any:
        """Reassemble the cross-process trace: driver ring/JSONL + every
        proc-site incarnation's sink, ordered on the shared monotonic
        clock (the ``observe.trace`` merge idiom)."""
        from repro.observe import EventLog
        from repro.observe.trace import load_jsonl

        merged = EventLog(capacity=max(self.cfg.log_capacity, 1 << 18))
        events: List[Any] = list(self.log.events())
        for path in self.proc.jsonl_paths:
            if os.path.exists(path):
                events.extend(load_jsonl(path))
        events.sort(key=lambda ev: ev.t)
        for ev in events:
            merged.emit(ev)
        return merged

    def _check(self, runner: ChaosRunner, extra_violations: List[str] = ()) -> InvariantReport:
        checker = InvariantChecker(recovery_bound_s=self.cfg.recovery_bound_s)
        report = checker.check(
            self.ledger, fired=runner.fired, probes=list(self.probes),
            events=self._merged_events(),
        )
        for v in extra_violations:
            report.violations.append(v)
            report.ok = False
        return report

    def _slo_violations(self, runner: ChaosRunner) -> List[str]:
        """SLO-mode invariants: chaos must have driven the alerting loop
        end to end — at least one alert fired, the partition raised one,
        everything resolved, and resolution stayed inside the bound."""
        eng = self.slo_engine
        out: List[str] = []
        fired = [tr for tr in eng.transitions if tr["to"] == "firing"]
        if not fired:
            out.append("slo: no alert fired during the chaos soak")
        part_ts = [f.t for f in runner.fired if f.action.kind == "partition" and f.ok]
        if part_ts:
            # The partition counts as alerted if any alert *activity*
            # (a firing or a resolve transition) lands at or after the
            # injection: a resolve after that instant means the alert was
            # still covering the link when the cut happened, so demanding
            # a brand-new firing transition would double-count merged
            # firing intervals as misses.
            p_t = part_ts[0]
            covered = any(
                tr["t"] >= p_t
                for tr in eng.transitions
                if tr["to"] == "firing" or tr["from"] == "firing"
            )
            if not covered:
                out.append("slo: the partition fault raised no alert")
        still = eng.firing()
        if still:
            out.append(f"slo: still firing after settle: {', '.join(sorted(still))}")
        resolve_times = [
            tr["firing_s"] for tr in eng.transitions
            if tr["from"] == "firing" and tr["to"] == "ok" and "firing_s" in tr
        ]
        worst = max(resolve_times, default=0.0)
        if worst > self.cfg.alert_resolve_bound_s:
            out.append(
                f"slo: slowest alert took {worst:.2f}s to resolve "
                f"(bound {self.cfg.alert_resolve_bound_s}s)"
            )
        return out

    def _metrics(self, runner: ChaosRunner, wall: float) -> Dict[str, Any]:
        sm = self.local.server.metrics
        out = {
            "wall_s": wall,
            "site_kills": self.proc.kills,
            "proc_generations": self.proc.generation,
            "requests_dropped": self.link.dropped,
            "results_delayed": self.link.delayed,
            "partition_drops": self.link.partition_drops,
            "local_retries": sm.tasks_retried,
            "local_workers_replaced": sm.workers_replaced,
            "local_speculated": sm.speculative_launched,
            "pool_resizes": len(self.scaler.resizes),
            "checkpoints_written": self.campaign.checkpoints_written,
            "resume_drills": len(self.drill_results),
            "faults_unfired": len(runner.unfired),
        }
        if self.slo_engine is not None:
            eng = self.slo_engine
            resolved = [
                tr["firing_s"] for tr in eng.transitions
                if tr["from"] == "firing" and tr["to"] == "ok" and "firing_s" in tr
            ]
            out.update({
                "alerts_fired": sum(1 for tr in eng.transitions if tr["to"] == "firing"),
                "alerts_resolved": len(resolved),
                "alerts_unresolved": len(eng.firing()),
                "max_alert_resolve_s": max(resolved, default=0.0),
                "remediations": eng.remediations_run,
            })
        return out


def run_soak(config: Optional[SoakConfig] = None, schedule: Optional[ChaosSchedule] = None) -> SoakResult:
    return SoakHarness(config, schedule).run()
