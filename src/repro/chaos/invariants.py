"""Event-log invariant gate for chaos runs.

The soak harness defines *exactly-once delivery* at the boundary that
matters to an application: every submitted work item is **accepted
exactly once** by the driver. Underneath, the stack is at-least-once
(the ledger resubmits work presumed lost to a killed site or a dropped
message) with deduplication at acceptance — so a late second execution
of a resubmitted item is *suppressed and counted*, not a violation,
while a second delivery of the **same task attempt** (same task id), or
any second delivery of a never-resubmitted item, is a hard violation:
the server broke its own delivery contract.

``InvariantChecker.check`` gates a run on:

* **zero lost** — every index accepted (``completed == n_tasks``);
* **zero duplicated deliveries** — no exactly-once violations as above;
* **payload integrity** — every accepted value equals ``f(index)``;
* **zero lifecycle-order violations** — over the merged cross-process
  event trace (parent ring + each server incarnation's JSONL sink,
  reassembled on the shared monotonic clock as in ``observe.trace``);
* **bounded recovery** — every fired fault's ``RecoveryProbe`` resolved
  (a matching-scope delivery landed after the fault) within
  ``recovery_bound_s``; and every firing's own handler reported ok
  (e.g. the corrupt-checkpoint resume drill actually fell back);
* **enough fire** — at least ``require_faults`` faults actually fired,
  so a run that finished before its schedule triggered cannot pass
  vacuously.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence


@dataclass
class RecoveryProbe:
    """Fault-to-next-delivery stopwatch.

    Registered when a fault fires; resolved by the driver at the first
    accepted delivery whose site matches ``scope`` (``"any"`` matches
    every site). ``recovery_s`` is the gap the bound applies to."""

    label: str
    scope: str = "any"
    t0: float = 0.0
    resolved_t: Optional[float] = None

    def matches(self, site: str) -> bool:
        return self.scope in ("any", site)

    def resolve(self, t: float) -> None:
        if self.resolved_t is None and t >= self.t0:
            self.resolved_t = t

    @property
    def recovery_s(self) -> Optional[float]:
        return None if self.resolved_t is None else self.resolved_t - self.t0


@dataclass
class InvariantReport:
    ok: bool
    n_tasks: int
    completed: int
    lost: int
    duplicates_suppressed: int
    exactly_once_violations: int
    value_errors: int
    order_violations: int
    failed_deliveries: int
    resubmits: int
    faults_fired: int
    faults_failed: int
    max_recovery_s: float
    recoveries: List[Dict[str, Any]] = field(default_factory=list)
    violations: List[str] = field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "n_tasks": self.n_tasks,
            "completed": self.completed,
            "lost": self.lost,
            "duplicates_suppressed": self.duplicates_suppressed,
            "exactly_once_violations": self.exactly_once_violations,
            "value_errors": self.value_errors,
            "order_violations": self.order_violations,
            "failed_deliveries": self.failed_deliveries,
            "resubmits": self.resubmits,
            "faults_fired": self.faults_fired,
            "faults_failed": self.faults_failed,
            "max_recovery_s": self.max_recovery_s,
            "recoveries": list(self.recoveries),
            "violations": list(self.violations),
        }


class InvariantChecker:
    def __init__(self, recovery_bound_s: float = 10.0, require_faults: int = 0) -> None:
        self.recovery_bound_s = recovery_bound_s
        self.require_faults = require_faults

    def check(
        self,
        ledger: Any,                       # repro.chaos.soak.WorkLedger (duck-typed)
        fired: Sequence[Any] = (),         # ChaosRunner.fired
        probes: Sequence[RecoveryProbe] = (),
        events: Optional[Any] = None,      # EventLog or by_task mapping
        max_sample: int = 8,
    ) -> InvariantReport:
        violations: List[str] = []

        # -- delivery: zero lost, exactly once, intact payloads ------------
        lost = ledger.n_tasks - ledger.completed
        if lost:
            missing = ledger.missing_indices(limit=max_sample)
            violations.append(f"{lost} task(s) never delivered (e.g. indices {missing})")
        dups = list(getattr(ledger, "exactly_once_violations", []))
        if dups:
            violations.append(
                f"{len(dups)} duplicated deliveries accepted (e.g. indices {dups[:max_sample]})"
            )
        value_errors = list(getattr(ledger, "value_errors", []))
        if value_errors:
            violations.append(
                f"{len(value_errors)} corrupted result payloads (e.g. indices {value_errors[:max_sample]})"
            )

        # -- event trace: causal ordering ----------------------------------
        order: List[str] = []
        if events is not None:
            from repro.observe import lifecycle_order_violations

            order = lifecycle_order_violations(events)
            if order:
                violations.append(
                    f"{len(order)} lifecycle-order violations (e.g. {order[:max_sample]})"
                )

        # -- faults: all fired cleanly, all recovered in bound -------------
        failed_firings = [f for f in fired if not f.ok]
        for f in failed_firings:
            violations.append(f"fault {f.action.label} failed to inject/recover: {f.detail}")
        if len(fired) < self.require_faults:
            violations.append(
                f"only {len(fired)} fault(s) fired; the gate requires >= {self.require_faults} "
                "(the run must actually have been under fire)"
            )

        recoveries: List[Dict[str, Any]] = []
        max_recovery = 0.0
        for p in probes:
            rec = p.recovery_s
            recoveries.append({"label": p.label, "scope": p.scope, "recovery_s": rec})
            if rec is None:
                violations.append(f"no {p.scope}-scope delivery ever landed after fault {p.label}")
            else:
                max_recovery = max(max_recovery, rec)
                if rec > self.recovery_bound_s:
                    violations.append(
                        f"recovery after {p.label} took {rec:.2f}s > bound {self.recovery_bound_s:.2f}s"
                    )

        return InvariantReport(
            ok=not violations,
            n_tasks=ledger.n_tasks,
            completed=ledger.completed,
            lost=lost,
            duplicates_suppressed=getattr(ledger, "duplicates_suppressed", 0),
            exactly_once_violations=len(dups),
            value_errors=len(value_errors),
            order_violations=len(order),
            failed_deliveries=getattr(ledger, "failed_deliveries", 0),
            resubmits=getattr(ledger, "resubmits", 0),
            faults_fired=len(fired),
            faults_failed=len(failed_firings),
            max_recovery_s=max_recovery,
            recoveries=recoveries,
            violations=violations,
        )
