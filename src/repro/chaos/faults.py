"""Fault primitives the chaos tier injects through.

Three families:

* **message chaos** — ``ChaosLink`` + queue subclasses that drop task
  requests on the floor or delay result delivery on the *driver* side
  of a queue pair (the side the ``ChaosRunner`` can toggle at runtime;
  the server side of a ``PipeColmenaQueues`` lives in another process
  and its link copy stays inert);
* **storage chaos** — truncate or bit-flip a file (campaign
  checkpoints) so resume must detect the damage and fall back;
* **process chaos** — SIGKILL a spawned ``ProcessTaskServer`` child,
  the no-goodbye node loss of the paper's exascale deployments, plus
  the transport surgery needed to survive it (a process killed while
  holding a ``multiprocessing.Queue`` lock poisons that lock for every
  later user, so the request channel is rebuilt on restart).
"""

from __future__ import annotations

import logging
import multiprocessing
import os
import random
import signal
import threading
import time
from dataclasses import dataclass
from typing import Any, List, Optional

from repro.core.queues import _KILL, LocalColmenaQueues, PipeColmenaQueues

logger = logging.getLogger("repro.chaos.faults")


# --------------------------------------------------------------------------
# Message chaos
# --------------------------------------------------------------------------


@dataclass
class ChaosLink:
    """Runtime-toggleable message chaos on one side of a queue pair.

    Dropping and delaying have independent activation windows so one
    schedule can run them back to back: ``enable_drop(rate, duration)``
    makes ``_push_request`` discard that fraction of task requests;
    ``enable_delay(delay, duration)`` makes every popped result sleep
    before delivery (a slow interconnect, not a lost one). Counters
    (``dropped``/``delayed``) feed the soak report.
    """

    seed: int = 0

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self.drop_rate = 0.0
        self.delay_s = 0.0
        self._drop_until = 0.0
        self._delay_until = 0.0
        self._partition_until = 0.0
        self.dropped = 0
        self.delayed = 0
        self.partition_drops = 0

    # Links ride inside queues across process boundaries; the child's
    # copy starts inert (windows closed) and cannot be toggled remotely.
    def __getstate__(self) -> dict:
        state = dict(self.__dict__)
        state.pop("_rng", None)
        state.pop("_lock", None)
        state["_drop_until"] = 0.0
        state["_delay_until"] = 0.0
        state["_partition_until"] = 0.0
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()

    def enable_drop(self, rate: float, duration_s: float) -> None:
        with self._lock:
            self.drop_rate = max(0.0, min(1.0, rate))
            self._drop_until = time.monotonic() + duration_s

    def enable_delay(self, delay_s: float, duration_s: float) -> None:
        with self._lock:
            self.delay_s = max(0.0, delay_s)
            self._delay_until = time.monotonic() + duration_s

    def enable_partition(self, duration_s: float) -> None:
        """Full bidirectional blackout: every task request is dropped and
        no result is delivered until the window closes (a network
        partition, not a lossy link — both directions go dark at once)."""
        with self._lock:
            self._partition_until = time.monotonic() + duration_s

    def disable(self) -> None:
        with self._lock:
            self._drop_until = 0.0
            self._delay_until = 0.0
            self._partition_until = 0.0

    def partitioned(self) -> bool:
        with self._lock:
            return time.monotonic() < self._partition_until

    def should_drop_request(self) -> bool:
        with self._lock:
            if time.monotonic() < self._partition_until:
                self.partition_drops += 1
                return True
            if time.monotonic() < self._drop_until and self._rng.random() < self.drop_rate:
                self.dropped += 1
                return True
            return False

    def result_delay(self) -> float:
        with self._lock:
            if time.monotonic() < self._delay_until and self.delay_s > 0:
                self.delayed += 1
                return self.delay_s
            return 0.0


class _ChaosQueuesMixin:
    """Mixin over a ``ColmenaQueues`` implementation applying a
    ``ChaosLink`` to the driver-side transport primitives."""

    def _init_chaos(self, chaos: Optional[ChaosLink]) -> None:
        self.chaos = chaos if chaos is not None else ChaosLink()

    def _push_request(self, payload: Any) -> None:
        # Never drop the kill sentinel: losing it turns every shutdown
        # into a timeout. (Pipe queues bypass this path for kills.)
        is_kill = isinstance(payload, str) and payload == _KILL
        if not is_kill and self.chaos.should_drop_request():
            logger.warning("chaos: dropped a task request on the floor")
            return
        super()._push_request(payload)

    def _pop_result(self, topic: str, timeout: Optional[float]) -> Any:
        # During a partition nothing crosses the link in either direction:
        # results stay buffered in the transport (delivered after heal),
        # so the driver sees silence, not loss.
        if self.chaos.partitioned():
            time.sleep(min(0.05, timeout) if timeout is not None else 0.05)
            return None
        payload = super()._pop_result(topic, timeout)
        if payload is not None:
            delay = self.chaos.result_delay()
            if delay > 0:
                time.sleep(delay)
        return payload


class ChaosLocalQueues(_ChaosQueuesMixin, LocalColmenaQueues):
    """In-process queues with drop/delay chaos (unit-test scale)."""

    def __init__(self, chaos: Optional[ChaosLink] = None, **kwargs: Any) -> None:
        super().__init__(**kwargs)
        self._init_chaos(chaos)


class ChaosPipeQueues(_ChaosQueuesMixin, PipeColmenaQueues):
    """Cross-process queues with drop/delay chaos plus post-SIGKILL
    transport surgery (``renew_transport``)."""

    def __init__(self, chaos: Optional[ChaosLink] = None, **kwargs: Any) -> None:
        super().__init__(**kwargs)
        self._init_chaos(chaos)
        self._ctx = multiprocessing.get_context("spawn")

    def __getstate__(self) -> dict:
        state = super().__getstate__()
        state.pop("_ctx", None)
        return state

    def __setstate__(self, state: dict) -> None:
        super().__setstate__(state)
        self._ctx = multiprocessing.get_context("spawn")

    def renew_transport(self) -> int:
        """Replace every ``multiprocessing`` channel with a fresh one.

        Call after SIGKILLing the consumer process and before spawning
        its replacement: a child killed inside ``Queue.get``/``put``
        dies holding the queue's shared-memory lock, leaving it acquired
        forever — the next incarnation would block on its first pop.
        Requests still buffered in the old channel are *lost* (the
        driver's resubmission ledger covers them, exactly as it covers
        requests the dead child had popped but not finished); results
        should be drained by the caller *before* renewal (the parent is
        the only result-queue reader, so draining stays safe after the
        child dies). Returns the number of channels replaced.
        """
        old = [self._requests, *self._results.values(), *self._notices.values()]
        self._requests = self._ctx.Queue()
        self._results = {t: self._ctx.Queue() for t in self.topics}
        self._notices = {t: self._ctx.Queue() for t in self.topics}
        self._discard(old)
        return len(old)

    def close_transport(self) -> None:
        """Final teardown: close every channel and cancel feeder joins.

        A queue whose consumer was SIGKILLed keeps a parent-side feeder
        thread blocked in ``send`` forever (the pipe is full, the reader
        is gone); ``multiprocessing`` joins feeders at interpreter exit,
        so without this the *harness process* hangs on shutdown."""
        self._discard([self._requests, *self._results.values(), *self._notices.values()])

    @staticmethod
    def _discard(queues: List[Any]) -> None:
        for q in queues:
            try:
                q.close()
                q.cancel_join_thread()  # never hang interpreter exit on a dead feeder
            except Exception:  # noqa: BLE001 - best-effort teardown of poisoned queues
                pass


# --------------------------------------------------------------------------
# Storage chaos
# --------------------------------------------------------------------------


def truncate_file(path: str, keep_fraction: float = 0.5) -> int:
    """Tear a file as a writer killed mid-publish would; returns the
    surviving byte count."""
    size = os.path.getsize(path)
    keep = int(size * max(0.0, min(1.0, keep_fraction)))
    with open(path, "rb+") as f:
        f.truncate(keep)
    return keep

def corrupt_file(path: str, n_bytes: int = 16, seed: int = 0, offset_frac: float = 0.5) -> int:
    """Flip a run of bytes mid-file (silent media corruption: the file
    stays loadable-looking but its content digest no longer matches).
    Returns how many bytes were overwritten."""
    rng = random.Random(seed)
    size = os.path.getsize(path)
    if size == 0:
        return 0
    start = min(int(size * max(0.0, min(1.0, offset_frac))), size - 1)
    count = max(1, min(n_bytes, size - start))
    with open(path, "rb+") as f:
        f.seek(start)
        original = f.read(count)
        f.seek(start)
        # XOR with a non-zero mask: guaranteed different from the original.
        f.write(bytes(b ^ (rng.randrange(1, 256)) for b in original))
    return count


# --------------------------------------------------------------------------
# Process chaos
# --------------------------------------------------------------------------


def kill_server_process(server: Any, sig: int = signal.SIGKILL) -> Optional[int]:
    """SIGKILL a ``ProcessTaskServer``'s child — no drain, no goodbye.

    Returns the pid killed, or None if no child was running. The
    server's process handle is cleared so a later ``stop()`` does not
    signal the corpse (or a recycled pid)."""
    proc = getattr(server, "_proc", None)
    if proc is None or proc.pid is None:
        return None
    pid = proc.pid
    try:
        os.kill(pid, sig)
    except ProcessLookupError:
        pass  # already gone: the goal state
    proc.join(timeout=10)
    server._proc = None
    logger.warning("chaos: SIGKILLed task-server process pid=%d", pid)
    return pid


def kill_control_plane(proc: Any, sig: int = signal.SIGKILL) -> Optional[int]:
    """SIGKILL a control-plane daemon subprocess mid-run — the fault the
    auto-resume path exists for. Accepts a ``subprocess.Popen`` (or
    anything with ``.pid``/``.wait``); returns the pid killed, or None
    if the daemon already exited."""
    pid = getattr(proc, "pid", None)
    if pid is None or (getattr(proc, "poll", None) and proc.poll() is not None):
        return None
    try:
        os.kill(pid, sig)
    except ProcessLookupError:
        return None  # already gone
    try:
        proc.wait(timeout=10)
    except Exception:  # noqa: BLE001 - a SIGKILLed child must reap; best effort
        pass
    logger.warning("chaos: SIGKILLed control-plane daemon pid=%d", pid)
    return pid


__all__: List[str] = [
    "ChaosLink",
    "ChaosLocalQueues",
    "ChaosPipeQueues",
    "corrupt_file",
    "kill_control_plane",
    "kill_server_process",
    "truncate_file",
]
