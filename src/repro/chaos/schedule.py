"""Declarative chaos schedules.

A ``ChaosSchedule`` is a list of timed fault injections — "kill the
remote site at 25% progress", "drop 30% of requests for 0.8 s starting
at t=4 s" — that a ``ChaosRunner`` fires against a live workflow from a
side thread. The schedule is data (``to_dict``/``from_dict`` round-trip
to JSON/TOML), the faults are handlers the harness supplies, and every
firing is recorded (and emitted as a ``chaos`` event when an
``EventLog`` is attached) so the invariant checker can demand a bounded
recovery after each one.

Triggers come in two flavors:

* ``at_s``   — wall-clock seconds since ``ChaosRunner.start()``;
* ``at_frac`` — workflow progress fraction in [0, 1] as reported by the
  runner's ``progress`` callable (e.g. tasks completed / tasks total),
  which keeps one schedule meaningful across soak sizes.
"""

from __future__ import annotations

import logging
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

logger = logging.getLogger("repro.chaos")


@dataclass
class ChaosAction:
    """One scheduled fault.

    ``kind`` selects the handler (``kill_site``, ``drop_requests``,
    ``delay_results``, ``doom_workers``, ``corrupt_checkpoint``,
    ``burst``, ...); ``params`` is passed to it verbatim. ``scope``
    names which deliveries prove recovery from this fault (a site name,
    or ``"any"``); ``"none"`` opts out of a delivery-based recovery
    probe (e.g. checkpoint corruption, whose recovery is a resume
    drill, not a delivery).
    """

    kind: str
    at_s: Optional[float] = None
    at_frac: Optional[float] = None
    params: Dict[str, Any] = field(default_factory=dict)
    scope: str = "any"
    label: Optional[str] = None

    def __post_init__(self) -> None:
        if (self.at_s is None) == (self.at_frac is None):
            raise ValueError(f"chaos action {self.kind!r}: set exactly one of at_s / at_frac")
        if self.at_frac is not None and not (0.0 <= self.at_frac <= 1.0):
            raise ValueError(f"chaos action {self.kind!r}: at_frac must be in [0, 1]")
        if self.label is None:
            trig = f"t={self.at_s}s" if self.at_s is not None else f"p={self.at_frac:.0%}"
            self.label = f"{self.kind}@{trig}"

    def due(self, elapsed_s: float, progress: float) -> bool:
        if self.at_s is not None:
            return elapsed_s >= self.at_s
        return progress >= self.at_frac

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"kind": self.kind, "scope": self.scope, "label": self.label}
        if self.at_s is not None:
            d["at_s"] = self.at_s
        if self.at_frac is not None:
            d["at_frac"] = self.at_frac
        if self.params:
            d["params"] = dict(self.params)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ChaosAction":
        return cls(
            kind=d["kind"],
            at_s=d.get("at_s"),
            at_frac=d.get("at_frac"),
            params=dict(d.get("params", {})),
            scope=d.get("scope", "any"),
            label=d.get("label"),
        )


@dataclass
class ChaosSchedule:
    """An ordered bag of ``ChaosAction``s. Order is authorship order;
    the runner checks *all* unfired actions each tick, so mixing ``at_s``
    and ``at_frac`` triggers is fine."""

    actions: List[ChaosAction] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.actions)

    def to_dict(self) -> Dict[str, Any]:
        return {"actions": [a.to_dict() for a in self.actions]}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ChaosSchedule":
        return cls(actions=[ChaosAction.from_dict(a) for a in d.get("actions", [])])


@dataclass
class FiredAction:
    """Record of one fault actually injected."""

    t: float                 # time.monotonic() at firing
    elapsed_s: float
    progress: float
    action: ChaosAction
    ok: bool                 # handler ran and (if it returned a dict) reported ok
    detail: Any = None


class ChaosRunner:
    """Fires a ``ChaosSchedule`` against handler callables from a side
    thread.

    ``handlers`` maps action kind -> ``fn(params) -> detail``; a handler
    raising, or returning a dict with ``{"ok": False}``, marks the
    firing failed (the invariant checker treats a failed firing as a
    violation — a fault that could not even be injected, or whose
    built-in recovery drill failed, must fail the run loudly).
    """

    def __init__(
        self,
        schedule: ChaosSchedule,
        handlers: Dict[str, Callable[[Dict[str, Any]], Any]],
        progress: Callable[[], float] = lambda: 0.0,
        event_log: Optional[Any] = None,
        poll_s: float = 0.05,
    ) -> None:
        self.schedule = schedule
        self.handlers = dict(handlers)
        self.progress = progress
        self.event_log = event_log
        self.poll_s = poll_s
        self.fired: List[FiredAction] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._t0: Optional[float] = None

    # ------------------------------------------------------------------ fire
    def _fire(self, action: ChaosAction, elapsed: float, prog: float) -> None:
        handler = self.handlers.get(action.kind)
        ok, detail = True, None
        if handler is None:
            ok, detail = False, f"no handler for chaos kind {action.kind!r}"
        else:
            try:
                detail = handler(dict(action.params))
                if isinstance(detail, dict) and detail.get("ok") is False:
                    ok = False
            except Exception as exc:  # noqa: BLE001 - a broken injector must not kill the run
                ok, detail = False, f"{type(exc).__name__}: {exc}"
                logger.exception("chaos handler %s raised", action.label)
        now = time.monotonic()
        self.fired.append(FiredAction(t=now, elapsed_s=elapsed, progress=prog, action=action, ok=ok, detail=detail))
        logger.warning("chaos: fired %s (ok=%s, detail=%s)", action.label, ok, detail)
        if self.event_log is not None:
            try:
                from repro.observe import Event  # deferred: chaos stays importable without observe

                self.event_log.emit(Event(
                    t=now, kind="chaos", stage=action.kind,
                    info={"label": action.label, "ok": ok, "scope": action.scope,
                          "elapsed_s": elapsed, "progress": prog},
                ))
            except Exception:  # noqa: BLE001 - telemetry must never break injection
                logger.exception("chaos event emission failed")

    def _loop(self) -> None:
        pending = list(self.schedule.actions)
        while pending and not self._stop.is_set():
            elapsed = time.monotonic() - self._t0
            try:
                prog = float(self.progress())
            except Exception:  # noqa: BLE001
                prog = 0.0
            still: List[ChaosAction] = []
            for action in pending:
                if action.due(elapsed, prog):
                    self._fire(action, elapsed, prog)
                else:
                    still.append(action)
            pending = still
            if pending:
                self._stop.wait(self.poll_s)
        self._unfired = pending

    # ------------------------------------------------------------- lifecycle
    def start(self) -> "ChaosRunner":
        if self._thread is not None:
            return self
        self._t0 = time.monotonic()
        self._unfired: List[ChaosAction] = list(self.schedule.actions)
        self._thread = threading.Thread(target=self._loop, daemon=True, name="chaos-runner")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None

    @property
    def unfired(self) -> List[ChaosAction]:
        """Actions whose trigger never came (run ended first)."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("runner still active")
        return list(getattr(self, "_unfired", self.schedule.actions))
