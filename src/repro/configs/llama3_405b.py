"""Llama-3.1-405B: GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16_384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53_248,
    vocab_size=128_256,
    activation="swiglu",
    rope_theta=500_000.0,
    # 405B on 256 x v5e (16 GB HBM): FSDP+TP, 16 microbatches, factored
    # optimizer state in bf16, bf16 grad accumulation (see DESIGN.md).
    sharding="fsdp_tp",
    grad_accum=16,
    optimizer="adafactor",
    opt_state_dtype="bfloat16",
    grad_accum_dtype="bfloat16",
    remat="full",
))
