"""Phi-4-mini-3.8B: RoPE SwiGLU GQA. [arXiv:2412.08905; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200_064,
    activation="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    grad_accum=8,               # 200k-vocab logits need microbatching
    sharding="dp_tp",
))
