"""Architecture configs: one module per assigned architecture."""

from .base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    all_configs,
    get_config,
    register,
    shape_applicable,
    smoke_config,
)

_LOADED = False

ARCH_MODULES = [
    "qwen3_moe_30b_a3b",
    "phi35_moe_42b_a66b",
    "gemma_2b",
    "llama3_405b",
    "yi_6b",
    "phi4_mini_38b",
    "rwkv6_16b",
    "internvl2_1b",
    "recurrentgemma_2b",
    "whisper_large_v3",
]


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib

    for mod in ARCH_MODULES:
        importlib.import_module(f"{__name__}.{mod}")
    _LOADED = True


ARCH_IDS = [
    "qwen3-moe-30b-a3b",
    "phi3.5-moe-42b-a6.6b",
    "gemma-2b",
    "llama3-405b",
    "yi-6b",
    "phi4-mini-3.8b",
    "rwkv6-1.6b",
    "internvl2-1b",
    "recurrentgemma-2b",
    "whisper-large-v3",
]
