"""Yi-6B: llama-arch GQA. [arXiv:2403.04652; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11_008,
    vocab_size=64_000,
    activation="swiglu",
    rope_theta=5_000_000.0,
    grad_accum=16,
    sharding="dp_tp",
))
