"""Model/shape configuration system.

One ``ModelConfig`` per assigned architecture lives in a sibling module;
the registry maps ``--arch <id>`` to it. Shape suites (train_4k,
prefill_32k, decode_32k, long_500k) are defined here and paired with
every architecture; applicability rules (e.g. long_500k only for
sub-quadratic families) are encoded in ``shape_applicable``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | rwkv6 | griffin | whisper | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    activation: str = "swiglu"       # swiglu | geglu | gelu | relu_sq
    norm_eps: float = 1e-6
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    norm_offset: float = 0.0         # gemma stores rmsnorm weight as delta around 1
    embed_scale: bool = False        # gemma multiplies embeddings by sqrt(d)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "scatter"    # scatter (memory-light) | onehot (reference)
    router_aux_coef: float = 0.01

    # --- recurrent families --------------------------------------------------
    # griffin: block pattern repeats (recurrent, recurrent, local_attn)
    attn_every: int = 0              # 0 = all-attention; 3 = griffin 1:2 pattern
    local_window: int = 0            # sliding-window size for local attention
    conv_width: int = 4              # temporal conv in griffin recurrent block
    rwkv_head_dim: int = 64

    # --- enc-dec / multimodal -------------------------------------------------
    encoder_layers: int = 0          # whisper encoder depth
    encoder_seq: int = 1500          # stub frame count (whisper: 30 s @ 50 Hz)
    vision_patches: int = 0          # stub patch count (vlm)

    # --- numerics / distribution knobs (perf levers) --------------------------
    dtype: str = "bfloat16"
    remat: str = "full"              # none | selective | full
    scan_layers: bool = True
    grad_accum: int = 1              # microbatches per train step
    sharding: str = "dp_tp"          # dp_tp | fsdp_tp
    grad_accum_dtype: str = "float32"
    optimizer: str = "adamw"         # adamw | adafactor
    opt_state_dtype: str = "float32" # float32 | bfloat16 (memory lever)
    grad_compress: bool = False      # int8 DP gradient compression
    seq_shard_norm: bool = False     # sequence-sharded norms/embeddings (SP lever)

    # ------------------------------------------------------------------ utils
    def with_(self, **kwargs) -> "ModelConfig":
        return replace(self, **kwargs)

    @property
    def vocab_padded(self) -> int:
        """Embedding/unembedding table rows: padded to a multiple of 256 so
        the vocab dim always shards over the model axis (unpadded vocabs
        like whisper's 51866 otherwise REPLICATE every logit tensor)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_subquadratic(self) -> bool:
        """True when long-context decode is architecturally tractable."""
        return self.family in ("rwkv6", "griffin")

    @property
    def n_params(self) -> int:
        """Approximate total parameter count (embeddings included)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab_size
        H, KV, hd = self.n_heads, self.n_kv_heads, self.head_dim
        embed = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "rwkv6":
            per = 4 * d * d + 3 * d * f // 2 + 2 * d * f  # rough: tmix + cmix
            per = 4 * d * d + 2 * d * f
            return embed + L * per
        attn = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        if self.family == "moe":
            ff = self.n_experts * 3 * d * f + d * self.n_experts
        elif self.activation in ("swiglu", "geglu"):
            ff = 3 * d * f
        else:
            ff = 2 * d * f
        per = attn + ff
        total = embed + L * per
        if self.family == "whisper":
            total += self.encoder_layers * (attn + ff) + L * attn  # cross-attn
        return total

    @property
    def n_active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.n_params
        d, f, L = self.d_model, self.d_ff, self.n_layers
        H, KV, hd = self.n_heads, self.n_kv_heads, self.head_dim
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        ff = self.experts_per_token * 3 * d * f + d * self.n_experts
        return embed + L * (attn + ff)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable?, reason). long_500k only for sub-quadratic families."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full-attention arch: 500k-token decode state is quadratic-cost territory; skipped per assignment"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import _load_all  # populate registry lazily

    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    from . import _load_all

    _load_all()
    return dict(_REGISTRY)


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small depth/width,
    few experts, tiny vocab — exercises identical code paths."""
    cfg = get_config(name)
    reduced = dict(
        n_layers=2 if cfg.attn_every == 0 else 3,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        grad_accum=1,
        remat="none",
        scan_layers=cfg.scan_layers,
    )
    if cfg.family == "moe":
        reduced.update(n_experts=4, experts_per_token=2)
    if cfg.family == "whisper":
        reduced.update(encoder_layers=2, encoder_seq=32)
    if cfg.family == "vlm":
        reduced.update(vision_patches=8)
    if cfg.family == "griffin":
        reduced.update(local_window=16, n_layers=3)
    if cfg.family == "rwkv6":
        reduced.update(rwkv_head_dim=16)
    return cfg.with_(**reduced)
