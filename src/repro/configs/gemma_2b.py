"""Gemma-2B: GeGLU, head_dim=256, MQA. [arXiv:2403.08295; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,               # MQA
    head_dim=256,
    d_ff=16_384,
    vocab_size=256_000,
    activation="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    norm_offset=1.0,
    embed_scale=True,
    grad_accum=8,               # 256k-vocab logits need microbatching
    sharding="dp_tp",
))
