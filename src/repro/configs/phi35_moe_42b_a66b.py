"""Phi-3.5-MoE-instruct: 16-expert top-2 MoE. [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,                  # per-expert intermediate size
    vocab_size=32_064,
    activation="swiglu",
    rope_theta=10_000.0,
    n_experts=16,
    experts_per_token=2,
    grad_accum=8,
    sharding="dp_tp",
))
