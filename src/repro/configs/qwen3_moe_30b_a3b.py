"""Qwen3-30B-A3B: 128-expert top-8 MoE. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,                   # per-expert moe_intermediate_size
    vocab_size=151_936,
    activation="swiglu",
    rope_theta=1_000_000.0,
    n_experts=128,
    experts_per_token=8,
    grad_accum=8,
    sharding="dp_tp",
))
