"""RecurrentGemma-2B (Griffin): RG-LRU + local attention, 1:2. [arXiv:2402.19427; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="griffin",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,               # local attention is MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    activation="geglu",
    attn_every=3,               # (recurrent, recurrent, local_attn) repeating
    local_window=2048,
    conv_width=4,
    tie_embeddings=True,
    norm_offset=1.0,
    embed_scale=True,
    grad_accum=8,
    sharding="dp_tp",
))
