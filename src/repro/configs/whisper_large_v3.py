"""Whisper-large-v3 backbone: enc-dec, conv frontend stubbed. [arXiv:2212.04356; unverified]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    family="whisper",
    n_layers=32,                # decoder layers
    encoder_layers=32,
    encoder_seq=1500,           # 30 s of audio at 50 Hz (stub frame embeddings)
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,              # MHA
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    activation="gelu",
    norm_type="layernorm",
    grad_accum=4,
    sharding="dp_tp",
))
