"""RWKV-6 'Finch' 1.6B: attention-free, data-dependent decay. [arXiv:2404.05892; unverified]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="rwkv6",
    n_layers=24,
    d_model=2048,
    n_heads=32,                 # d_model / rwkv_head_dim
    n_kv_heads=32,
    head_dim=64,
    rwkv_head_dim=64,
    d_ff=7168,
    vocab_size=65_536,
    activation="relu_sq",       # rwkv channel mix uses squared relu
    grad_accum=4,
    sharding="dp_tp",
))
