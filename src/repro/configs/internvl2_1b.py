"""InternVL2-1B backbone: InternViT (stub) + Qwen2-0.5B LM. [arXiv:2404.16821; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_655,
    activation="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    vision_patches=256,          # stub: precomputed patch embeddings
    grad_accum=4,
    sharding="dp_tp",
))
