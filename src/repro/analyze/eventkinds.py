"""Rule ``event-kind``: the event vocabulary must be closed.

* every ``Event(kind=...)`` constructed anywhere — including the
  ``EventLog`` emit helpers — must use a kind declared in the
  ``EVENT_KINDS`` registry in ``observe/events.py``;
* every kind the observability consumers (``metrics.py``,
  ``report.py``, ``trace.py``) dispatch on must actually be emitted
  somewhere (directly or through an ``EventLog`` helper), else the
  consumer is dead code watching for an event that never fires.

The checker is corpus-wide: if no analyzed file declares
``EVENT_KINDS`` the rule reports that gap once and stops (there is no
registry to check against).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .engine import Corpus, SourceFile, Violation, expr_text

_CONSUMER_FILES = {"metrics.py", "report.py", "trace.py"}


def _find_registry(corpus: Corpus) -> Tuple[Optional[SourceFile], Set[str]]:
    for f in corpus.files:
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Assign):
                targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
            elif isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
                targets = [node.target.id]
            else:
                continue
            if "EVENT_KINDS" not in targets or node.value is None:
                continue
            kinds: Set[str] = set()
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    kinds.add(sub.value)
            return f, kinds
    return None, set()


def _event_emissions(corpus: Corpus) -> List[Tuple[SourceFile, int, str]]:
    """(file, line, kind) for every ``Event(kind="...")`` construction."""
    out = []
    for f in corpus.files:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            name = expr_text(node.func).rsplit(".", 1)[-1]
            if name != "Event":
                continue
            for kw in node.keywords:
                if kw.arg == "kind" and isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, str):
                    out.append((f, node.lineno, kw.value.value))
    return out


def _helper_kinds(corpus: Corpus) -> Dict[str, str]:
    """EventLog helper-method name -> the kind it emits."""
    out: Dict[str, str] = {}
    for f in corpus.files:
        for node in ast.walk(f.tree):
            if not (isinstance(node, ast.ClassDef) and node.name == "EventLog"):
                continue
            for m in node.body:
                if not isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                for sub in ast.walk(m):
                    if (isinstance(sub, ast.Call)
                            and expr_text(sub.func).rsplit(".", 1)[-1] == "Event"):
                        for kw in sub.keywords:
                            if kw.arg == "kind" and isinstance(kw.value, ast.Constant) \
                                    and isinstance(kw.value.value, str):
                                out[m.name] = kw.value.value
    return out


def _helper_calls(corpus: Corpus, helpers: Dict[str, str]) -> Set[str]:
    """Kinds emitted via ``<log>.helper(...)`` calls anywhere."""
    out: Set[str] = set()
    for f in corpus.files:
        for node in ast.walk(f.tree):
            if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
                    and node.func.attr in helpers):
                out.add(helpers[node.func.attr])
    return out


def _consumed_kinds(corpus: Corpus) -> List[Tuple[SourceFile, int, str]]:
    """(file, line, kind) for every ``<x>.kind == "..."`` /
    ``<x>.kind in (...)`` dispatch inside the consumer modules."""
    out = []
    for f in corpus.files:
        if f.name not in _CONSUMER_FILES:
            continue
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Compare):
                continue
            left = node.left
            if not (isinstance(left, ast.Attribute) and left.attr == "kind"):
                continue
            for op, comp in zip(node.ops, node.comparators):
                if isinstance(op, (ast.Eq, ast.NotEq)) and isinstance(comp, ast.Constant) \
                        and isinstance(comp.value, str):
                    out.append((f, node.lineno, comp.value))
                elif isinstance(op, (ast.In, ast.NotIn)):
                    for sub in ast.walk(comp):
                        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                            out.append((f, node.lineno, sub.value))
    return out


def check(corpus: Corpus) -> List[Violation]:
    registry_file, declared = _find_registry(corpus)
    emissions = _event_emissions(corpus)
    helpers = _helper_kinds(corpus)

    if registry_file is None:
        # Only meaningful when the events module is in scope at all.
        if not emissions and not helpers:
            return []
        f, line, _ = emissions[0] if emissions else (corpus.files[0], 1, "")
        return [Violation(
            rule="event-kind",
            path=f.path,
            line=line,
            symbol="EVENT_KINDS",
            message=("Event kinds are emitted but no EVENT_KINDS registry is "
                     "declared in the analyzed corpus (expected in observe/events.py)"),
        )]

    out: List[Violation] = []
    for f, line, kind in emissions:
        if kind not in declared:
            out.append(Violation(
                rule="event-kind",
                path=f.path,
                line=line,
                symbol=f"emit:{kind}",
                message=(f"Event kind {kind!r} is emitted but not declared in "
                         f"EVENT_KINDS ({registry_file.path}) — consumers will "
                         "file it under unknown_kinds"),
            ))

    emitted = {k for (_, _, k) in emissions} | _helper_calls(corpus, helpers) \
        | set(helpers.values())
    seen: Set[Tuple[str, str]] = set()
    for f, line, kind in _consumed_kinds(corpus):
        if kind in emitted or (f.path, kind) in seen:
            continue
        seen.add((f.path, kind))
        out.append(Violation(
            rule="event-kind",
            path=f.path,
            line=line,
            symbol=f"consume:{kind}",
            message=(f"{f.name} dispatches on event kind {kind!r}, but nothing "
                     "in the analyzed corpus emits it"),
        ))
    return out
