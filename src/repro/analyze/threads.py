"""Rule ``thread-lifecycle``: every started ``Thread`` must either be a
daemon or have a reachable join/stop path.

A non-daemon thread with no ``join`` anywhere in its owning scope keeps
the interpreter alive after the campaign finishes — the classic "soak
harness hangs at exit" failure. The rule accepts either:

* ``daemon=True`` spelled literally at construction (the repo idiom:
  daemon + an explicit stop event + join-with-timeout in ``stop()``), or
* a ``.join(...)`` call somewhere in the enclosing class (for threads
  created in methods) or module (for threads created at function/module
  scope) — the thread is fire-and-wait, not fire-and-forget.

``daemon=<expr>`` (e.g. ``daemon=self.daemon``) is treated as
not-literally-daemon and therefore requires the join path.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from .engine import Corpus, Violation, enclosing_qualname, expr_text


def _is_thread_ctor(call: ast.Call) -> bool:
    return expr_text(call.func) in ("threading.Thread", "Thread")


def _daemon_literal_true(call: ast.Call) -> bool:
    for kw in call.keywords:
        if kw.arg == "daemon":
            return isinstance(kw.value, ast.Constant) and kw.value.value is True
    return False


def _enclosing_class(tree: ast.Module, target: ast.AST) -> Optional[ast.ClassDef]:
    best: Optional[ast.ClassDef] = None
    for node in ast.walk(tree):
        if (isinstance(node, ast.ClassDef)
                and node.lineno <= target.lineno
                and getattr(node, "end_lineno", node.lineno) >= target.lineno):
            if best is None or node.lineno > best.lineno:
                best = node
    return best


def _has_join(scope: ast.AST) -> bool:
    for node in ast.walk(scope):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
                and node.func.attr == "join"
                # exclude ", ".join(...) — a string-literal receiver is str.join
                and not isinstance(node.func.value, ast.Constant)):
            return True
    return False


def check(corpus: Corpus) -> List[Violation]:
    out: List[Violation] = []
    for f in corpus.files:
        for node in ast.walk(f.tree):
            if not (isinstance(node, ast.Call) and _is_thread_ctor(node)):
                continue
            if _daemon_literal_true(node):
                continue
            scope: ast.AST = _enclosing_class(f.tree, node) or f.tree
            if _has_join(scope):
                continue
            where = enclosing_qualname(f.tree, node)
            out.append(Violation(
                rule="thread-lifecycle",
                path=f.path,
                line=node.lineno,
                symbol=where,
                message=(
                    f"{where}: Thread started without daemon=True and with no "
                    "join path in its owning scope — it will outlive the "
                    "campaign; mark it daemon (with a stop event) or join it"
                ),
            ))
    return out
