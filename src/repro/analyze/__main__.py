"""CLI: ``python -m repro.analyze [PATHS] [options]``.

Exit status: 0 when clean (or when not ``--fail-on-violation``),
1 when live violations remain, 2 on usage errors.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from .engine import all_checkers, analyze_paths, load_baseline, write_baseline


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="Project-invariant static analysis for the repro codebase.",
    )
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to analyze (default: src/repro)")
    ap.add_argument("--fail-on-violation", action="store_true",
                    help="exit 1 if any live violation remains")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="JSON baseline of accepted findings (with reasons)")
    ap.add_argument("--write-baseline", default=None, metavar="FILE",
                    help="write current live findings as a new baseline and exit")
    ap.add_argument("--rule", action="append", dest="rules", metavar="RULE",
                    help="run only this rule (repeatable); default: all of "
                         + ", ".join(sorted(all_checkers())))
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the per-rule summary, print violations only")
    args = ap.parse_args(argv)

    try:
        result = analyze_paths(args.paths or ["src/repro"],
                               baseline=args.baseline, rules=args.rules)
    except (FileNotFoundError, ValueError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2

    if args.write_baseline:
        reasons = load_baseline(args.write_baseline)
        write_baseline(args.write_baseline, result.violations + result.baselined,
                       reasons=reasons)
        print(f"wrote {len(result.violations) + len(result.baselined)} entries "
              f"to {args.write_baseline}")
        return 0

    for v in result.violations:
        print(v.render())
    if not args.quiet:
        print(
            f"analyze: {len(result.violations)} violation(s), "
            f"{len(result.suppressed)} suppressed inline, "
            f"{len(result.baselined)} baselined",
            file=sys.stderr,
        )
        if result.stale_baseline:
            print("analyze: stale baseline entries (no longer fire, prune them):",
                  file=sys.stderr)
            for fp in result.stale_baseline:
                print(f"  {fp}", file=sys.stderr)
    if result.violations and args.fail_on_violation:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
