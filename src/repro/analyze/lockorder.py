"""Rule ``lock-order``: extract the static lock-acquisition graph and
flag potential deadlock cycles.

Lock nodes
    Attributes assigned ``threading.Lock()/RLock()/Condition()`` inside
    a class (``self._lock = threading.Lock()`` → node ``Class._lock``)
    or at module scope (node ``module:NAME``). Acquisitions through a
    *different* receiver (``pool._lock``, ``self.agg._lock``) become
    textual nodes (``pool._lock``) — deliberately NOT unified with any
    class, because the receiver's type is unknown statically; merging
    every ``_lock`` in the codebase into one node would manufacture
    cycles that do not exist. The runtime sanitizer
    (``repro.analyze.runtime``) covers the orderings this heuristic
    cannot see.

Edges
    ``A -> B`` when B is acquired while A is held: lexically nested
    ``with`` blocks, ``x.acquire()`` inside a held region, and — one
    call level deep — ``self.method()`` calls where ``method`` of the
    same class (or a corpus base class) directly acquires another lock.

A strongly-connected component with more than one node (or a 2-cycle)
is a potential deadlock and is reported once per cycle with every
contributing acquisition site.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .engine import Corpus, SourceFile, Violation, expr_text

_LOCK_CTORS = {
    "threading.Lock", "threading.RLock", "threading.Condition",
    "Lock", "RLock", "Condition",
}


class _ClassInfo:
    def __init__(self, name: str, node: ast.ClassDef, src: SourceFile) -> None:
        self.name = name
        self.node = node
        self.src = src
        self.bases = [expr_text(b).split(".")[-1] for b in node.bases]
        self.lock_attrs: Set[str] = set()
        self.methods: Dict[str, ast.FunctionDef] = {}
        # method name -> lock keys it acquires directly (filled in pass 2)
        self.direct: Dict[str, Set[str]] = {}


def _collect_classes(corpus: Corpus) -> Dict[str, _ClassInfo]:
    classes: Dict[str, _ClassInfo] = {}
    for f in corpus.files:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            info = _ClassInfo(node.name, node, f)
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    info.methods[item.name] = item
                    for sub in ast.walk(item):
                        if (isinstance(sub, ast.Assign)
                                and isinstance(sub.value, ast.Call)
                                and expr_text(sub.value.func) in _LOCK_CTORS):
                            for tgt in sub.targets:
                                if (isinstance(tgt, ast.Attribute)
                                        and expr_text(tgt.value) == "self"):
                                    info.lock_attrs.add(tgt.attr)
                elif (isinstance(item, ast.Assign)
                      and isinstance(item.value, ast.Call)
                      and expr_text(item.value.func) in _LOCK_CTORS):
                    for tgt in item.targets:
                        if isinstance(tgt, ast.Name):
                            info.lock_attrs.add(tgt.id)
            # last definition wins on a name clash; fine for this codebase
            classes[node.name] = info
    return classes


def _own_and_inherited_lock_attrs(cls: _ClassInfo,
                                  classes: Dict[str, _ClassInfo]) -> Set[str]:
    out: Set[str] = set()
    seen: Set[str] = set()
    stack = [cls.name]
    while stack:
        name = stack.pop()
        if name in seen or name not in classes:
            continue
        seen.add(name)
        out |= classes[name].lock_attrs
        stack.extend(classes[name].bases)
    return out


def _lock_key(cls: Optional[_ClassInfo], classes: Dict[str, _ClassInfo],
              expr: ast.AST) -> Optional[str]:
    """Lock-graph node key for an acquired expression, or None if the
    expression is not a known lock."""
    text = expr_text(expr)
    if not text:
        return None
    if cls is not None and text.startswith("self."):
        attr = text[len("self."):]
        if "." not in attr:
            if attr in _own_and_inherited_lock_attrs(cls, classes):
                return f"{cls.name}.{attr}"
            return None
    # Non-self receiver (pool._lock, self.agg._lock): keep the receiver
    # text — unifying by attr name across classes fabricates cycles.
    leaf = text.rsplit(".", 1)[-1]
    looks_lockish = leaf.startswith("_") and (
        "lock" in leaf or "cond" in leaf or "mutex" in leaf
    )
    return text if looks_lockish else None


def _acquired_expr(node: ast.AST) -> Optional[ast.AST]:
    """The lock expression a statement acquires, if any: ``with X:`` items
    or ``X.acquire()``."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
            and node.func.attr == "acquire":
        return node.func.value
    return None


class _EdgeWalker(ast.NodeVisitor):
    """Walk one function body tracking the stack of held lock keys."""

    def __init__(self, cls: Optional[_ClassInfo], classes: Dict[str, _ClassInfo],
                 src: SourceFile, edges: Dict[Tuple[str, str], List[Tuple[str, int]]],
                 method_name: str = "") -> None:
        self.cls = cls
        self.classes = classes
        self.src = src
        self.edges = edges
        self.held: List[str] = []
        self.method_name = method_name

    # -- helpers
    def _on_acquire(self, key: str, line: int) -> None:
        for h in self.held:
            if h != key:
                self.edges.setdefault((h, key), []).append((self.src.path, line))

    def _class_method_direct(self, name: str) -> Set[str]:
        """Locks ``self.<name>()`` acquires directly (one level, corpus
        bases included)."""
        out: Set[str] = set()
        seen: Set[str] = set()
        stack = [self.cls.name] if self.cls else []
        while stack:
            cname = stack.pop()
            if cname in seen or cname not in self.classes:
                continue
            seen.add(cname)
            info = self.classes[cname]
            if name in info.direct:
                out |= info.direct[name]
                break  # closest definition in the MRO wins
            stack.extend(info.bases)
        return out

    # -- visitors
    def visit_With(self, node: ast.With) -> None:
        keys = []
        for item in node.items:
            key = _lock_key(self.cls, self.classes, item.context_expr)
            if key is not None:
                self._on_acquire(key, node.lineno)
                self.held.append(key)
                keys.append(key)
        for stmt in node.body:
            self.visit(stmt)
        for _ in keys:
            self.held.pop()

    def visit_Call(self, node: ast.Call) -> None:
        expr = _acquired_expr(node)
        if expr is not None:
            key = _lock_key(self.cls, self.classes, expr)
            if key is not None:
                self._on_acquire(key, node.lineno)
                # treat as held for the rest of the function (linear
                # approximation; release tracking is handled by `with`)
                self.held.append(key)
        elif (self.cls is not None and isinstance(node.func, ast.Attribute)
              and expr_text(node.func.value) == "self" and self.held):
            for key in self._class_method_direct(node.func.attr):
                self._on_acquire(key, node.lineno)
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        pass  # nested defs run later, with an empty held stack

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        pass


def _direct_locks(cls: _ClassInfo, classes: Dict[str, _ClassInfo],
                  fn: ast.FunctionDef) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        expr = None
        if isinstance(node, ast.With):
            for item in node.items:
                key = _lock_key(cls, classes, item.context_expr)
                if key:
                    out.add(key)
        else:
            expr = _acquired_expr(node)
            if expr is not None:
                key = _lock_key(cls, classes, expr)
                if key:
                    out.add(key)
    return out


def _find_cycles(edges: Dict[Tuple[str, str], List[Tuple[str, int]]]) -> List[List[str]]:
    """Strongly-connected components with a cycle (size > 1, or a
    self-referential pair A->B->A)."""
    graph: Dict[str, Set[str]] = {}
    for (a, b) in edges:
        graph.setdefault(a, set()).add(b)
        graph.setdefault(b, set())

    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Set[str] = set()
    stack: List[str] = []
    counter = [0]
    sccs: List[List[str]] = []

    def strongconnect(v: str) -> None:
        work = [(v, iter(sorted(graph[v])))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(sorted(graph[w]))))
                    advanced = True
                    break
                if w in on_stack:
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == index[node]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == node:
                        break
                if len(comp) > 1:
                    sccs.append(sorted(comp))

    for v in sorted(graph):
        if v not in index:
            strongconnect(v)
    return sccs


def build_lock_graph(corpus: Corpus) -> Dict[Tuple[str, str], List[Tuple[str, int]]]:
    """(from_key, to_key) -> [(path, line), ...] acquisition sites."""
    classes = _collect_classes(corpus)
    # pass 2a: per-method direct acquisitions (for one-level call expansion)
    for info in classes.values():
        for name, fn in info.methods.items():
            info.direct[name] = _direct_locks(info, classes, fn)

    edges: Dict[Tuple[str, str], List[Tuple[str, int]]] = {}
    for f in corpus.files:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            info = classes.get(node.name)
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    walker = _EdgeWalker(info, classes, f, edges, item.name)
                    for stmt in item.body:
                        walker.visit(stmt)
        # module-level functions
        for item in f.tree.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                walker = _EdgeWalker(None, classes, f, edges)
                for stmt in item.body:
                    walker.visit(stmt)
    return edges


def check(corpus: Corpus) -> List[Violation]:
    edges = build_lock_graph(corpus)
    out: List[Violation] = []
    for cycle in _find_cycles(edges):
        cset = set(cycle)
        sites = sorted({
            f"{p}:{ln} ({a} -> {b})"
            for (a, b), locs in edges.items()
            if a in cset and b in cset
            for (p, ln) in locs
        })
        path, line = "", 0
        for (a, b), locs in sorted(edges.items()):
            if a in cset and b in cset:
                path, line = locs[0]
                break
        out.append(Violation(
            rule="lock-order",
            path=path,
            line=line,
            symbol="<->".join(cycle),
            message=(
                "potential deadlock cycle in the lock-acquisition graph: "
                + " <-> ".join(cycle) + "; sites: " + "; ".join(sites)
            ),
        ))
    return out
