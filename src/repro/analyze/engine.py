"""Checker engine: file corpus, suppression comments, baseline, report.

The engine parses every ``.py`` file under the given paths once into a
``Corpus`` and hands the whole corpus to each checker — several rules
(lock-order, event-kind, spec round-trip, pickle-boundary) are
cross-file by nature, so per-file visitors would miss exactly the bugs
they exist to catch.

Suppression layers, innermost first:

* **inline** — ``# analyze: ignore[rule]`` (or ``ignore[rule1,rule2]``,
  or ``ignore[*]``) on the flagged line or the line directly above it;
* **baseline** — a committed JSON file mapping violation fingerprints
  to reason strings. Fingerprints deliberately exclude line numbers so
  unrelated edits don't churn the baseline.
"""

from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence

_SUPPRESS_RE = re.compile(r"#\s*analyze:\s*ignore\[([\w\-*,\s]+)\]")


@dataclass
class Violation:
    """One finding. ``symbol`` is a stable identifier (qualname, lock
    cycle, field name) used for baseline fingerprinting instead of the
    line number."""

    rule: str
    path: str
    line: int
    message: str
    symbol: str = ""

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}:{self.path}:{self.symbol or self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class SourceFile:
    path: str        # as given (repo-relative when invoked from the repo root)
    source: str
    tree: ast.Module
    suppressions: Dict[int, set] = field(default_factory=dict)  # line -> rules ('*' = all)

    @property
    def name(self) -> str:
        return os.path.basename(self.path)

    def suppressed(self, rule: str, line: int) -> bool:
        for ln in (line, line - 1):
            rules = self.suppressions.get(ln)
            if rules and ("*" in rules or rule in rules):
                return True
        return False


class Corpus:
    """Every parsed file plus shared lookups checkers need."""

    def __init__(self, files: Sequence[SourceFile]) -> None:
        self.files = list(files)
        self.by_name: Dict[str, List[SourceFile]] = {}
        for f in self.files:
            self.by_name.setdefault(f.name, []).append(f)

    def find(self, suffix: str) -> Optional[SourceFile]:
        """The unique file whose path ends with ``suffix`` (None if absent)."""
        norm = suffix.replace("\\", "/")
        hits = [f for f in self.files if f.path.replace("\\", "/").endswith(norm)]
        return hits[0] if len(hits) == 1 else None


def _scan_suppressions(source: str) -> Dict[int, set]:
    out: Dict[int, set] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def collect_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d != "__pycache__")
                out.extend(os.path.join(root, n) for n in sorted(names) if n.endswith(".py"))
        else:
            raise FileNotFoundError(f"no such file or directory: {p}")
    return out


def build_corpus(paths: Iterable[str]) -> Corpus:
    files: List[SourceFile] = []
    for path in collect_files(paths):
        with open(path, encoding="utf-8") as f:
            source = f.read()
        tree = ast.parse(source, filename=path)
        files.append(SourceFile(path=path, source=source, tree=tree,
                                suppressions=_scan_suppressions(source)))
    return Corpus(files)


# --------------------------------------------------------------------------
# Baseline
# --------------------------------------------------------------------------


def load_baseline(path: str) -> Dict[str, str]:
    """fingerprint -> reason. Tolerates a missing file (empty baseline)."""
    if not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    entries = doc.get("entries", []) if isinstance(doc, dict) else doc
    out: Dict[str, str] = {}
    for e in entries:
        out[e["fingerprint"]] = e.get("reason", "")
    return out


def write_baseline(path: str, violations: Sequence[Violation],
                   reasons: Optional[Dict[str, str]] = None) -> None:
    reasons = reasons or {}
    entries = [
        {
            "fingerprint": v.fingerprint,
            "rule": v.rule,
            "path": v.path,
            "reason": reasons.get(v.fingerprint, "TODO: justify or fix"),
        }
        for v in sorted(violations, key=lambda v: v.fingerprint)
    ]
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"version": 1, "entries": entries}, f, indent=2)
        f.write("\n")


# --------------------------------------------------------------------------
# Run
# --------------------------------------------------------------------------


@dataclass
class AnalysisResult:
    violations: List[Violation]          # live findings (not suppressed/baselined)
    suppressed: List[Violation]          # killed by inline comments
    baselined: List[Violation]           # killed by the baseline file
    stale_baseline: List[str]            # baseline fingerprints that no longer fire

    @property
    def ok(self) -> bool:
        return not self.violations


Checker = Callable[[Corpus], List[Violation]]


def all_checkers() -> Dict[str, Checker]:
    from . import busywait, eventkinds, lockorder, pickleboundary, roundtrip, threads

    return {
        "busy-wait": busywait.check,
        "lock-order": lockorder.check,
        "pickle-boundary": pickleboundary.check,
        "event-kind": eventkinds.check,
        "spec-roundtrip": roundtrip.check,
        "thread-lifecycle": threads.check,
    }


def analyze_paths(paths: Iterable[str], baseline: Optional[str] = None,
                  rules: Optional[Iterable[str]] = None) -> AnalysisResult:
    corpus = build_corpus(paths)
    checkers = all_checkers()
    if rules is not None:
        unknown = set(rules) - set(checkers)
        if unknown:
            raise ValueError(f"unknown rules: {sorted(unknown)} (have {sorted(checkers)})")
        checkers = {r: checkers[r] for r in rules}

    raw: List[Violation] = []
    for fn in checkers.values():
        raw.extend(fn(corpus))
    raw.sort(key=lambda v: (v.path, v.line, v.rule))

    by_path = {f.path: f for f in corpus.files}
    base = load_baseline(baseline) if baseline else {}

    live: List[Violation] = []
    suppressed: List[Violation] = []
    baselined: List[Violation] = []
    fired_fps = set()
    for v in raw:
        fired_fps.add(v.fingerprint)
        sf = by_path.get(v.path)
        if sf is not None and sf.suppressed(v.rule, v.line):
            suppressed.append(v)
        elif v.fingerprint in base:
            baselined.append(v)
        else:
            live.append(v)
    stale = sorted(fp for fp in base if fp not in fired_fps)
    return AnalysisResult(violations=live, suppressed=suppressed,
                          baselined=baselined, stale_baseline=stale)


# --------------------------------------------------------------------------
# Shared AST helpers used by several checkers
# --------------------------------------------------------------------------


def walk_scope(node: ast.AST) -> Iterable[ast.AST]:
    """Yield nodes in ``node``'s body without descending into nested
    function/class definitions (loop bodies, with-blocks etc. are
    traversed)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Lambda)):
            stack.extend(ast.iter_child_nodes(n))


def is_call_to(node: ast.AST, dotted: str) -> bool:
    """True for ``Call`` nodes spelled exactly ``a.b(...)`` or, for a
    bare name, ``b(...)``."""
    if not isinstance(node, ast.Call):
        return False
    return expr_text(node.func) == dotted


def expr_text(node: ast.AST) -> str:
    """Dotted-name text of simple expressions ('' for anything complex)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = expr_text(node.value)
        return f"{base}.{node.attr}" if base else ""
    return ""


def qualname_index(tree: ast.Module) -> Dict[ast.AST, str]:
    """Map every function/class def node to its dotted qualname."""
    out: Dict[ast.AST, str] = {}

    def visit(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                q = f"{prefix}.{child.name}" if prefix else child.name
                out[child] = q
                visit(child, q)
            else:
                visit(child, prefix)

    visit(tree, "")
    return out


def enclosing_qualname(tree: ast.Module, target: ast.AST) -> str:
    """Qualname of the innermost def/class containing ``target``
    ('<module>' at top level)."""
    index = qualname_index(tree)
    best = "<module>"
    best_span = None
    for node, q in index.items():
        if (node.lineno <= target.lineno
                and getattr(node, "end_lineno", node.lineno) >= getattr(target, "end_lineno", target.lineno)):
            span = getattr(node, "end_lineno", node.lineno) - node.lineno
            if best_span is None or span < best_span:
                best, best_span = q, span
    return best
