"""Runtime lock sanitizer: a mini-TSan for the steering stack.

Under ``REPRO_LOCK_SANITIZER=1`` (installed by ``tests/conftest.py``),
every ``threading.Lock``/``RLock``/``Condition`` **created by repro
code** is wrapped so real acquisitions are recorded into a global
lock-order graph: an edge ``A -> B`` means some thread acquired B while
holding A. At session end the graph must be acyclic — a cycle is a
lock-order inversion that static analysis (``repro.analyze``'s
``lock-order`` rule) may not see, because the static checker
deliberately refuses to unify same-named lock attributes across
classes.

Locks are keyed by *creation site* (``file:line``), so every instance
created at one site is one graph node — exactly the granularity the
static graph uses. Locks created outside the repro package (stdlib,
third-party) are left untouched: they are returned raw, cost nothing,
and cannot pollute the graph.

The wrappers implement the full ``Condition`` protocol
(``_release_save``/``_acquire_restore``/``_is_owned``) so a traced
RLock works as a Condition's inner lock, and ``threading.Condition()``
called with no lock from repro code gets a traced RLock injected.
``threading.Event`` is NOT patched: ``repro.core.thinker.WakeEvent``
subclasses it, and a factory function cannot be subclassed.
"""

from __future__ import annotations

import os
import sys
import threading
import traceback
from typing import Dict, List, Optional, Set, Tuple

import _thread

ENV_FLAG = "REPRO_LOCK_SANITIZER"

_REPRO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class LockGraph:
    """Acquisition-order edges keyed by lock creation site."""

    def __init__(self) -> None:
        # raw lock: the graph must never recurse into its own tracing
        self._glock = _thread.allocate_lock()
        self._local = threading.local()
        # (from_site, to_site) -> (count, example traceback summary)
        self.edges: Dict[Tuple[str, str], Tuple[int, str]] = {}
        self.acquisitions = 0

    # ------------------------------------------------------------- recording
    def _held(self) -> List[str]:
        held = getattr(self._local, "held", None)
        if held is None:
            held = self._local.held = []
        return held

    def on_acquire(self, site: str) -> None:
        held = self._held()
        self.acquisitions += 1
        if held:
            stack: Optional[str] = None
            with self._glock:
                for h in held:
                    if h == site:
                        continue  # re-entrant RLock acquire: not an ordering
                    key = (h, site)
                    prev = self.edges.get(key)
                    if prev is None:
                        if stack is None:
                            stack = "".join(traceback.format_stack(limit=8)[:-2])
                        self.edges[key] = (1, stack)
                    else:
                        self.edges[key] = (prev[0] + 1, prev[1])
        held.append(site)

    def on_release(self, site: str) -> None:
        held = self._held()
        # release order may differ from acquire order: drop the last match
        for i in range(len(held) - 1, -1, -1):
            if held[i] == site:
                del held[i]
                return

    # ------------------------------------------------------------- analysis
    def find_cycles(self) -> List[List[str]]:
        """Strongly-connected components of size > 1 (each is a cycle)."""
        with self._glock:
            keys = list(self.edges)
        graph: Dict[str, Set[str]] = {}
        for a, b in keys:
            graph.setdefault(a, set()).add(b)
            graph.setdefault(b, set())

        index: Dict[str, int] = {}
        low: Dict[str, int] = {}
        on_stack: Set[str] = set()
        stack: List[str] = []
        counter = [0]
        sccs: List[List[str]] = []

        def connect(v: str) -> None:
            work = [(v, iter(sorted(graph[v])))]
            index[v] = low[v] = counter[0]
            counter[0] += 1
            stack.append(v)
            on_stack.add(v)
            while work:
                node, it = work[-1]
                advanced = False
                for w in it:
                    if w not in index:
                        index[w] = low[w] = counter[0]
                        counter[0] += 1
                        stack.append(w)
                        on_stack.add(w)
                        work.append((w, iter(sorted(graph[w]))))
                        advanced = True
                        break
                    if w in on_stack:
                        low[node] = min(low[node], index[w])
                if advanced:
                    continue
                work.pop()
                if work:
                    low[work[-1][0]] = min(low[work[-1][0]], low[node])
                if low[node] == index[node]:
                    comp = []
                    while True:
                        w = stack.pop()
                        on_stack.discard(w)
                        comp.append(w)
                        if w == node:
                            break
                    if len(comp) > 1:
                        sccs.append(sorted(comp))

        for v in sorted(graph):
            if v not in index:
                connect(v)
        return sccs

    def report_cycles(self) -> str:
        lines = []
        for cycle in self.find_cycles():
            cset = set(cycle)
            lines.append("lock-order cycle: " + " <-> ".join(cycle))
            with self._glock:
                for (a, b), (count, stack) in sorted(self.edges.items()):
                    if a in cset and b in cset:
                        lines.append(f"  {a} -> {b} (seen {count}x); first acquisition:")
                        lines.extend("    " + ln for ln in stack.rstrip().splitlines())
        return "\n".join(lines)

    def assert_acyclic(self) -> None:
        cycles = self.find_cycles()
        if cycles:
            raise AssertionError(
                "runtime lock sanitizer found lock-order inversion(s):\n"
                + self.report_cycles()
            )


_GLOBAL = LockGraph()


def graph() -> LockGraph:
    return _GLOBAL


# --------------------------------------------------------------------------
# Traced wrappers
# --------------------------------------------------------------------------


class _TracedLockBase:
    __slots__ = ("_inner", "_site", "_graph")

    def __init__(self, inner, site: str, graph: LockGraph) -> None:
        self._inner = inner
        self._site = site
        self._graph = graph

    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._graph.on_acquire(self._site)
        return got

    def release(self) -> None:
        self._graph.on_release(self._site)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def _at_fork_reinit(self) -> None:  # pragma: no cover - fork safety
        self._inner._at_fork_reinit()

    def __repr__(self) -> str:
        return f"<traced {self._inner!r} from {self._site}>"


class TracedLock(_TracedLockBase):
    """threading.Lock wrapper (Condition uses its plain acquire/release)."""


class TracedRLock(_TracedLockBase):
    """threading.RLock wrapper implementing the Condition inner-lock
    protocol; ``wait()`` fully releases, so tracing must mirror it."""

    def _release_save(self):
        self._graph.on_release(self._site)
        return self._inner._release_save()

    def _acquire_restore(self, state) -> None:
        self._inner._acquire_restore(state)
        self._graph.on_acquire(self._site)

    def _is_owned(self) -> bool:
        return self._inner._is_owned()

    def _recursion_count(self) -> int:  # pragma: no cover - 3.12+ API
        return self._inner._recursion_count()


# --------------------------------------------------------------------------
# Patching
# --------------------------------------------------------------------------

_originals: Dict[str, object] = {}


def _caller_site(depth: int = 2) -> Optional[str]:
    """``file:line`` of the factory's caller when it lives under the
    repro package; None otherwise (lock stays untraced)."""
    try:
        frame = sys._getframe(depth)
    except ValueError:  # pragma: no cover
        return None
    path = frame.f_code.co_filename
    if not os.path.abspath(path).startswith(_REPRO_ROOT):
        return None
    rel = os.path.relpath(path, os.path.dirname(_REPRO_ROOT))
    return f"{rel}:{frame.f_lineno}"


def installed() -> bool:
    return bool(_originals)


def install(graph: Optional[LockGraph] = None) -> None:
    """Patch ``threading.Lock/RLock/Condition`` so repro-created locks
    are traced into ``graph`` (the global graph by default). Idempotent."""
    if _originals:
        return
    g = graph if graph is not None else _GLOBAL
    orig_lock = threading.Lock
    orig_rlock = threading.RLock
    orig_condition = threading.Condition
    _originals.update(Lock=orig_lock, RLock=orig_rlock, Condition=orig_condition)

    def traced_lock():
        site = _caller_site()
        inner = orig_lock()
        return TracedLock(inner, site, g) if site else inner

    def traced_rlock():
        site = _caller_site()
        inner = orig_rlock()
        return TracedRLock(inner, site, g) if site else inner

    def traced_condition(lock=None):
        if lock is None:
            site = _caller_site()
            if site:
                lock = TracedRLock(orig_rlock(), site, g)
        return orig_condition(lock)

    threading.Lock = traced_lock
    threading.RLock = traced_rlock
    threading.Condition = traced_condition


def uninstall() -> None:
    """Restore the original factories. Locks created while installed
    stay traced (they keep recording into their graph)."""
    if not _originals:
        return
    threading.Lock = _originals.pop("Lock")
    threading.RLock = _originals.pop("RLock")
    threading.Condition = _originals.pop("Condition")


def install_from_env() -> bool:
    """Install when ``REPRO_LOCK_SANITIZER=1``; returns whether installed."""
    if os.environ.get(ENV_FLAG) == "1":
        install()
        return True
    return False
