"""Rule ``busy-wait``: a ``while`` loop that spins on ``time.sleep``.

The steering stack's contract (PR 1) is that waiting threads *park* on
a ``Condition``/``Event`` and are woken by the producer — a loop that
re-checks state every ``sleep(poll)`` burns a core, adds up to a full
poll interval of latency per hop, and cannot be interrupted by
``stop()``. A loop passes when it blocks on a real wakeup primitive
(``<event>.wait(timeout)``, ``<cond>.wait(...)``, a blocking
``queue.get``) instead of sleeping.

A second, softer form is also flagged: a loop whose wait *is* an
``Event.wait`` but with a sub-100 ms constant timeout (or the
``_POLL_S`` module constant) — spinning at 50 Hz on an event that a
producer could subscribe to instead (the ``WakeEvent`` idiom).
Deliberate short-poll fallbacks are expected to carry an inline
suppression or a baseline entry explaining why polling is required.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from .engine import Corpus, Violation, enclosing_qualname, expr_text, walk_scope

_WAKEUP_ATTRS = {"wait", "wait_for", "get", "acquire", "join", "select"}
_POLL_NAMES = {"_POLL_S", "POLL_S", "_POLL"}
_SHORT_POLL_S = 0.1


def _sleep_calls(loop: ast.While) -> List[ast.Call]:
    out = []
    for n in walk_scope(loop):
        if isinstance(n, ast.Call) and expr_text(n.func) in ("time.sleep", "sleep"):
            out.append(n)
    return out


def _has_wakeup(loop: ast.While) -> bool:
    for n in [loop.test, *walk_scope(loop)]:
        for sub in ast.walk(n):
            if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in _WAKEUP_ATTRS):
                return True
    return False


def _short_poll_wait(loop: ast.While) -> Optional[ast.Call]:
    """A ``<x>.wait(t)`` call in the loop with a provably short timeout."""
    for n in walk_scope(loop):
        if not (isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
                and n.func.attr == "wait"):
            continue
        arg = None
        if n.args:
            arg = n.args[0]
        for kw in n.keywords:
            if kw.arg == "timeout":
                arg = kw.value
        if isinstance(arg, ast.Constant) and isinstance(arg.value, (int, float)) \
                and 0 < arg.value < _SHORT_POLL_S:
            return n
        if isinstance(arg, ast.Name) and arg.id in _POLL_NAMES:
            return n
    return None


def check(corpus: Corpus) -> List[Violation]:
    out: List[Violation] = []
    for f in corpus.files:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.While):
                continue
            sleeps = _sleep_calls(node)
            where = enclosing_qualname(f.tree, node)
            if sleeps and not _has_wakeup(node):
                out.append(Violation(
                    rule="busy-wait",
                    path=f.path,
                    line=sleeps[0].lineno,
                    symbol=where,
                    message=(
                        f"{where}: while-loop polls with time.sleep and no "
                        "Condition/Event wakeup — park on <event>.wait(timeout) "
                        "(or a stop event) so producers and stop() can interrupt it"
                    ),
                ))
                continue
            poll = _short_poll_wait(node)
            if poll is not None:
                out.append(Violation(
                    rule="busy-wait",
                    path=f.path,
                    line=poll.lineno,
                    symbol=f"{where}:short-poll",
                    message=(
                        f"{where}: while-loop spins on a sub-{int(_SHORT_POLL_S * 1000)} ms "
                        "event poll — subscribe the waiter (WakeEvent/Condition) "
                        "so the producer wakes it, or suppress with the reason "
                        "polling is required"
                    ),
                ))
    return out
