"""Rule ``pickle-boundary``: classes shipped across process boundaries
must not carry unpicklable attributes unless ``__getstate__``/
``__reduce__`` handles them.

Boundary classes are (a) anything that already defines
``__getstate__``/``__setstate__``/``__reduce__`` (it has declared
itself picklable-with-care), (b) the known payload classes named in
``_BOUNDARY_NAMES`` (results, specs, chaos links — the objects pipe
queues and ``ProcessTaskServer`` actually serialize), and (c) classes
whose name ends in ``Spec`` or ``Policy`` (the spec vocabulary is
defined as picklable).

Risky attributes are assignments of ``threading.Lock/RLock/Condition/
Event/Thread``, ``lambda``s, and ``open(...)`` handles. An attribute is
*handled* when its name appears as a string constant inside the class's
(or a corpus base class's) ``__getstate__``/``__setstate__`` — the
``state.pop("_lock")`` idiom — or when the class defines ``__reduce__``.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from .engine import Corpus, SourceFile, Violation, expr_text

_RISKY_CTORS = {
    "threading.Lock", "threading.RLock", "threading.Condition",
    "threading.Event", "threading.Thread",
    "Lock", "RLock", "Condition", "Event", "Thread",
}

_BOUNDARY_NAMES = {
    "Result", "PoolSpec", "ChaosLink", "FailureInjector", "TaskDef",
    "TraceContext", "ResourceRequest", "Timestamps", "TimingInfo",
}

_STATE_METHODS = {"__getstate__", "__setstate__"}
_REDUCE_METHODS = {"__reduce__", "__reduce_ex__"}


class _Cls:
    def __init__(self, node: ast.ClassDef, src: SourceFile) -> None:
        self.node = node
        self.src = src
        self.name = node.name
        self.bases = [expr_text(b).split(".")[-1] for b in node.bases]
        self.methods = {
            m.name: m for m in node.body
            if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        # attr -> (line, what) for risky assignments
        self.risky: Dict[str, Tuple[int, str]] = {}
        for m in self.methods.values():
            for sub in ast.walk(m):
                if isinstance(sub, ast.Assign):
                    what = _risky_value(sub.value)
                    if what is None:
                        continue
                    for tgt in sub.targets:
                        if isinstance(tgt, ast.Attribute) and expr_text(tgt.value) == "self":
                            self.risky[tgt.attr] = (sub.lineno, what)

    def handled_names(self) -> Set[str]:
        """String constants inside this class's own state methods."""
        out: Set[str] = set()
        for name in _STATE_METHODS:
            fn = self.methods.get(name)
            if fn is None:
                continue
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    out.add(sub.value)
        return out

    def has_reduce(self) -> bool:
        return bool(_REDUCE_METHODS & set(self.methods))

    def has_state_hooks(self) -> bool:
        return bool(_STATE_METHODS & set(self.methods))


def _risky_value(value: ast.AST) -> "str | None":
    if isinstance(value, ast.Call):
        fn = expr_text(value.func)
        if fn in _RISKY_CTORS:
            return fn
        if fn == "open":
            return "open(...) file handle"
    if isinstance(value, ast.Lambda):
        return "lambda"
    return None


def _is_boundary(cls: _Cls) -> bool:
    return (cls.name in _BOUNDARY_NAMES
            or cls.name.endswith("Spec")
            or cls.name.endswith("Policy")
            or cls.has_state_hooks()
            or cls.has_reduce())


def check(corpus: Corpus) -> List[Violation]:
    classes: Dict[str, _Cls] = {}
    for f in corpus.files:
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ClassDef):
                classes[node.name] = _Cls(node, f)

    def mro_handled(cls: _Cls) -> Set[str]:
        out: Set[str] = set()
        seen: Set[str] = set()
        stack = [cls.name]
        while stack:
            name = stack.pop()
            if name in seen or name not in classes:
                continue
            seen.add(name)
            out |= classes[name].handled_names()
            stack.extend(classes[name].bases)
        return out

    def mro_reduce(cls: _Cls) -> bool:
        seen: Set[str] = set()
        stack = [cls.name]
        while stack:
            name = stack.pop()
            if name in seen or name not in classes:
                continue
            seen.add(name)
            if classes[name].has_reduce():
                return True
            stack.extend(classes[name].bases)
        return False

    out: List[Violation] = []
    for cls in classes.values():
        if not _is_boundary(cls) or not cls.risky:
            continue
        if mro_reduce(cls):
            continue
        handled = mro_handled(cls)
        for attr, (line, what) in sorted(cls.risky.items()):
            if attr in handled:
                continue
            out.append(Violation(
                rule="pickle-boundary",
                path=cls.src.path,
                line=line,
                symbol=f"{cls.name}.{attr}",
                message=(
                    f"{cls.name}.{attr} holds a {what}, but {cls.name} crosses "
                    "a process boundary and its __getstate__ does not drop or "
                    "rebuild it — pickling will fail (or ship a dead lock)"
                ),
            ))
    return out
