"""Project-invariant static analysis for the repro codebase.

``repro.analyze`` is a purpose-built AST checker suite: each rule
encodes a concurrency or serialization invariant this repo has already
paid a bug for (busy-wait poll loops, inconsistent lock ordering,
unpicklable attrs shipped across process boundaries, undeclared event
kinds, spec fields silently dropped by the TOML round-trip, leaked
threads). Run it as::

    python -m repro.analyze src/repro --fail-on-violation \
        --baseline analyze-baseline.json

Findings are suppressed either inline (``# analyze: ignore[rule]``, on
the flagged line or the line above) or via a committed baseline file
whose entries carry a human reason string.

``repro.analyze.runtime`` is the dynamic complement: a lock sanitizer
that (under ``REPRO_LOCK_SANITIZER=1``) instruments every
``threading.Lock/RLock/Condition`` created by repro code, records the
real acquisition-order graph, and asserts it stays acyclic — a
mini-TSan for the steering stack that tier-1 runs once with in CI.
"""

from .engine import (
    AnalysisResult,
    Corpus,
    SourceFile,
    Violation,
    all_checkers,
    analyze_paths,
    load_baseline,
    write_baseline,
)

__all__ = [
    "AnalysisResult",
    "Corpus",
    "SourceFile",
    "Violation",
    "all_checkers",
    "analyze_paths",
    "load_baseline",
    "write_baseline",
]
