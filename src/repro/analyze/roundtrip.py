"""Rule ``spec-roundtrip``: every field on the spec dataclasses that
``specfile.py`` serializes must be *handled* by the to/from-dict code.

"Handled" means the field name appears in the serialization surface:
as a dict-key string constant, an attribute access, or a keyword
argument inside ``specfile.py`` — or inside the class's own
``to_dict``/``from_dict`` methods (the ``PoolSpec`` pattern, which
specfile delegates to). A field that appears nowhere is silently
dropped on save and silently defaulted on load: exactly the bug class
PR 8 hit when new knobs were added by hand.

The set of audited classes is discovered, not hard-coded: every
capitalized name *called* inside ``specfile.py`` that resolves to a
dataclass in the corpus (plus anything specfile touches through
``X.from_dict``).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .engine import Corpus, SourceFile, Violation, expr_text


def _find_specfile(corpus: Corpus) -> Optional[SourceFile]:
    direct = corpus.find("core/specfile.py")
    if direct is not None:
        return direct
    for f in corpus.files:
        names = {n.name for n in ast.walk(f.tree) if isinstance(n, ast.FunctionDef)}
        if {"spec_to_dict", "spec_from_dict"} <= names:
            return f
    return None


def _dataclasses(corpus: Corpus) -> Dict[str, Tuple[SourceFile, ast.ClassDef]]:
    out: Dict[str, Tuple[SourceFile, ast.ClassDef]] = {}
    for f in corpus.files:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for dec in node.decorator_list:
                name = expr_text(dec if not isinstance(dec, ast.Call) else dec.func)
                if name.rsplit(".", 1)[-1] == "dataclass":
                    out[node.name] = (f, node)
                    break
    return out


def _fields(node: ast.ClassDef) -> List[Tuple[str, int]]:
    out = []
    for item in node.body:
        if isinstance(item, ast.AnnAssign) and isinstance(item.target, ast.Name):
            name = item.target.id
            if not name.startswith("_") and name.isupper() is False:
                out.append((name, item.lineno))
    return out


def _mentioned_names(*scopes: ast.AST) -> Set[str]:
    """Strings, attribute names, and keyword-arg names in the scopes."""
    out: Set[str] = set()
    for scope in scopes:
        for node in ast.walk(scope):
            if isinstance(node, ast.Constant) and isinstance(node.value, str):
                out.add(node.value)
            elif isinstance(node, ast.Attribute):
                out.add(node.attr)
            elif isinstance(node, ast.keyword) and node.arg:
                out.add(node.arg)
    return out


def _audited_classes(specfile: SourceFile,
                     dataclasses: Dict[str, Tuple[SourceFile, ast.ClassDef]]) -> Set[str]:
    audited: Set[str] = set()
    for node in ast.walk(specfile.tree):
        if not isinstance(node, ast.Call):
            continue
        text = expr_text(node.func)
        if not text:
            continue
        leaf = text.rsplit(".", 1)[-1]
        if leaf in dataclasses:
            audited.add(leaf)
        elif leaf in ("from_dict", "to_dict"):
            owner = text.rsplit(".", 2)[-2] if text.count(".") >= 1 else ""
            if owner in dataclasses:
                audited.add(owner)
    return audited


def check(corpus: Corpus) -> List[Violation]:
    specfile = _find_specfile(corpus)
    if specfile is None:
        return []  # specfile not in the analyzed set: nothing to audit against
    dcs = _dataclasses(corpus)
    audited = _audited_classes(specfile, dcs)

    handled_global = _mentioned_names(specfile.tree)
    out: List[Violation] = []
    for cname in sorted(audited):
        src, node = dcs[cname]
        own_serializers = [
            m for m in node.body
            if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))
            and m.name in ("to_dict", "from_dict")
        ]
        handled = handled_global | _mentioned_names(*own_serializers)
        for fname, line in _fields(node):
            if fname in handled:
                continue
            out.append(Violation(
                rule="spec-roundtrip",
                path=src.path,
                line=line,
                symbol=f"{cname}.{fname}",
                message=(
                    f"{cname}.{fname} is never mentioned by specfile.py (or "
                    f"{cname}.to_dict/from_dict): the field is silently dropped "
                    "on save and silently defaulted on load — serialize it or "
                    "reject it explicitly"
                ),
            ))
    return out
