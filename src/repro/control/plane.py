"""The campaign control plane: many campaigns, one shared fleet.

``ControlPlane`` is the long-lived daemon behind ``python -m
repro.control serve``. It owns:

* a durable :class:`~repro.control.state.StateStore` of campaign records
  (crash-safe; ``recover()`` re-stages interrupted campaigns on boot);
* a scheduler tick that apportions the shared fleet's slots across
  schedulable campaigns by weighted fair share with priority preemption
  (:mod:`repro.control.scheduler`);
* one runner thread per running campaign, each hosting a full
  :class:`~repro.core.app.ColmenaApp` built from the submitted spec with
  its managed pool sizes overridden to the current grant — pause is
  ``app.pause()`` (checkpoint + release every slot), resume is a fresh
  app with ``resume=True`` (checkpoint + journal replay).

The HTTP API lives in :mod:`repro.control.api`; this module is fully
usable in-process (the tests drive it directly).
"""

from __future__ import annotations

import dataclasses
import logging
import os
import threading
import time
from typing import Any, Dict, List, Optional

from repro.observe import EventLog

from . import scheduler as fair
from .state import (
    DONE,
    FAILED,
    PAUSED,
    RUNNING,
    STAGED,
    SUBMITTED,
    CampaignRecord,
    StateStore,
)

logger = logging.getLogger("repro.control.plane")


def _load_toml_text(text: str) -> Dict[str, Any]:
    try:
        import tomllib  # Python >= 3.11
    except ModuleNotFoundError:  # pragma: no cover - 3.10 path
        import tomli as tomllib
    return tomllib.loads(text)


class _Runner:
    """Hosts one running campaign's ColmenaApp on its own thread."""

    def __init__(self, plane: "ControlPlane", rec: CampaignRecord, grant: Dict[str, int]) -> None:
        self.plane = plane
        self.cid = rec.id
        self.grant = dict(grant)
        self.app: Optional[Any] = None
        self.pause_evt = threading.Event()
        self.pause_reason = "preempted"
        self.done_evt = threading.Event()
        self.outcome: Optional[str] = None
        self.error: Optional[str] = None
        self.thread = threading.Thread(
            target=self._run, daemon=True, name=f"campaign-{self.cid}"
        )

    def start(self) -> "_Runner":
        self.thread.start()
        return self

    def request_pause(self, reason: str) -> None:
        self.pause_reason = reason
        self.pause_evt.set()

    def apply_grant(self, grant: Dict[str, int]) -> None:
        """Live-resize the app's managed pools to a new grant."""
        app = self.app
        if app is None:
            return
        for pool, target in grant.items():
            live = app.pools.get(pool)
            if live is None or live.n_workers == target:
                continue
            old, new = live.resize(target)
            if new != old and app.event_log is not None:
                app.event_log.pool_resize(pool, old, new, reason="fair-share")
        self.grant = dict(grant)

    def _run(self) -> None:
        try:
            app = self.plane._build_app(self.cid, self.grant)
            self.app = app
            app.start()
            while True:
                if self.pause_evt.is_set():
                    app.pause()
                    self.outcome = "paused"
                    break
                if app.wait(timeout=0.2):
                    exc = app.thinker_exception
                    if exc is not None:
                        self.outcome, self.error = "failed", f"{type(exc).__name__}: {exc}"
                    else:
                        self.outcome = "done"
                    app.stop()
                    break
        except Exception as exc:  # noqa: BLE001 - a runner crash is a campaign failure
            logger.exception("campaign %s runner crashed", self.cid)
            self.outcome, self.error = "failed", f"{type(exc).__name__}: {exc}"
        finally:
            self.done_evt.set()
            self.plane._on_runner_exit(self)


class ControlPlane:
    """Persistent multi-campaign scheduler over one shared fleet."""

    def __init__(
        self,
        root: str,
        fleet: Dict[str, int],
        tick_s: float = 0.5,
        event_log: Optional[EventLog] = None,
    ) -> None:
        if not fleet:
            raise ValueError("the control plane needs a non-empty fleet ({pool: slots})")
        self.root = root
        self.fleet = {str(k): int(v) for k, v in fleet.items()}
        self.tick_s = max(0.1, tick_s)
        os.makedirs(root, exist_ok=True)
        self.store = StateStore(root)
        self.accounting = fair.FleetAccounting(os.path.join(root, "fleet_accounting.json"))
        self.event_log = event_log or EventLog(
            jsonl_path=os.path.join(root, "plane-events.jsonl")
        )
        self._runners: Dict[str, _Runner] = {}
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._tick_thread: Optional[threading.Thread] = None
        self._last_tick: Optional[float] = None
        self.started_at = time.time()

    # -------------------------------------------------------------- lifecycle
    def start(self) -> "ControlPlane":
        restaged = self.store.recover()
        for rec in restaged:
            self.event_log.campaign_state(rec.name, STAGED, id=rec.id, reason="crash-recovery")
        if restaged:
            logger.info("recovered %d interrupted campaign(s)", len(restaged))
        self._tick_thread = threading.Thread(
            target=self._tick_loop, daemon=True, name="control-plane-tick"
        )
        self._tick_thread.start()
        return self

    def stop(self, pause_running: bool = True) -> None:
        self._stop.set()
        if self._tick_thread is not None:
            self._tick_thread.join(timeout=10)
        runners = list(self._runners.values())
        if pause_running:
            for r in runners:
                r.request_pause("daemon stop")
        for r in runners:
            r.done_evt.wait(timeout=15)

    # ----------------------------------------------------------------- submit
    def submit(self, spec_text: str, name: Optional[str] = None) -> CampaignRecord:
        """Validate and durably admit one campaign TOML; returns its record
        already ``staged`` (the next tick schedules it)."""
        from repro.core.specfile import spec_from_dict

        try:
            d = _load_toml_text(spec_text)
        except Exception as exc:  # noqa: BLE001 - surface as a 400, not a 500
            raise ValueError(f"invalid campaign spec: {exc}") from exc
        d.pop("smoke", None)
        # The daemon owns durable state placement; a submitted spec may
        # omit [campaign] (or its state_dir) entirely.
        camp = dict(d.get("campaign", {}))
        camp.setdefault("state_dir", "state")  # placeholder; overridden per-campaign
        d["campaign"] = camp
        try:
            spec = spec_from_dict(d)  # fail fast: bad specs never enter the store
        except Exception as exc:  # noqa: BLE001 - surface as a 400, not a 500
            raise ValueError(f"invalid campaign spec: {exc}") from exc
        if not spec.server.in_process:
            raise ValueError(
                "control-plane campaigns run in_process servers; remote sites "
                "are reached through the queue control channel instead"
            )
        ctl = spec.control
        demand: Dict[str, int] = {}
        # Demand counts only pools the submission itself declares (or
        # routes tasks to) — AppSpec normalization adds a "default" pool
        # that an all-custom-pool campaign never touches.
        declared = set(d.get("pools", {})) or set(spec.pools or {})
        for pname, ps in (spec.pools or {}).items():
            if pname in self.fleet and pname in declared:
                demand[pname] = ps.size
        for td in spec.tasks:
            pool = getattr(td, "pool", "default")
            if pool in self.fleet:
                demand.setdefault(pool, 1)
        if ctl is not None and ctl.demand is not None:
            demand = {p: min(v, ctl.demand) for p, v in demand.items()}
        if not demand:
            raise ValueError(
                f"campaign demands no fleet pool (fleet: {sorted(self.fleet)})"
            )
        with self._lock:
            rec = self.store.create(
                name or (spec.campaign.name if spec.campaign else "campaign"),
                spec_text,
                weight=ctl.weight if ctl else 1.0,
                priority=ctl.priority if ctl else 0,
                min_slots=ctl.min_slots if ctl else 1,
                demand=demand,
            )
            self.event_log.campaign_state(rec.name, SUBMITTED, id=rec.id)
            self._transition(rec.id, STAGED, reason="admitted")
        logger.info("campaign %s (%s) submitted: demand=%s", rec.id, rec.name, demand)
        return rec

    # ------------------------------------------------------------ pause/resume
    def pause(self, cid: str, wait_s: float = 15.0) -> CampaignRecord:
        """Operator pause: checkpoint + release slots; stays paused across
        daemon restarts until resumed."""
        with self._lock:
            rec = self.store.get(cid)
            self.store.set_paused_by_user(cid, True)
            if rec.state in (SUBMITTED, STAGED):
                return self._transition(cid, PAUSED, reason="user")
            if rec.state != RUNNING:
                return rec
            runner = self._runners.get(cid)
        if runner is not None:
            runner.request_pause("user")
            runner.done_evt.wait(timeout=wait_s)
        return self.store.get(cid)

    def resume(self, cid: str) -> CampaignRecord:
        with self._lock:
            rec = self.store.get(cid)
            if rec.state != PAUSED:
                return rec
            self.store.set_paused_by_user(cid, False)
            return self._transition(cid, STAGED, reason="user resume")

    # ------------------------------------------------------------------ status
    def status(self) -> Dict[str, Any]:
        with self._lock:
            records = self.store.list()
            grants = fair.compute_grants(records, self.fleet, self._schedulable_states())
            return {
                "uptime_s": round(time.time() - self.started_at, 3),
                "fleet": dict(self.fleet),
                "campaigns": [
                    {**r.to_dict(), "grant": grants.get(r.id, {})} for r in records
                ],
                "accounting": self.accounting.report(),
            }

    # ------------------------------------------------------------------- tick
    @staticmethod
    def _schedulable_states() -> List[str]:
        return [STAGED, RUNNING, PAUSED]

    def _tick_loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.tick()
            except Exception:  # noqa: BLE001 - one bad tick must not kill the daemon
                logger.exception("control-plane tick failed")
            self._stop.wait(self.tick_s)

    def tick(self) -> None:
        now = time.monotonic()
        with self._lock:
            dt = 0.0 if self._last_tick is None else now - self._last_tick
            self._last_tick = now
            records = self.store.list()
            # Auto-paused campaigns stay in the grant computation: they
            # re-stage the moment contention eases enough to meet their
            # floor (deterministic apportionment -> no flapping).
            schedulable = [
                r for r in records
                if r.state in (STAGED, RUNNING)
                or (r.state == PAUSED and not r.paused_by_user)
            ]
            grants = fair.compute_grants(schedulable, self.fleet, self._schedulable_states())
            for rec in records:
                grant = grants.get(rec.id, {})
                if rec.state == RUNNING:
                    runner = self._runners.get(rec.id)
                    if runner is None or runner.done_evt.is_set():
                        continue  # exit path owns the transition
                    if not fair.meets_floor(rec, grant):
                        runner.request_pause("preempted")
                    elif grant != runner.grant:
                        runner.apply_grant(grant)
                        self.event_log.gauge(
                            "campaign_slots", fair.total_slots(grant), campaign=rec.id
                        )
                elif rec.state == PAUSED and not rec.paused_by_user:
                    if fair.meets_floor(rec, grant):
                        self._transition(rec.id, STAGED, reason="capacity freed")
                        rec = self.store.get(rec.id)
                if rec.state == STAGED and fair.meets_floor(rec, grant):
                    self._launch(rec, grant)
            self.accounting.observe(schedulable, grants, self.fleet, dt)

    def _launch(self, rec: CampaignRecord, grant: Dict[str, int]) -> None:
        self._transition(rec.id, RUNNING, reason=f"granted {grant}")
        self.event_log.gauge("campaign_slots", fair.total_slots(grant), campaign=rec.id)
        self._runners[rec.id] = _Runner(self, rec, grant).start()

    def _on_runner_exit(self, runner: _Runner) -> None:
        with self._lock:
            self._runners.pop(runner.cid, None)
            try:
                rec = self.store.get(runner.cid)
            except KeyError:
                return
            if rec.state != RUNNING:
                return
            if runner.outcome == "done":
                self._transition(runner.cid, DONE, reason="completed")
            elif runner.outcome == "paused":
                self._transition(runner.cid, PAUSED, reason=runner.pause_reason)
                self.event_log.gauge("campaign_slots", 0, campaign=runner.cid)
            else:
                self._transition(
                    runner.cid, FAILED, reason="runner exit", error=runner.error
                )

    def _transition(self, cid: str, state: str, *, reason: str = "", error: Optional[str] = None) -> CampaignRecord:
        rec = self.store.transition(cid, state, reason=reason, error=error)
        self.event_log.campaign_state(rec.name, state, id=cid, reason=reason)
        return rec

    # ------------------------------------------------------------- app build
    def _build_app(self, cid: str, grant: Dict[str, int]) -> Any:
        from repro.core.app import CampaignSpec, ColmenaApp
        from repro.core.executors import PoolSpec
        from repro.core.specfile import spec_from_dict

        rec = self.store.get(cid)
        with open(self.store.spec_path(cid)) as f:
            d = _load_toml_text(f.read())
        d.pop("smoke", None)
        camp = dict(d.get("campaign", {}))
        camp.setdefault("state_dir", "state")  # placeholder; replaced below
        d["campaign"] = camp
        spec = spec_from_dict(d)
        # Durable state lives with the record; resume always on — a first
        # run simply finds no checkpoint.
        spec.campaign = CampaignSpec(
            state_dir=self.store.state_dir(cid),
            checkpoint_interval_s=(
                spec.campaign.checkpoint_interval_s if spec.campaign else 2.0
            ),
            name=rec.name,
            resume=True,
        )
        # Managed pools run at their granted size, elastic within the
        # fleet's band so later ticks can live-resize without a restart.
        for pool, slots in grant.items():
            base = spec.pools.get(pool) or PoolSpec(pool, max(1, slots))
            spec.pools[pool] = dataclasses.replace(
                base,
                size=max(1, slots),
                min_size=0,
                max_size=max(self.fleet.get(pool, slots), slots, base.size),
            )
        return ColmenaApp(spec)


__all__ = ["ControlPlane"]
