"""Weighted fair-share scheduling of campaigns over a shared fleet.

The fleet is ``{pool_name: capacity}`` — the site's slot budget, shared
by every campaign the daemon runs (the paper's many-campaign facilities
multiplex one allocation). Per pool:

1. **Priority classes** strictly dominate: a higher ``priority`` class
   takes all the slots it demands before a lower class sees any.
2. Within a class, slots are apportioned by **D'Hondt highest-averages**
   on campaign ``weight``: repeatedly grant one slot to the campaign
   maximizing ``weight / (granted + 1)`` among those still under their
   demand. This converges to grants proportional to weight while staying
   integral and work-conserving (unused demand flows to whoever wants it).
3. ``min_slots`` floors are **reserved** when the class's floors fit in
   the capacity (apportionment then shapes only the surplus). When they
   don't fit, the weakest claims (lowest weight) are evicted to **zero**
   until the surviving floors do — the control plane pauses the evicted
   campaigns (preemption) rather than letting them crawl below their
   floor.

``FleetAccounting`` integrates grant-seconds against weight-share-seconds
*while the pool is contended* (total demand > capacity) so a benchmark
can assert "each campaign's realized share stayed within X% of its
weight" — the fair-share gate.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Iterable, List, Optional, Tuple

from .state import PAUSED, RUNNING, STAGED, CampaignRecord

Grants = Dict[str, Dict[str, int]]  # campaign id -> {pool: slots}


def _dhondt(
    entries: List[Tuple[str, float, int]],
    capacity: int,
    floors: Optional[Dict[str, int]] = None,
) -> Dict[str, int]:
    """Apportion ``capacity`` slots over ``(id, weight, demand)`` entries
    by highest averages, optionally seeding each campaign's grant at its
    ``floors`` reservation (callers guarantee the floors fit). The seeded
    slots count toward the quotients, so the proportional shape is
    preserved above the floors. Deterministic: ties break by id."""
    grants = {cid: 0 for cid, _, _ in entries}
    demand = {cid: d for cid, _, d in entries}
    weight = {cid: w for cid, w, _ in entries}
    if floors:
        for cid in grants:
            grants[cid] = min(floors.get(cid, 0), demand[cid])
        capacity -= sum(grants.values())
    for _ in range(max(0, capacity)):
        best: Optional[str] = None
        best_q = -1.0
        for cid in sorted(grants):
            if grants[cid] >= demand[cid]:
                continue
            q = weight[cid] / (grants[cid] + 1)
            if q > best_q:
                best, best_q = cid, q
        if best is None:
            break  # all demand satisfied
        grants[best] += 1
    return grants


def compute_grants(
    records: Iterable[CampaignRecord],
    fleet: Dict[str, int],
    schedulable: Iterable[str] = (STAGED, RUNNING),
) -> Grants:
    """Slot grants for every schedulable campaign over every fleet pool."""
    schedulable = set(schedulable)
    active = [r for r in records if r.state in schedulable]
    grants: Grants = {r.id: {} for r in active}
    for pool, capacity in fleet.items():
        wanting = [r for r in active if r.demand.get(pool, 0) > 0]
        remaining = capacity
        # Strict priority: higher classes are apportioned first out of
        # whatever the classes above them left behind.
        for prio in sorted({r.priority for r in wanting}, reverse=True):
            klass = [r for r in wanting if r.priority == prio]
            # min_slots floors are reserved when they fit; when the class's
            # floors together exceed capacity, the weakest claims (lowest
            # weight, id tiebreak) are evicted to zero until they do — the
            # control plane pauses those rather than letting them crawl.
            evicted: List[str] = []
            while klass and sum(
                min(r.min_slots, r.demand[pool]) for r in klass
            ) > remaining:
                evict = min(klass, key=lambda r: (r.weight, r.id))
                evicted.append(evict.id)
                klass = [r for r in klass if r.id != evict.id]
            entries = [(r.id, r.weight, r.demand[pool]) for r in klass]
            floors = {r.id: min(r.min_slots, r.demand[pool]) for r in klass}
            pool_grants = _dhondt(entries, remaining, floors)
            for cid in evicted:
                pool_grants[cid] = 0
            used = 0
            for r in [x for x in wanting if x.priority == prio]:
                g = pool_grants.get(r.id, 0)
                grants[r.id][pool] = g
                used += g
            remaining -= used
            if remaining <= 0:
                break
        # Pools a campaign wants but got nothing from still appear (0),
        # so callers can distinguish "denied" from "never asked".
        for r in active:
            if r.demand.get(pool, 0) > 0:
                grants[r.id].setdefault(pool, 0)
    return grants


def total_slots(grant: Dict[str, int]) -> int:
    return sum(grant.values())


def meets_floor(rec: CampaignRecord, grant: Dict[str, int]) -> bool:
    """A campaign can (keep) run(ning) only when every pool it demands
    grants at least ``min_slots`` — a starved pool stalls the whole
    campaign, so partial grants are preemptions, not progress."""
    if not rec.demand:
        return False
    return all(
        grant.get(pool, 0) >= min(rec.min_slots, want)
        for pool, want in rec.demand.items()
        if want > 0
    )


class FleetAccounting:
    """Integrate realized vs. entitled slot-share per campaign while the
    fleet is contended; persisted so a restarted daemon keeps the ledger.
    """

    def __init__(self, path: Optional[str] = None) -> None:
        self.path = path
        self._lock = threading.Lock()
        # cid -> {"actual": slot-seconds granted, "expected": slot-seconds
        # entitled by weight share, "contended_s": seconds under contention}
        self.shares: Dict[str, Dict[str, float]] = {}
        if path and os.path.exists(path):
            try:
                with open(path) as f:
                    self.shares = {k: dict(v) for k, v in json.load(f).items()}
            except Exception:  # noqa: BLE001 - accounting is advisory, start fresh
                self.shares = {}

    def observe(
        self,
        records: List[CampaignRecord],
        grants: Grants,
        fleet: Dict[str, int],
        dt: float,
    ) -> None:
        if dt <= 0:
            return
        by_id = {r.id: r for r in records}
        with self._lock:
            for pool, capacity in fleet.items():
                wanting = [
                    r for r in records
                    if r.state in (STAGED, RUNNING, PAUSED) and r.demand.get(pool, 0) > 0
                ]
                demand_total = sum(r.demand[pool] for r in wanting)
                if demand_total <= capacity or not wanting:
                    continue  # uncontended: any split is fair
                granted_total = sum(
                    min(grants.get(r.id, {}).get(pool, 0), r.demand[pool]) for r in wanting
                )
                weight_total = sum(r.weight for r in wanting)
                for r in wanting:
                    cell = self.shares.setdefault(
                        r.id, {"actual": 0.0, "expected": 0.0, "contended_s": 0.0}
                    )
                    cell["actual"] += grants.get(r.id, {}).get(pool, 0) * dt
                    cell["expected"] += (r.weight / weight_total) * granted_total * dt
                    cell["contended_s"] += dt
            self._persist(by_id)

    def _persist(self, by_id: Dict[str, CampaignRecord]) -> None:
        if not self.path:
            return
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.shares, f, indent=2, sort_keys=True)
        os.replace(tmp, self.path)

    def report(self) -> Dict[str, Any]:
        """Per-campaign realized/entitled slot-seconds and the relative
        error ``|actual - expected| / expected`` (None until contended)."""
        with self._lock:
            out: Dict[str, Any] = {}
            for cid, cell in self.shares.items():
                expected = cell["expected"]
                err = abs(cell["actual"] - expected) / expected if expected > 0 else None
                out[cid] = {**cell, "share_error": err}
            return out


__all__ = [
    "FleetAccounting",
    "Grants",
    "compute_grants",
    "meets_floor",
    "total_slots",
]
