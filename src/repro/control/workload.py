"""A journaled, exactly-once counted workload for control-plane campaigns.

``CountedWorkload`` drives ``n_tasks`` integer tasks through the queues
with at most ``n_parallel`` in flight, reusing the chaos tier's
:class:`~repro.chaos.soak.WorkLedger` for exactly-once acceptance, and
adds the piece a SIGKILLed daemon needs: a **results journal**. Campaign
checkpoints are periodic, so the ledger state they capture is a *prefix*
of the truth; every accepted result is also appended to
``results.jsonl`` at accept time. On resume the journal replays over the
restored checkpoint, re-marking anything accepted after the last
checkpoint — so a crash loses zero results and re-runs only work that
genuinely never delivered (tasks are idempotent, per the paper).

Used by the control-plane tests/benchmark as the steering section of a
submitted campaign::

    [steering]
    thinker = "repro.control.workload.make_workload"
    [steering.kwargs]
    n_tasks = 120
    n_parallel = 8
    task_s = 0.01
"""

from __future__ import annotations

import collections
import json
import logging
import os
import time
from typing import Any, Dict, Optional

from repro.chaos.soak import WorkLedger
from repro.core import Result
from repro.core.thinker import BaseThinker, agent, result_processor

logger = logging.getLogger("repro.control.workload")


def workload_task(x: int, task_s: float = 0.0) -> int:
    """Module-level (pickles into spawned sites); output is a checkable
    function of the input, matching ``WorkLedger``'s payload check."""
    if task_s > 0:
        time.sleep(task_s)
    return x * 3 + 1


class CountedWorkload(BaseThinker):
    """Submit/accept loop over a ``WorkLedger`` with a durable journal."""

    def __init__(
        self,
        queues: Any,
        n_tasks: int,
        n_parallel: int = 4,
        journal_path: Optional[str] = None,
        task_s: float = 0.0,
        method: str = "workload_task",
        resubmit_after_s: float = 30.0,
    ) -> None:
        super().__init__(queues)
        self.ledger = WorkLedger(n_tasks, resubmit_after_s=resubmit_after_s)
        self.n_parallel = n_parallel
        self.journal_path = journal_path
        self.task_s = task_s
        self.method = method

    # ------------------------------------------------------------ checkpoint
    def get_state(self) -> Dict[str, Any]:
        return self.ledger.get_state()

    def set_state(self, state: Dict[str, Any]) -> None:
        self.ledger.set_state(state)

    # --------------------------------------------------------------- journal
    def _journal(self, index: int, task_id: str) -> None:
        if not self.journal_path:
            return
        with open(self.journal_path, "a") as f:
            f.write(json.dumps({"index": index, "task_id": task_id}) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def replay_journal(self) -> int:
        """Re-mark journal entries over the (checkpoint-restored) ledger.

        Idempotent: already-done indices are skipped, so checkpoint and
        journal can overlap arbitrarily. Returns how many entries were
        newer than the checkpoint."""
        if not self.journal_path or not os.path.exists(self.journal_path):
            return 0
        led = self.ledger
        replayed = 0
        with open(self.journal_path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    entry = json.loads(line)
                    index = int(entry["index"])
                except (ValueError, KeyError):
                    continue  # torn tail line from a mid-append SIGKILL
                with led._lock:
                    if 0 <= index < led.n_tasks and not led.done[index]:
                        led.done[index] = 1
                        led.completed += 1
                        led.next_fresh = max(led.next_fresh, index + 1)
                        replayed += 1
        # Rebuild the retry queue: everything handed out before the crash
        # that never journaled a delivery goes back to the front.
        with led._lock:
            led.retry_q = collections.deque(
                i for i in range(led.next_fresh) if not led.done[i]
            )
        if replayed:
            logger.info("journal replay recovered %d results past the checkpoint", replayed)
        return replayed

    # ---------------------------------------------------------------- agents
    @agent(startup=True)
    def recover(self) -> None:
        self.replay_journal()

    def _submit(self, index: int) -> None:
        task_id = self.queues.send_inputs(
            index,
            keyword_args={"task_s": self.task_s} if self.task_s else None,
            method=self.method,
            task_info={"index": index},
        )
        self.ledger.on_submitted(index, "fleet", task_id, time.monotonic())

    @agent
    def driver(self) -> None:
        """Top-up loop: keeps ``n_parallel`` in flight and recycles
        overdue work; the hot path (submit-on-accept) lives in
        ``accept`` so throughput is not tick-bound."""
        led = self.ledger
        while not self.done.is_set():
            if led.completed >= led.n_tasks:
                return  # critical agent exit -> thinker shuts down
            led.overdue(time.monotonic())
            want = self.n_parallel - len(led.inflight)
            for index in led.take(max(0, want)):
                self._submit(index)
            self.done.wait(0.2)

    @result_processor
    def accept(self, result: Result) -> None:
        status = self.ledger.accept(result)
        if status == "accepted":
            self._journal(result.task_info["index"], result.task_id)
        if self.ledger.completed >= self.ledger.n_tasks:
            self.done.set()
            return
        if status in ("accepted", "failed") and not self.done.is_set():
            for index in self.ledger.take(1):
                self._submit(index)


def make_workload(app: Any, **kwargs: Any) -> CountedWorkload:
    """SteeringSpec factory: journal defaults to ``results.jsonl`` next
    to the campaign checkpoints so the control plane's per-campaign
    ``state/`` override places it automatically."""
    if "journal_path" not in kwargs:
        state_dir = app.spec.campaign.state_dir if app.spec.campaign else None
        if state_dir:
            os.makedirs(state_dir, exist_ok=True)
            kwargs["journal_path"] = os.path.join(state_dir, "results.jsonl")
    return CountedWorkload(app.queues, **kwargs)


__all__ = ["CountedWorkload", "make_workload", "workload_task"]
