"""HTTP API for the control plane (stdlib http.server, same idiom as
``repro.observe.ops``).

  ==============================  =======================================
  ``GET  /``                        endpoint index
  ``GET  /healthz``                 daemon liveness + campaign counts
  ``GET  /campaigns``               every campaign record + current grant
  ``GET  /campaigns/<id>``          one record
  ``POST /campaigns?name=<n>``      submit (body: campaign TOML) -> 201
  ``POST /campaigns/<id>/pause``    checkpoint + release slots
  ``POST /campaigns/<id>/resume``   re-stage a paused campaign
  ``GET  /fleet``                   fleet capacities + fair-share ledger
  ==============================  =======================================

``port=0`` binds an ephemeral port (read ``.port``/``.url`` back) — the
right default for tests and multi-daemon hosts.
"""

from __future__ import annotations

import json
import logging
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional
from urllib.parse import parse_qs, urlparse

from .plane import ControlPlane
from .state import IllegalTransition

logger = logging.getLogger("repro.control.api")


class ControlServer:
    """Serve one ControlPlane over HTTP from a daemon thread."""

    def __init__(self, plane: ControlPlane, host: str = "127.0.0.1", port: int = 0) -> None:
        self.plane = plane
        self.host = host
        self.port = port
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- lifecycle
    def start(self) -> "ControlServer":
        if self._httpd is not None:
            return self
        server = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt: str, *args: Any) -> None:  # noqa: N802
                logger.debug("api: %s", fmt % args)

            def do_GET(self) -> None:  # noqa: N802
                server._safe_route(self)

            def do_POST(self) -> None:  # noqa: N802
                server._safe_route(self)

        self._httpd = ThreadingHTTPServer((self.host, self.port), Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.1},
            daemon=True, name="control-api",
        )
        self._thread.start()
        logger.info("control plane serving on http://%s:%d", self.host, self.port)
        return self

    def stop(self) -> None:
        httpd, self._httpd = self._httpd, None
        if httpd is not None:
            httpd.shutdown()
            httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    # --------------------------------------------------------------- routing
    def _safe_route(self, req: BaseHTTPRequestHandler) -> None:
        try:
            self._route(req)
        except BrokenPipeError:
            pass  # client went away mid-response
        except (KeyError,) as exc:
            self._send_json(req, 404, {"error": str(exc)})
        except (ValueError, IllegalTransition) as exc:
            self._send_json(req, 400, {"error": str(exc)})
        except Exception:  # noqa: BLE001 - one bad request must not kill serving
            logger.exception("control api request %s failed", req.path)
            try:
                req.send_error(500)
            except Exception:  # noqa: BLE001
                pass

    def _route(self, req: BaseHTTPRequestHandler) -> None:
        url = urlparse(req.path)
        parts = [p for p in url.path.split("/") if p]
        query = {k: v[-1] for k, v in parse_qs(url.query).items()}
        plane = self.plane

        if req.command == "GET":
            if not parts:
                self._send_json(req, 200, {
                    "endpoints": [
                        "/healthz", "/campaigns", "/campaigns/<id>",
                        "POST /campaigns", "POST /campaigns/<id>/pause",
                        "POST /campaigns/<id>/resume", "/fleet",
                    ],
                })
            elif parts == ["healthz"]:
                status = plane.status()
                counts: Dict[str, int] = {}
                for c in status["campaigns"]:
                    counts[c["state"]] = counts.get(c["state"], 0) + 1
                self._send_json(req, 200, {
                    "ok": True, "uptime_s": status["uptime_s"], "campaigns": counts,
                })
            elif parts == ["campaigns"]:
                self._send_json(req, 200, {"campaigns": plane.status()["campaigns"]})
            elif len(parts) == 2 and parts[0] == "campaigns":
                for c in plane.status()["campaigns"]:
                    if c["id"] == parts[1]:
                        self._send_json(req, 200, c)
                        return
                raise KeyError(f"unknown campaign {parts[1]!r}")
            elif parts == ["fleet"]:
                status = plane.status()
                self._send_json(req, 200, {
                    "fleet": status["fleet"], "accounting": status["accounting"],
                })
            else:
                self._send_json(req, 404, {"error": f"unknown path {url.path!r}"})
            return

        # POST
        if parts == ["campaigns"]:
            length = int(req.headers.get("Content-Length", 0))
            body = req.rfile.read(length).decode("utf-8")
            if not body.strip():
                raise ValueError("empty submission body (expected campaign TOML)")
            rec = plane.submit(body, name=query.get("name"))
            self._send_json(req, 201, rec.to_dict())
        elif len(parts) == 3 and parts[0] == "campaigns" and parts[2] == "pause":
            rec = plane.pause(parts[1])
            self._send_json(req, 200, rec.to_dict())
        elif len(parts) == 3 and parts[0] == "campaigns" and parts[2] == "resume":
            rec = plane.resume(parts[1])
            self._send_json(req, 200, rec.to_dict())
        else:
            self._send_json(req, 404, {"error": f"unknown path {url.path!r}"})

    # ---------------------------------------------------------------- output
    @staticmethod
    def _send_json(req: BaseHTTPRequestHandler, code: int, body: Dict[str, Any]) -> None:
        data = (json.dumps(body, indent=2, default=str) + "\n").encode("utf-8")
        req.send_response(code)
        req.send_header("Content-Type", "application/json; charset=utf-8")
        req.send_header("Content-Length", str(len(data)))
        req.end_headers()
        req.wfile.write(data)


__all__ = ["ControlServer"]
