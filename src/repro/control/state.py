"""Durable per-campaign state machine for the control plane.

Each campaign the daemon manages is a directory under
``<root>/campaigns/<id>/``:

    record.json    the CampaignRecord (state, weights, history)
    spec.toml      the submitted campaign file, byte-for-byte
    state/         Campaign checkpoints + the results journal

``record.json`` is the source of truth across daemon restarts: a
SIGKILLed daemon replays the directory on startup and re-stages every
campaign that had not reached a terminal state (``recover``), so runs
resume without any operator action — the paper's long-lived
multi-campaign sites cannot afford babysitting.

States and legal transitions::

    submitted --> staged --> running --> done
        |            ^  \\      |  \\
        v            |   v     v   v
      failed         +- paused failed

``paused`` is re-stageable (resume) and reachable from both ``staged``
(operator pause before launch) and ``running`` (operator pause or
fair-share preemption). Anything else raises ``IllegalTransition``.
"""

from __future__ import annotations

import json
import logging
import os
import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

logger = logging.getLogger("repro.control.state")

SUBMITTED = "submitted"
STAGED = "staged"
RUNNING = "running"
PAUSED = "paused"
DONE = "done"
FAILED = "failed"

STATES = (SUBMITTED, STAGED, RUNNING, PAUSED, DONE, FAILED)
TERMINAL = frozenset({DONE, FAILED})

LEGAL: Dict[str, frozenset] = {
    SUBMITTED: frozenset({STAGED, FAILED}),
    STAGED: frozenset({RUNNING, PAUSED, FAILED}),
    RUNNING: frozenset({PAUSED, DONE, FAILED}),
    PAUSED: frozenset({STAGED, RUNNING, FAILED}),
    DONE: frozenset(),
    FAILED: frozenset(),
}


class IllegalTransition(ValueError):
    """Raised when a campaign is driven through an edge not in LEGAL."""


@dataclass
class CampaignRecord:
    """One campaign as the control plane sees it (JSON-serializable)."""

    id: str
    name: str
    state: str = SUBMITTED
    weight: float = 1.0
    priority: int = 0
    min_slots: int = 1
    # Per-pool slot demand on the shared fleet (spec pool sizes, capped
    # by [control].demand when set).
    demand: Dict[str, int] = field(default_factory=dict)
    history: List[List[Any]] = field(default_factory=list)  # [state, unix_t, reason]
    error: Optional[str] = None
    paused_by_user: bool = False
    resumed: int = 0  # times re-staged after a pause/crash

    def to_dict(self) -> Dict[str, Any]:
        return {
            "id": self.id,
            "name": self.name,
            "state": self.state,
            "weight": self.weight,
            "priority": self.priority,
            "min_slots": self.min_slots,
            "demand": dict(self.demand),
            "history": [list(h) for h in self.history],
            "error": self.error,
            "paused_by_user": self.paused_by_user,
            "resumed": self.resumed,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CampaignRecord":
        return cls(
            id=d["id"],
            name=d["name"],
            state=d.get("state", SUBMITTED),
            weight=float(d.get("weight", 1.0)),
            priority=int(d.get("priority", 0)),
            min_slots=int(d.get("min_slots", 1)),
            demand={k: int(v) for k, v in d.get("demand", {}).items()},
            history=[list(h) for h in d.get("history", [])],
            error=d.get("error"),
            paused_by_user=bool(d.get("paused_by_user", False)),
            resumed=int(d.get("resumed", 0)),
        )


class StateStore:
    """Durable campaign records under ``<root>/campaigns/<id>/``.

    Every mutation goes through ``transition`` (legality-checked) and is
    published atomically (tmp + ``os.replace``), so a daemon killed
    mid-write leaves either the old record or the new one — never a torn
    file.
    """

    def __init__(self, root: str) -> None:
        self.root = root
        self.campaigns_dir = os.path.join(root, "campaigns")
        os.makedirs(self.campaigns_dir, exist_ok=True)
        self._lock = threading.RLock()
        self._records: Dict[str, CampaignRecord] = {}
        self._load()

    # ----------------------------------------------------------------- paths
    def dir_for(self, cid: str) -> str:
        return os.path.join(self.campaigns_dir, cid)

    def spec_path(self, cid: str) -> str:
        return os.path.join(self.dir_for(cid), "spec.toml")

    def state_dir(self, cid: str) -> str:
        return os.path.join(self.dir_for(cid), "state")

    def _record_path(self, cid: str) -> str:
        return os.path.join(self.dir_for(cid), "record.json")

    # ------------------------------------------------------------------- I/O
    def _load(self) -> None:
        with self._lock:
            for cid in sorted(os.listdir(self.campaigns_dir)):
                path = self._record_path(cid)
                try:
                    with open(path) as f:
                        self._records[cid] = CampaignRecord.from_dict(json.load(f))
                except FileNotFoundError:
                    continue  # half-created campaign dir: ignore
                except Exception:  # noqa: BLE001 - one bad record must not kill the daemon
                    logger.exception("unreadable campaign record %s; skipping", path)

    def _save(self, rec: CampaignRecord) -> None:
        path = self._record_path(rec.id)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(rec.to_dict(), f, indent=2, sort_keys=True)
        os.replace(tmp, path)  # atomic publish

    # --------------------------------------------------------------- surface
    def create(
        self,
        name: str,
        spec_text: str,
        *,
        weight: float = 1.0,
        priority: int = 0,
        min_slots: int = 1,
        demand: Optional[Dict[str, int]] = None,
    ) -> CampaignRecord:
        cid = uuid.uuid4().hex[:8]
        with self._lock:
            os.makedirs(self.state_dir(cid), exist_ok=True)
            with open(self.spec_path(cid), "w") as f:
                f.write(spec_text)
            rec = CampaignRecord(
                id=cid,
                name=name,
                weight=weight,
                priority=priority,
                min_slots=min_slots,
                demand=dict(demand or {}),
            )
            rec.history.append([SUBMITTED, time.time(), "submitted"])
            self._save(rec)
            self._records[cid] = rec
            return rec

    def get(self, cid: str) -> CampaignRecord:
        with self._lock:
            rec = self._records.get(cid)
            if rec is None:
                raise KeyError(f"unknown campaign {cid!r}")
            return rec

    def list(self) -> List[CampaignRecord]:  # noqa: A003 - store surface
        with self._lock:
            return sorted(self._records.values(), key=lambda r: r.history[0][1] if r.history else 0)

    def transition(
        self, cid: str, new_state: str, *, reason: str = "", error: Optional[str] = None
    ) -> CampaignRecord:
        if new_state not in STATES:
            raise IllegalTransition(f"unknown state {new_state!r} (expected one of {STATES})")
        with self._lock:
            rec = self.get(cid)
            if new_state not in LEGAL[rec.state]:
                raise IllegalTransition(
                    f"campaign {cid} ({rec.name}): illegal transition "
                    f"{rec.state!r} -> {new_state!r}"
                )
            rec.state = new_state
            rec.history.append([new_state, time.time(), reason])
            if error is not None:
                rec.error = error
            if new_state == STAGED:
                rec.resumed += 1 if len(rec.history) > 2 else 0
            self._save(rec)
            return rec

    def set_paused_by_user(self, cid: str, value: bool) -> None:
        with self._lock:
            rec = self.get(cid)
            rec.paused_by_user = value
            self._save(rec)

    def recover(self) -> List[CampaignRecord]:
        """Re-stage every campaign interrupted by a daemon crash.

        ``submitted``/``staged``/``running`` all become ``staged`` (their
        work resumes from the latest Campaign checkpoint + journal);
        ``paused`` stays paused only when the *user* paused it — a
        preemption pause is scheduler state, not operator intent, so it
        re-stages too. Returns the records that were re-staged.
        """
        restaged: List[CampaignRecord] = []
        with self._lock:
            for rec in list(self._records.values()):
                if rec.state in TERMINAL:
                    continue
                if rec.state == PAUSED and rec.paused_by_user:
                    continue
                if rec.state == SUBMITTED:
                    self.transition(rec.id, STAGED, reason="crash-recovery")
                elif rec.state == RUNNING:
                    # running -> staged is not a legal operator edge; a
                    # crash goes through paused (the checkpoint on disk is
                    # the implicit pause) then back to staged.
                    self.transition(rec.id, PAUSED, reason="daemon crash")
                    self.transition(rec.id, STAGED, reason="crash-recovery")
                elif rec.state == PAUSED:
                    self.transition(rec.id, STAGED, reason="crash-recovery")
                elif rec.state != STAGED:
                    continue
                restaged.append(rec)
        return restaged


__all__ = [
    "CampaignRecord",
    "DONE",
    "FAILED",
    "IllegalTransition",
    "LEGAL",
    "PAUSED",
    "RUNNING",
    "STAGED",
    "STATES",
    "SUBMITTED",
    "StateStore",
    "TERMINAL",
]
