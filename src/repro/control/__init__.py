"""repro.control: a persistent multi-campaign control plane.

One daemon per site hosts many concurrent campaigns over a shared
worker fleet: submissions arrive as ``campaign.toml`` over HTTP, every
campaign's lifecycle is a durable state machine (crash -> auto-resume
from its latest checkpoint + results journal), and slots are apportioned
by weighted fair share with priority preemption. See
``python -m repro.control --help``.
"""

from .api import ControlServer
from .plane import ControlPlane
from .scheduler import FleetAccounting, compute_grants, meets_floor, total_slots
from .state import (
    DONE,
    FAILED,
    LEGAL,
    PAUSED,
    RUNNING,
    STAGED,
    STATES,
    SUBMITTED,
    TERMINAL,
    CampaignRecord,
    IllegalTransition,
    StateStore,
)
from .workload import CountedWorkload, make_workload, workload_task

__all__ = [
    "CampaignRecord",
    "ControlPlane",
    "ControlServer",
    "CountedWorkload",
    "DONE",
    "FAILED",
    "FleetAccounting",
    "IllegalTransition",
    "LEGAL",
    "PAUSED",
    "RUNNING",
    "STAGED",
    "STATES",
    "SUBMITTED",
    "StateStore",
    "TERMINAL",
    "compute_grants",
    "make_workload",
    "meets_floor",
    "total_slots",
    "workload_task",
]
