"""CLI: run or talk to the campaign control plane.

    # daemon (one per site)
    python -m repro.control serve --root /var/run/campaigns --fleet fleet.toml

    # clients
    python -m repro.control submit --url http://127.0.0.1:8765 campaign.toml
    python -m repro.control status --url http://127.0.0.1:8765
    python -m repro.control pause  --url http://127.0.0.1:8765 <id>
    python -m repro.control resume --url http://127.0.0.1:8765 <id>

The fleet file declares the site's shared slot budget::

    [pools.default]
    size = 8
    [pools.gpu]
    size = 2

``--port 0`` (the default) binds an ephemeral port; ``--port-file``
writes the bound port for whoever spawned the daemon (the CI smoke job
and the benchmark use this handshake).
"""

from __future__ import annotations

import argparse
import json
import signal
import sys
import threading
import urllib.error
import urllib.request
from typing import Any, Dict, List, Optional


def _load_fleet(path: str) -> Dict[str, int]:
    try:
        import tomllib  # Python >= 3.11
    except ModuleNotFoundError:  # pragma: no cover - 3.10 path
        import tomli as tomllib
    with open(path, "rb") as f:
        d = tomllib.load(f)
    pools = d.get("pools", d)  # accept both [pools.X] and top-level tables
    fleet: Dict[str, int] = {}
    for name, v in pools.items():
        if isinstance(v, dict):
            fleet[name] = int(v.get("size", 1))
        elif isinstance(v, int) and not isinstance(v, bool):
            fleet[name] = v
    if not fleet:
        raise ValueError(f"{path} declares no pools")
    return fleet


def _http(method: str, url: str, data: Optional[bytes] = None) -> Dict[str, Any]:
    req = urllib.request.Request(url, data=data, method=method)
    if data is not None:
        req.add_header("Content-Type", "application/toml")
    try:
        with urllib.request.urlopen(req, timeout=30) as resp:
            return json.loads(resp.read().decode("utf-8"))
    except urllib.error.HTTPError as exc:
        body = exc.read().decode("utf-8", "replace")
        raise SystemExit(f"error: HTTP {exc.code} from {url}: {body.strip()}") from exc


def _cmd_serve(args: argparse.Namespace) -> int:
    from .api import ControlServer
    from .plane import ControlPlane

    fleet = _load_fleet(args.fleet)
    plane = ControlPlane(args.root, fleet, tick_s=args.tick).start()
    server = ControlServer(plane, host=args.host, port=args.port).start()
    if args.port_file:
        with open(args.port_file, "w") as f:
            f.write(str(server.port))
    print(f"control plane: root={args.root} fleet={fleet} url={server.url}", flush=True)

    stop = threading.Event()

    def _on_signal(signum: int, frame: Any) -> None:
        stop.set()

    signal.signal(signal.SIGTERM, _on_signal)
    signal.signal(signal.SIGINT, _on_signal)
    while not stop.is_set():
        stop.wait(0.5)
    server.stop()
    plane.stop()
    return 0


def _cmd_submit(args: argparse.Namespace) -> int:
    with open(args.path) as f:
        body = f.read()
    url = f"{args.url.rstrip('/')}/campaigns"
    if args.name:
        url += f"?name={args.name}"
    rec = _http("POST", url, body.encode("utf-8"))
    print(json.dumps(rec, indent=2, sort_keys=True))
    return 0


def _cmd_status(args: argparse.Namespace) -> int:
    out = _http("GET", f"{args.url.rstrip('/')}/campaigns")
    print(json.dumps(out, indent=2, sort_keys=True))
    return 0


def _cmd_pause(args: argparse.Namespace) -> int:
    rec = _http("POST", f"{args.url.rstrip('/')}/campaigns/{args.id}/pause")
    print(json.dumps(rec, indent=2, sort_keys=True))
    return 0


def _cmd_resume(args: argparse.Namespace) -> int:
    rec = _http("POST", f"{args.url.rstrip('/')}/campaigns/{args.id}/resume")
    print(json.dumps(rec, indent=2, sort_keys=True))
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.control",
        description="Persistent multi-campaign control plane (daemon + clients).",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    serve = sub.add_parser("serve", help="run the control-plane daemon")
    serve.add_argument("--root", required=True, help="durable state directory")
    serve.add_argument("--fleet", required=True, help="fleet TOML ({pools.X: size})")
    serve.add_argument("--host", default="127.0.0.1")
    serve.add_argument("--port", type=int, default=0, help="0 = ephemeral")
    serve.add_argument("--port-file", default=None,
                       help="write the bound port here (spawn handshake)")
    serve.add_argument("--tick", type=float, default=0.5, help="scheduler tick seconds")
    serve.set_defaults(fn=_cmd_serve)

    submit = sub.add_parser("submit", help="submit a campaign TOML")
    submit.add_argument("path")
    submit.add_argument("--url", required=True)
    submit.add_argument("--name", default=None)
    submit.set_defaults(fn=_cmd_submit)

    status = sub.add_parser("status", help="list campaigns")
    status.add_argument("--url", required=True)
    status.set_defaults(fn=_cmd_status)

    pause = sub.add_parser("pause", help="pause a campaign (checkpoint + release)")
    pause.add_argument("id")
    pause.add_argument("--url", required=True)
    pause.set_defaults(fn=_cmd_pause)

    resume = sub.add_parser("resume", help="resume a paused campaign")
    resume.add_argument("id")
    resume.add_argument("--url", required=True)
    resume.set_defaults(fn=_cmd_resume)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
