"""Pallas TPU kernel for chunked WKV-6 (RWKV 'Finch' linear attention).

Grid = (B * H, S / C) with the chunk axis sequential: the (K, V) state
matrix for each head lives in f32 VMEM scratch and carries across
chunks. Within a chunk the GLA-style chunkwise-parallel form is used:

    out  = (r * exp(cum_excl)) @ S                      (MXU, C x K x V)
         + tril_{s<t}[ (r_t . k_s) * exp(pair) ] @ v    (pairwise, VPU+MXU)
         + diag bonus (u)
    S'   = diag(exp(total)) S + (k * exp(total - cum_incl))^T @ v

All decay exponents are differences of log-decay cumsums arranged to be
<= 0, so no exp can overflow regardless of decay magnitude. With C = 64,
K = V = 64 the VMEM working set is ~1.4 MB (state 64x64 f32 = 16 kB;
pairwise tensor 64*64*64 f32 = 1 MB) — small enough to double-buffer the
chunk streams. The MXU matmuls are (64,64)@(64,64): hardware aligned.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(
    r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
    out_ref, sfinal_ref,
    S_ref,                         # VMEM scratch (K, V) f32
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        S_ref[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (C, V)
    lw = lw_ref[0].astype(jnp.float32)        # (C, K)
    u = u_ref[0].astype(jnp.float32)          # (1, K) -> (K,)

    cum_incl = jnp.cumsum(lw, axis=0)
    cum_excl = cum_incl - lw
    total = cum_incl[-1:]                     # (1, K)

    S = S_ref[...]
    r_dec = r * jnp.exp(cum_excl)
    out = jax.lax.dot_general(
        r_dec, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (C, V)

    # intra-chunk pairwise (strictly causal)
    pair = cum_excl[:, None, :] - cum_incl[None, :, :]       # (C, C, K), <= 0 for s<t
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = (s_idx < t_idx)[:, :, None]
    w_pair = jnp.where(causal, jnp.exp(jnp.where(causal, pair, 0.0)), 0.0)
    A = jnp.einsum("tk,sk,tsk->ts", r, k, w_pair)            # (C, C)
    out += jax.lax.dot_general(
        A, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True)        # (C, 1)
    out += diag * v

    # state update
    k_dec = k * jnp.exp(total - cum_incl)
    S_ref[...] = jnp.exp(total[0])[:, None] * S + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[0] = out.astype(out_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _finish():
        sfinal_ref[0] = S_ref[...].astype(sfinal_ref.dtype)


def wkv6_pallas(
    r: jnp.ndarray,       # (B, H, S, K)
    k: jnp.ndarray,
    v: jnp.ndarray,       # (B, H, S, V)
    lw: jnp.ndarray,      # (B, H, S, K)
    u: jnp.ndarray,       # (H, K)
    state0: jnp.ndarray,  # (B, H, K, V)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, H, S, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)

    rf = r.reshape(B * H, S, K)
    kf = k.reshape(B * H, S, K)
    vf = v.reshape(B * H, S, V)
    lwf = lw.reshape(B * H, S, K)
    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, 1, K)
    s0 = state0.reshape(B * H, K, V)

    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    out, s_final = pl.pallas_call(
        kernel,
        grid=(B * H, S // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, V), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, K), lambda bh, ci: (bh, 0, 0)),
            pl.BlockSpec((1, K, V), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, V), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, K, V), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, V), v.dtype),
            jax.ShapeDtypeStruct((B * H, K, V), state0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, lwf, uf, s0)
    return out.reshape(B, H, S, V), s_final.reshape(B, H, K, V)
