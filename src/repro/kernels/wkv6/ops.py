"""Public WKV-6 op with implementation dispatch."""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import wkv6_pallas
from .ref import wkv6_ref
from .xla import wkv6_xla


def _default_impl() -> str:
    env = os.environ.get("REPRO_SCAN_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def wkv6(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lw: jnp.ndarray,
    u: jnp.ndarray,
    state0: jnp.ndarray,
    *,
    chunk: int = 64,
    impl: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    impl = impl or _default_impl()
    if impl == "pallas":
        return wkv6_pallas(r, k, v, lw, u, state0, chunk=chunk)
    if impl == "interpret":
        return wkv6_pallas(r, k, v, lw, u, state0, chunk=chunk, interpret=True)
    if impl == "xla":
        return wkv6_xla(r, k, v, lw, u, state0, chunk=chunk)
    if impl == "ref":
        return wkv6_ref(r, k, v, lw, u, state0)
    raise ValueError(f"unknown wkv6 impl {impl!r}")
