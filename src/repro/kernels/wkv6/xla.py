"""Chunked WKV-6 in pure XLA (GLA-style chunkwise-parallel form).

The naive recurrence is sequential over S. The chunked form processes
chunks of C tokens: within a chunk, pairwise decay factors are computed
in log space with *non-positive exponents only* (numerically safe — no
exp overflow regardless of decay magnitude), and cross-chunk state is
carried by a lax.scan. Compute per chunk is dominated by
(C,K)@(K,V) matmuls — MXU-shaped — plus one (C,C,K) pairwise tensor
(bounded: C=64 keeps it at 64*64*K floats).

All math in f32; inputs may be bf16.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _chunk_step(u, carry_S, chunk):
    r, k, v, lw = chunk                     # (B,H,C,K/V)
    B, H, C, K = r.shape
    cum_incl = jnp.cumsum(lw, axis=2)       # sum_{s<=t} lw_s
    cum_excl = cum_incl - lw                # sum_{s<t} lw_s
    total = cum_incl[:, :, -1:, :]          # (B,H,1,K)

    # inter-chunk: tokens see the carried state decayed to their position
    r_dec = r * jnp.exp(cum_excl)                       # exponent <= 0
    out_inter = jnp.einsum("bhck,bhkv->bhcv", r_dec, carry_S)

    # intra-chunk, strictly causal (s < t): pairwise decay exponent
    # cum_excl[t] - cum_incl[s] = sum_{u=s+1..t-1} lw_u <= 0  -> safe
    pair = cum_excl[:, :, :, None, :] - cum_incl[:, :, None, :, :]  # (B,H,C,C,K)
    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)[None, None, :, :, None]
    pair = jnp.where(mask, pair, -jnp.inf)
    A = jnp.einsum("bhtk,bhsk,bhtsk->bhts", r, k, jnp.exp(pair))
    # diagonal (current token) with bonus u
    diag = jnp.einsum("bhtk,k,bhtk->bht", r, u, k) if u.ndim == 1 else \
        jnp.einsum("bhtk,hk,bhtk->bht", r, u, k)
    out_intra = jnp.einsum("bhts,bhsv->bhtv", A, v) + diag[..., None] * v

    # state update: S' = diag(exp(total)) S + sum_s (k_s * exp(total - cum_incl[s]))^T v_s
    k_dec = k * jnp.exp(total - cum_incl)               # exponent <= 0
    S_new = jnp.exp(total)[:, :, 0, :, None] * carry_S + jnp.einsum(
        "bhck,bhcv->bhkv", k_dec, v
    )
    return S_new, out_inter + out_intra


def wkv6_xla(
    r: jnp.ndarray,       # (B, H, S, K)
    k: jnp.ndarray,
    v: jnp.ndarray,       # (B, H, S, V)
    lw: jnp.ndarray,      # (B, H, S, K) log decay <= 0
    u: jnp.ndarray,       # (H, K)
    state0: jnp.ndarray,  # (B, H, K, V)
    *,
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, H, S, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        r = jnp.pad(r, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        lw = jnp.pad(lw, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = (S + pad) // chunk

    rf, kf, vf, lwf = (x.astype(jnp.float32) for x in (r, k, v, lw))
    uf = u.astype(jnp.float32)

    def to_chunks(x):
        return jnp.moveaxis(
            x.reshape(B, H, n_chunks, chunk, x.shape[-1]), 2, 0
        )  # (n, B, H, C, *)

    xs = (to_chunks(rf), to_chunks(kf), to_chunks(vf), to_chunks(lwf))
    step = functools.partial(_chunk_step, uf)
    S_final, outs = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    out = jnp.moveaxis(outs, 0, 2).reshape(B, H, n_chunks * chunk, V)
    return out[:, :, :S].astype(v.dtype), S_final.astype(state0.dtype)
