"""Pure-jnp oracle for the RWKV-6 WKV recurrence (naive time scan).

Per head with state S in R^{K x V}:
    out_t = r_t @ (S_{t-1} + (u * k_t)^T v_t)
    S_t   = diag(exp(lw_t)) S_{t-1} + k_t^T v_t
where lw_t <= 0 is the (data-dependent) log-decay, u is the per-channel
"bonus" applied to the current token only.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def wkv6_ref(
    r: jnp.ndarray,       # (B, H, S, K)
    k: jnp.ndarray,       # (B, H, S, K)
    v: jnp.ndarray,       # (B, H, S, V)
    lw: jnp.ndarray,      # (B, H, S, K) log decay, <= 0
    u: jnp.ndarray,       # (H, K) bonus
    state0: jnp.ndarray,  # (B, H, K, V)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    lwf = lw.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def step(S, inputs):
        r_t, k_t, v_t, lw_t = inputs             # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = k_t[..., :, None] * v_t[..., None, :]           # (B,H,K,V)
        S_eff = S + uf[None, :, :, None] * kv                # bonus on current token
        out_t = jnp.einsum("bhk,bhkv->bhv", r_t, S_eff)
        S = jnp.exp(lw_t)[..., None] * S + kv
        return S, out_t

    xs = tuple(jnp.moveaxis(x, 2, 0) for x in (rf, kf, vf, lwf))  # (S, B, H, *)
    S_final, outs = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    out = jnp.moveaxis(outs, 0, 2)               # (B, H, S, V)
    return out.astype(v.dtype), S_final.astype(state0.dtype)
