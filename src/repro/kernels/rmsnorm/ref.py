"""Pure-jnp oracle for fused RMSNorm: y = x * rsqrt(mean(x^2)+eps) * (off+w).

``scale_offset=1.0`` reproduces the Gemma convention (weight stored as a
delta around 1); ``0.0`` gives the Llama convention.
"""

from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    eps: float = 1e-6,
    scale_offset: float = 0.0,
) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * (var + eps) ** -0.5
    return (y * (scale_offset + w.astype(jnp.float32))).astype(x.dtype)
