"""Public fused-RMSNorm op with implementation dispatch."""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import rmsnorm_pallas
from .ref import rmsnorm_ref


def _default_impl() -> str:
    env = os.environ.get("REPRO_NORM_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def rmsnorm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    eps: float = 1e-6,
    scale_offset: float = 0.0,
    impl: Optional[str] = None,
) -> jnp.ndarray:
    impl = impl or _default_impl()
    if impl == "pallas":
        return rmsnorm_pallas(x, w, eps=eps, scale_offset=scale_offset)
    if impl == "interpret":
        return rmsnorm_pallas(x, w, eps=eps, scale_offset=scale_offset, interpret=True)
    if impl == "ref":
        return rmsnorm_ref(x, w, eps=eps, scale_offset=scale_offset)
    raise ValueError(f"unknown rmsnorm impl {impl!r}")
