"""Pallas TPU fused RMSNorm kernel.

A bandwidth-bound elementwise+reduction op: each row of x is read once,
normalized in f32, scaled, and written once. Tiling: grid over row
blocks; the full feature dimension D sits in the lane axis of one VMEM
block (rows x D). block_rows is chosen so block bytes ~ 1-2 MB: with
D = 16384 (llama3-405b) and bf16 in, 64 rows x 16384 x 2 B = 2 MB.
The weight vector (1, D) is broadcast to every program instance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, scale_offset: float):
    x = x_ref[...].astype(jnp.float32)                 # (rows, D)
    w = w_ref[...].astype(jnp.float32)                 # (1, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (scale_offset + w)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jnp.ndarray,            # (..., D)
    w: jnp.ndarray,            # (D,)
    *,
    eps: float = 1e-6,
    scale_offset: float = 0.0,
    block_rows: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    orig_shape = x.shape
    d = x.shape[-1]
    rows = int(x.size // d)
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    kernel = functools.partial(_rmsnorm_kernel, eps=eps, scale_offset=scale_offset)
    out = pl.pallas_call(
        kernel,
        grid=((rows + pad) // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, d), x.dtype),
        interpret=interpret,
    )(xf, w.reshape(1, d))
    return out[:rows].reshape(orig_shape)
