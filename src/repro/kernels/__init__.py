"""Pallas TPU kernels for the perf-critical compute of the model substrate.

Each kernel directory contains:
  * ``kernel.py`` — the Pallas TPU kernel (pl.pallas_call + BlockSpec),
    validated on CPU with ``interpret=True``;
  * ``ops.py``    — the public jit'd wrapper with impl dispatch
    (pallas on TPU / XLA or ref elsewhere);
  * ``ref.py``    — the pure-jnp oracle used by tests.
"""

from .flash_attention.ops import flash_attention
from .decode_attention.ops import decode_attention
from .rglru_scan.ops import rglru_scan
from .wkv6.ops import wkv6
from .rmsnorm.ops import rmsnorm

__all__ = ["flash_attention", "decode_attention", "rglru_scan", "wkv6", "rmsnorm"]
