"""Pure-jnp oracle for the gated linear recurrence  h_t = a_t * h_{t-1} + b_t.

This is the primitive under RG-LRU (RecurrentGemma): the caller computes
``a_t = exp(log_a_t)`` gates and pre-gated inputs ``b_t`` and we run the
diagonal linear recurrence, returning all states and the final state.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rglru_scan_ref(
    log_a: jnp.ndarray,   # (B, S, D) log decay per step (<= 0)
    b: jnp.ndarray,       # (B, S, D) pre-gated input
    h0: jnp.ndarray,      # (B, D) initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    a = jnp.exp(log_a.astype(jnp.float32))
    bf = b.astype(jnp.float32)

    def step(h, inputs):
        a_t, b_t = inputs
        h = a_t * h + b_t
        return h, h

    h_final, hs = jax.lax.scan(
        step, h0.astype(jnp.float32), (a.swapaxes(0, 1), bf.swapaxes(0, 1))
    )
    return hs.swapaxes(0, 1).astype(b.dtype), h_final.astype(b.dtype)
