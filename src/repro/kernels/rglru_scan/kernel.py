"""Pallas TPU kernel for the RG-LRU gated linear recurrence.

The recurrence is sequential in time but embarrassingly parallel over
(batch, channel). Tiling: grid = (B, D / block_d, S / block_s) with the
time axis innermost (sequential on TPU), so the running state vector
h (block_d,) lives in VMEM scratch and carries across time blocks.

The op is memory-bound — every element of (log_a, b) is read exactly
once and every h written once — so the kernel's job is purely to stream
HBM->VMEM at full bandwidth while the VPU does 2 flops/element. Inside a
block we run the scan with a fori_loop over rows of the VMEM-resident
tile; block_s x block_d = 256 x 512 (f32) = 512 kB per operand keeps the
working set well inside VMEM with room for double buffering.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(
    log_a_ref, b_ref, h0_ref,      # inputs
    hs_ref, hlast_ref,             # outputs
    h_ref,                         # VMEM scratch: carried state (1, block_d)
    *,
    block_s: int,
):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = jnp.exp(log_a_ref[0].astype(jnp.float32))    # (block_s, block_d)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        hs_ref[0, t, :] = h.astype(hs_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[0, :])
    h_ref[...] = h[None]

    @pl.when(si == pl.num_programs(2) - 1)
    def _finish():
        hlast_ref[...] = h[None].astype(hlast_ref.dtype)


def rglru_scan_pallas(
    log_a: jnp.ndarray,   # (B, S, D)
    b: jnp.ndarray,       # (B, S, D)
    h0: jnp.ndarray,      # (B, D)
    *,
    block_s: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = b.shape
    block_s = min(block_s, S)
    block_d = min(block_d, D)
    assert S % block_s == 0 and D % block_d == 0, (S, block_s, D, block_d)

    kernel = functools.partial(_rglru_kernel, block_s=block_s)
    hs, hlast = pl.pallas_call(
        kernel,
        grid=(B, D // block_d, S // block_s),
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, block_s, block_d), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, block_d), lambda bi, di, si: (bi, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, block_d), lambda bi, di, si: (bi, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), b.dtype),
            jax.ShapeDtypeStruct((B, D), b.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(log_a, b, h0)
    return hs, hlast
