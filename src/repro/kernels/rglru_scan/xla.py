"""XLA path for the gated linear recurrence: associative scan.

``(a, b) o (a', b') = (a*a', a'*b + b')`` is associative, so
``lax.associative_scan`` computes all states in O(log S) depth — the
SPMD-friendly form the dry run compiles. Sequence stays unsharded
(recurrence is sequential); batch and channel dims shard freely.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rglru_scan_xla(
    log_a: jnp.ndarray,   # (B, S, D)
    b: jnp.ndarray,       # (B, S, D)
    h0: jnp.ndarray,      # (B, D)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    a = jnp.exp(log_a.astype(jnp.float32))
    bf = b.astype(jnp.float32)
    # fold h0 into the first step: b_0' = a_0 * h0 + b_0
    bf = bf.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, hs = jax.lax.associative_scan(combine, (a, bf), axis=1)
    return hs.astype(b.dtype), hs[:, -1].astype(b.dtype)
