"""Public gated-linear-recurrence op with implementation dispatch."""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import rglru_scan_pallas
from .ref import rglru_scan_ref
from .xla import rglru_scan_xla


def _default_impl() -> str:
    env = os.environ.get("REPRO_SCAN_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def rglru_scan(
    log_a: jnp.ndarray,
    b: jnp.ndarray,
    h0: jnp.ndarray,
    *,
    impl: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    impl = impl or _default_impl()
    if impl == "pallas":
        return rglru_scan_pallas(log_a, b, h0)
    if impl == "interpret":
        return rglru_scan_pallas(log_a, b, h0, interpret=True)
    if impl == "xla":
        return rglru_scan_xla(log_a, b, h0)
    if impl == "ref":
        return rglru_scan_ref(log_a, b, h0)
    raise ValueError(f"unknown rglru impl {impl!r}")
