"""Public decode-attention op with implementation dispatch.

The XLA path is a plain masked einsum: for one query token the score
tensor is only (B, H, S) — bounded — and XLA fuses the mask+softmax
chain well. The Pallas kernel wins on real TPUs by streaming the cache
through VMEM once (see kernel.py); ``REPRO_ATTN_IMPL`` forces a choice.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import decode_attention_pallas
from .ref import decode_attention_ref


def _default_impl() -> str:
    env = os.environ.get("REPRO_ATTN_IMPL")
    if env:
        return env if env != "xla" else "ref"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
    impl: Optional[str] = None,
    block_k: int = 1024,
) -> jnp.ndarray:
    impl = impl or _default_impl()
    if impl == "pallas":
        return decode_attention_pallas(
            q, k, v, lengths, sm_scale=sm_scale, window=window, block_k=block_k
        )
    if impl == "interpret":
        return decode_attention_pallas(
            q, k, v, lengths, sm_scale=sm_scale, window=window, block_k=block_k,
            interpret=True,
        )
    if impl == "ref":
        return decode_attention_ref(q, k, v, lengths, sm_scale=sm_scale, window=window)
    raise ValueError(f"unknown decode attention impl {impl!r}")
