"""Pure-jnp decode attention over a KV cache (also the CPU/XLA path).

GQA is computed with grouped einsums — q reshaped to (B, KV, G, hd) —
rather than ``jnp.repeat`` of the cache: repeating would materialize a
group-times-larger copy of the (possibly 32k-token, sequence-sharded)
cache and force the SPMD partitioner to reshard it. Operands stay in
their storage dtype (no f32 cache copies); dots accumulate in f32.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def decode_attention_ref(
    q: jnp.ndarray,          # (B, H, D) — one new token per sequence
    k: jnp.ndarray,          # (B, KVH, S, D) — cache (padded to S)
    v: jnp.ndarray,          # (B, KVH, S, D)
    lengths: jnp.ndarray,    # (B,) int32 — valid cache entries per sequence
    *,
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
) -> jnp.ndarray:
    b, h, d = q.shape
    kvh, s = k.shape[1], k.shape[2]
    group = h // kvh
    scale = sm_scale if sm_scale is not None else d ** -0.5

    qg = q.reshape(b, kvh, group, d)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(s)[None, :]
    mask = pos < lengths[:, None]
    if window is not None and window > 0:
        mask &= pos >= (lengths[:, None] - window)
    mask4 = mask[:, None, None, :]
    scores = jnp.where(mask4, scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = jnp.where(mask4, probs, 0.0)
    probs = probs / jnp.maximum(probs.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgs,bksd->bkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, d).astype(q.dtype)
