"""Pallas TPU flash-decode kernel: one query token vs. a long KV cache.

Decode attention is *memory-bound*: the whole KV cache (up to 32k x
kv_heads x 128 per sequence here) streams through VMEM once per step
while compute is a rank-1 product. The kernel therefore tiles the cache
sequence dimension — grid = (B * H, S / block_k), sequential over the
cache — and keeps the online-softmax state for the single query row in
VMEM scratch. block_k = 1024 x d=128 x bf16 = 256 kB per kv operand,
sized so double-buffered HBM->VMEM streams saturate bandwidth.

GQA is folded into the index maps (kv head = q head // group), so the
cache is read once per kv head group rather than once per q head.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,                      # (1, 1) int32 in SMEM-ish block
    q_ref, k_ref, v_ref,          # VMEM blocks
    o_ref,
    m_ref, l_ref, acc_ref,        # scratch
    *,
    sm_scale: float,
    block_k: int,
    window: int,
):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)          # (1, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)            # (bk, d)
    length = len_ref[0, 0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale                                 # (1, bk)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = k_pos < length
    if window > 0:
        mask &= k_pos >= length - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0, 0]
    l_prev = l_ref[0, 0]
    m_cur = jnp.maximum(m_prev, s.max())
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    p = jnp.where(mask, p, 0.0)
    l_cur = l_prev * alpha + p.sum()

    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.full_like(m_ref, m_cur)
    l_ref[...] = jnp.full_like(l_ref, l_cur)

    @pl.when(ki == pl.num_programs(1) - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[0, 0], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jnp.ndarray,          # (B, H, D)
    k: jnp.ndarray,          # (B, KVH, S, D)
    v: jnp.ndarray,
    lengths: jnp.ndarray,    # (B,) int32
    *,
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
    block_k: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, d = q.shape
    kvh, s = k.shape[1], k.shape[2]
    group = h // kvh
    block_k = min(block_k, s)
    assert s % block_k == 0, (s, block_k)
    scale = sm_scale if sm_scale is not None else d ** -0.5

    qf = q.reshape(b * h, d)
    kf = k.reshape(b * kvh, s, d)
    vf = v.reshape(b * kvh, s, d)
    lens = jnp.broadcast_to(lengths[:, None], (b, h)).reshape(b * h, 1).astype(jnp.int32)

    kernel = functools.partial(
        _decode_kernel, sm_scale=scale, block_k=block_k, window=window or 0
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_k),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0)),
            pl.BlockSpec((1, d), lambda bh, ki: (bh, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bh, ki: (bh, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 128), jnp.float32),
            pltpu.VMEM((1, 128), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out.reshape(b, h, d)
