"""Public flash-attention op: dispatches to the best implementation.

Order of preference:
  * ``pallas``     — the TPU kernel (kernel.py), on TPU backends;
  * ``xla``        — lax.scan online softmax (memory-bounded, SPMD-safe);
  * ``ref``        — naive oracle (tests only);
  * ``interpret``  — the Pallas kernel interpreted on CPU (tests only).

Set ``REPRO_ATTN_IMPL`` to force one globally.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref
from .xla import flash_attention_xla, flash_attention_vjp


def _default_impl() -> str:
    env = os.environ.get("REPRO_ATTN_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    q_offset: int = 0,
    impl: Optional[str] = None,
    block_q: int = 512,
    block_k: int = 512,
    skip_masked_blocks: bool = False,
) -> jnp.ndarray:
    impl = impl or _default_impl()
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale,
            block_q=block_q, block_k=block_k, q_offset=q_offset,
        )
    if impl == "interpret":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale,
            block_q=block_q, block_k=block_k, q_offset=q_offset, interpret=True,
        )
    if impl == "xla":
        # custom-VJP path: backward recomputes probabilities blockwise
        # instead of letting scan-autodiff stack them (see xla.py)
        return flash_attention_vjp(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale,
            block_k=block_k, q_offset=q_offset,
        )
    if impl == "xla_scan":
        return flash_attention_xla(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale,
            block_k=block_k, q_offset=q_offset, skip_masked_blocks=skip_masked_blocks,
        )
    if impl == "ref":
        return attention_ref(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale, q_offset=q_offset
        )
    raise ValueError(f"unknown attention impl {impl!r}")
