"""Memory-bounded flash attention in pure XLA (lax.scan online softmax).

This is the path the SPMD dry-run compiles (the container has no TPU, and
even on TPU it is the portable fallback). It never materializes the
(Sq, Skv) score matrix: kv is processed in blocks with the online-softmax
recurrence, so peak temp memory is O(Sq * D) per head — the property that
makes 32k-token prefill fit in HBM.

``skip_masked_blocks=True`` processes, for each q block, only the kv
prefix it can attend to (causal) / its window (local attention) using a
bounded fori_loop — halving attention FLOPs for causal training. This is
a perf lever measured in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _attend_block(q, k, v, q_pos, k_pos, causal, window, scale):
    """One (q block) x (kv block) online-softmax contribution."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    mask = jnp.ones(s.shape[-2:], dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    return s, mask


def flash_attention_xla(
    q: jnp.ndarray,                  # (B, H, Sq, D)
    k: jnp.ndarray,                  # (B, KVH, Skv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    block_k: int = 1024,
    q_offset: int = 0,
    skip_masked_blocks: bool = False,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    _, kvh, skv, _ = k.shape
    group = h // kvh
    scale = sm_scale if sm_scale is not None else d ** -0.5
    block_k = min(block_k, skv)
    # pad kv to a block multiple
    pad = (-skv) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_blocks = (skv + pad) // block_k

    qf = q.astype(jnp.float32)
    # reshape kv blocks to scan over: (n_blocks, B, KVH, block_k, D)
    kb = jnp.moveaxis(k.reshape(b, kvh, n_blocks, block_k, d), 2, 0).astype(jnp.float32)
    vb = jnp.moveaxis(v.reshape(b, kvh, n_blocks, block_k, d), 2, 0).astype(jnp.float32)

    q_pos = jnp.arange(sq) + q_offset
    win = window or 0

    def body(carry, inputs):
        acc, m, l = carry
        kblk, vblk, blk_idx = inputs
        if group > 1:
            kblk = jnp.repeat(kblk, group, axis=1)
            vblk = jnp.repeat(vblk, group, axis=1)
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        s, mask = _attend_block(qf, kblk, vblk, q_pos, k_pos, causal, win, scale)
        # also mask kv padding
        pad_mask = k_pos < skv
        s = jnp.where(pad_mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where((mask[None, None] & pad_mask[None, None, None]), p, 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vblk)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)

    if not skip_masked_blocks:
        (acc, m, l), _ = jax.lax.scan(
            body, (acc0, m0, l0), (kb, vb, jnp.arange(n_blocks))
        )
    else:
        # Bounded while-loop: stop after the last block any q can see.
        # For causal full-seq (q_offset=0, sq==skv) this halves FLOPs is
        # not possible without per-q-block bounds; instead we iterate per
        # q block (see blockwise variant below).
        return _flash_blockwise_causal(
            qf, kb, vb, scale, causal, win, q_offset, sq, skv, block_k, group
        ).astype(q.dtype)

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Custom-VJP flash attention: the scan above, if differentiated directly,
# STACKS every per-block probability matrix as a residual (O(Sq*Skv) f32 per
# layer — measured 5 GiB/layer on whisper train). The custom backward saves
# only (q, k, v, out, lse) and RECOMPUTES probabilities blockwise — the
# defining trick of flash attention, applied to the XLA path.
# ---------------------------------------------------------------------------


def _fwd_scan(q, k, v, *, causal, window, scale, block_k, q_offset):
    """Online-softmax forward returning (out_f32, lse). kv pre-repeated to H."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    pad = (-skv) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_blocks = (skv + pad) // block_k
    kb = jnp.moveaxis(k.reshape(b, h, n_blocks, block_k, d), 2, 0)
    vb = jnp.moveaxis(v.reshape(b, h, n_blocks, block_k, d), 2, 0)
    q_pos = jnp.arange(sq) + q_offset

    def body(carry, inputs):
        acc, m, l = carry
        kblk, vblk, blk_idx = inputs
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((sq, block_k), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos < skv)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  (kb, vb, jnp.arange(n_blocks)))
    l = jnp.maximum(l, 1e-30)
    out = acc / l[..., None]
    lse = m + jnp.log(l)
    return out, lse


def _flash_core(q, k, v, causal, window, scale, block_k, q_offset):
    out, _ = _fwd_scan(q, k, v, causal=causal, window=window, scale=scale,
                       block_k=block_k, q_offset=q_offset)
    return out.astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_vjp(q, k, v, causal, window, scale, block_k, q_offset):
    return _flash_core(q, k, v, causal, window, scale, block_k, q_offset)


def _flash_vjp_fwd(q, k, v, causal, window, scale, block_k, q_offset):
    out, lse = _fwd_scan(q, k, v, causal=causal, window=window, scale=scale,
                         block_k=block_k, q_offset=q_offset)
    return out.astype(q.dtype), (q, k, v, out.astype(q.dtype), lse)


def _flash_vjp_bwd(causal, window, scale, block_k, q_offset, res, do):
    q, k, v, out, lse = res
    b, h, sq, d = q.shape
    skv = k.shape[2]
    pad = (-skv) % block_k
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else v
    n_blocks = (skv + pad) // block_k
    kb = jnp.moveaxis(kp.reshape(b, h, n_blocks, block_k, d), 2, 0)
    vb = jnp.moveaxis(vp.reshape(b, h, n_blocks, block_k, d), 2, 0)
    q_pos = jnp.arange(sq) + q_offset
    dof = do.astype(jnp.float32)
    D = jnp.einsum("bhqd,bhqd->bhq", dof, out.astype(jnp.float32))

    def body(dq, inputs):
        kblk, vblk, blk_idx = inputs
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((sq, block_k), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos < skv)[None, :]
        p = jnp.where(mask[None, None], jnp.exp(s - lse[..., None]), 0.0)
        dv_blk = jnp.einsum("bhqk,bhqd->bhkd", p, dof,
                            preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vblk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - D[..., None]) * scale
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds.astype(kblk.dtype), kblk,
                             preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bhqk,bhqd->bhkd", ds, q,
                            preferred_element_type=jnp.float32)
        return dq, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, h, sq, d), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(n_blocks)))
    dk = jnp.moveaxis(dk_b, 0, 2).reshape(b, h, n_blocks * block_k, d)[:, :, :skv]
    dv = jnp.moveaxis(dv_b, 0, 2).reshape(b, h, n_blocks * block_k, d)[:, :, :skv]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention_vjp(q, k, v, *, causal=True, window=None, sm_scale=None,
                        block_k=1024, q_offset=0):
    """Memory-lean differentiable flash attention (XLA path).

    kv heads are repeated to H up front (grads summed back per group) —
    at microbatch scale this costs far less than the stacked-probability
    residuals it eliminates."""
    b, h, sq, d = q.shape
    kvh = k.shape[1]
    group = h // kvh
    scale = sm_scale if sm_scale is not None else d ** -0.5
    block_k = min(block_k, k.shape[2])
    if group > 1:
        k_full = jnp.repeat(k, group, axis=1)
        v_full = jnp.repeat(v, group, axis=1)
    else:
        k_full, v_full = k, v
    out = _flash_vjp(q, k_full, v_full, causal, window or 0, scale, block_k, q_offset)
    return out


def _flash_blockwise_causal(qf, kb, vb, scale, causal, win, q_offset, sq, skv, block_k, group):
    """Per-q-block kv iteration with static per-block trip bounds.

    q is split into blocks of ``block_k``; q block i only visits kv blocks
    [lo_i, hi_i] derived from causality/window. Because q-block index is a
    Python int under scan-free unrolling of the outer loop, the kv scan
    length is static per q block: upper-triangle compute is skipped
    entirely (the flash-attention causal saving, in pure XLA).
    """
    b, h = qf.shape[0], qf.shape[1]
    d = qf.shape[-1]
    block_q = block_k
    n_q = (sq + block_q - 1) // block_q
    pad_q = n_q * block_q - sq
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    outs = []
    n_kv_total = kb.shape[0]
    for qi in range(n_q):
        q_blk = qf[:, :, qi * block_q:(qi + 1) * block_q]
        q_pos = jnp.arange(block_q) + qi * block_q + q_offset
        hi_pos = qi * block_q + block_q - 1 + q_offset        # max visible key pos
        hi = min(n_kv_total, hi_pos // block_k + 1) if causal else n_kv_total
        lo = 0
        if win:
            lo_pos = max(0, qi * block_q + q_offset - win + 1)
            lo = min(lo_pos // block_k, n_kv_total)
        acc = jnp.zeros((b, h, block_q, d), jnp.float32)
        m = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l = jnp.zeros((b, h, block_q), jnp.float32)

        def body(carry, inputs):
            acc, m, l = carry
            kblk, vblk, blk_idx = inputs
            if group > 1:
                kblk = jnp.repeat(kblk, group, axis=1)
                vblk = jnp.repeat(vblk, group, axis=1)
            k_pos = blk_idx * block_k + jnp.arange(block_k)
            s, mask = _attend_block(q_blk, kblk, vblk, q_pos, k_pos, causal, win, scale)
            pad_mask = k_pos < skv
            s = jnp.where(pad_mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where((mask[None, None] & pad_mask[None, None, None]), p, 0.0)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vblk)
            return (acc_new, m_new, l_new), None

        idx = jnp.arange(lo, hi)
        (acc, m, l), _ = jax.lax.scan(body, (acc, m, l), (kb[lo:hi], vb[lo:hi], idx))
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
    out = jnp.concatenate(outs, axis=2)
    return out[:, :, :sq]
