"""Pallas TPU flash-attention kernel (forward).

Tiling: grid = (B * H, Sq / block_q, Skv / block_k). The last grid axis is
sequential on TPU, so the online-softmax accumulators (m, l, acc) live in
VMEM scratch and carry across kv blocks. GQA is handled in the BlockSpec
index maps: the kv block for q-head ``h`` reads kv-head ``h // group``,
so kv is never materialized per-q-head in HBM.

VMEM working set per program instance:
    q block  (block_q, d)        bf16
    k block  (block_k, d)        bf16
    v block  (block_k, d)        bf16
    acc      (block_q, d)        f32
    m, l     (block_q, 128)      f32 (lane-padded)
With block_q = block_k = 512 and d = 128 this is ~1.1 MB — comfortably
inside the ~16 MB/core VMEM budget while keeping the (512, 128) @
(128, 512) MXU matmuls hardware-aligned (multiples of 128).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,            # VMEM blocks
    o_ref,                          # output block
    m_ref, l_ref, acc_ref,          # VMEM scratch (carried over kv grid dim)
    *,
    sm_scale: float,
    causal: bool,
    window: int,                    # 0 = disabled
    block_q: int,
    block_k: int,
    q_offset: int,
    kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)            # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale                                 # (bq, bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    # kv padding rows (sequence padded up to a block multiple) never
    # contribute; padded q rows are sliced off by the caller.
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...][:, 0]                    # (bq,)
    l_prev = l_ref[...][:, 0]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_cur = l_prev * alpha + p.sum(axis=-1)

    acc = acc_ref[...] * alpha[:, None]
    acc += jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)
    acc_ref[...] = acc

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        l = l_ref[...][:, 0]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,                  # (B, H, Sq, D)
    k: jnp.ndarray,                  # (B, KVH, Skv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    q_offset: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    _, kvh, skv, _ = k.shape
    assert h % kvh == 0, "q heads must be a multiple of kv heads"
    group = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    # Odd (non-multiple-of-block) sequence lengths: pad up to block
    # multiples; padded kv positions are masked out inside the kernel
    # (k_pos < kv_len) and padded q rows are sliced off below.
    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    scale = sm_scale if sm_scale is not None else d ** -0.5

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * kvh, skv, d)
    vf = v.reshape(b * kvh, skv, d)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))

    grid = (b * h, (sq + pad_q) // block_q, (skv + pad_k) // block_k)

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=scale,
        causal=causal,
        window=window or 0,
        block_q=block_q,
        block_k=block_k,
        q_offset=q_offset,
        kv_len=skv,
    )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pad_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # m (running max, lane-padded)
            pltpu.VMEM((block_q, 128), jnp.float32),   # l (running denom)
            pltpu.VMEM((block_q, d), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :sq].reshape(b, h, sq, d)
