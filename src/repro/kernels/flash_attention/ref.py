"""Pure-jnp oracle for flash attention (naive softmax attention).

Materializes the full (Sq, Skv) score matrix — O(S^2) memory — so it is
only used for correctness testing against the Pallas/XLA implementations.
Supports causal masking, sliding windows, and GQA (n_q_heads a multiple
of n_kv_heads).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, KVH, S, D) -> (B, H, S, D) by repeating each kv head."""
    b, kvh, s, d = k.shape
    group = n_heads // kvh
    return jnp.repeat(k, group, axis=1)


def attention_ref(
    q: jnp.ndarray,                 # (B, H, Sq, D)
    k: jnp.ndarray,                 # (B, KVH, Skv, D)
    v: jnp.ndarray,                 # (B, KVH, Skv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,   # sliding window size (keys in (i-w, i])
    sm_scale: Optional[float] = None,
    q_offset: int = 0,              # absolute position of q[0] (decode/prefill chunks)
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    skv = k.shape[2]
    if k.shape[1] != h:
        k = repeat_kv(k, h)
        v = repeat_kv(v, h)
    scale = sm_scale if sm_scale is not None else d ** -0.5

    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None] + q_offset
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None and window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = jnp.where(mask[None, None], probs, 0.0)
    denom = probs.sum(axis=-1, keepdims=True)
    probs = probs / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
