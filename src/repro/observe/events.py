"""Structured workflow event log: ring buffer + optional JSONL sink.

Every component of the core (queues, task server, worker pools, thinker)
emits ``Event`` records at each hop of a task's life. The log is the
single source of truth for the paper-style evaluation: utilization
timelines, overhead breakdowns, and steering-gain comparisons are all
derived from it (``repro.observe.metrics`` / ``repro.observe.report``)
instead of ad-hoc timestamps.

Design notes:
  * **cheap hot path** — events land in a ``collections.deque`` ring
    buffer under a short lock (append + optional JSONL write only);
    subscribers run outside it. Snapshots (``events``/``by_task``) take
    the same lock so readers never observe a mid-mutation deque.
  * **bounded memory** — the ring buffer keeps the most recent
    ``capacity`` events; the JSONL sink (when enabled) keeps everything.
  * **streaming consumers** — ``subscribe`` registers a callback invoked
    inline at emit time (``MetricsAggregator`` uses this to aggregate
    without ever materializing the full trace).

Core modules hold an ``event_log`` attribute that defaults to ``None``
and duck-type against this class, so ``repro.core`` never imports
``repro.observe`` (no import cycle) and instrumentation costs one
attribute check when disabled.
"""

from __future__ import annotations

import atexit
import json
import os
import threading
import time
from collections import defaultdict, deque
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, List, Optional

# Canonical lifecycle stages, in causal order. ``completed`` / ``failed``
# are alternatives at the same position; ``speculated`` / ``retried``
# mark server-side recovery actions and sit outside the happy path.
STAGE_ORDER: tuple = (
    "submitted",          # Thinker built the request (queues.send_inputs)
    "queued",             # request pushed onto the task queue
    "picked_up",          # TaskServer popped the request
    "dispatched",         # handed to a WorkerPool slot
    "running",            # a worker began executing
    "completed",          # worker finished successfully
    "failed",             # worker raised / node died / timed out
    "result_received",    # Thinker popped the result
    "decision_made",      # Thinker's result processor finished reacting
)

# Stages emitted outside the linear lifecycle.
AUX_STAGES: tuple = ("speculated", "retried", "reallocated")

# The closed vocabulary of ``Event.kind`` values. Every ``Event(kind=...)``
# constructed anywhere in the tree must use one of these (enforced by the
# ``event-kind`` rule of ``repro.analyze``); consumers that dispatch on a
# kind not listed here are watching for an event that never fires. Add the
# kind here in the same change that introduces its first emitter.
EVENT_KINDS: tuple = (
    "task",          # lifecycle stage for one task (task_event)
    "cache",         # warm-worker proxy cache hit/miss (cache_event)
    "gauge",         # named scalar sample (gauge)
    "realloc",       # cross-pool resource move (realloc)
    "pool_resize",   # elastic fleet grow/shrink (pool_resize)
    "surrogate",     # surrogate-model retrain/rerank (surrogate_event)
    "profile",       # profiled code span (profile)
    "alert",         # SLO alert transition (alert)
    "remediation",   # auto-remediation attempt (remediation)
    "chaos",         # fault-injection action fired (chaos.schedule)
    "campaign_state",  # control-plane campaign transition (campaign_state)
)


@dataclass
class Event:
    """One observation. ``kind`` is ``task`` (lifecycle stage for a task),
    ``gauge`` (a named scalar sample, e.g. per-pool slot allocation),
    ``cache`` (a warm-worker cache ``hit``/``miss``), or ``realloc`` (a
    resource move)."""

    t: float                              # time.monotonic() at emit
    kind: str                             # task | gauge | realloc
    stage: str                            # lifecycle stage or gauge name
    task_id: Optional[str] = None
    method: Optional[str] = None
    topic: Optional[str] = None
    pool: Optional[str] = None
    value: Optional[float] = None         # gauges only
    info: Dict[str, Any] = field(default_factory=dict)


class EventLog:
    """Append-only event collector shared by every workflow component."""

    def __init__(
        self,
        capacity: int = 1 << 16,
        jsonl_path: Optional[str] = None,
        clock: Callable[[], float] = time.monotonic,
        rotate_bytes: Optional[int] = None,
        rotate_keep: int = 3,
    ) -> None:
        self._clock = clock
        self._buf: "deque[Event]" = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._subs: List[Callable[[Event], None]] = []
        self._jsonl_path = jsonl_path
        self._rotate_bytes = rotate_bytes
        self._rotate_keep = max(1, rotate_keep)
        self._jsonl_written = 0
        # Line-buffered so every event reaches the OS as it is emitted; a
        # SIGKILL'd federated child loses at most the line being written,
        # not its whole log — merged traces survive hard crashes.
        self._jsonl = open(jsonl_path, "w", buffering=1) if jsonl_path else None
        self._atexit_cb: Optional[Callable[[], None]] = None
        if self._jsonl is not None:
            self._atexit_cb = self.close
            atexit.register(self._atexit_cb)
        self.t0 = clock()

    # ------------------------------------------------------------------ emit
    def emit(self, event: Event) -> Event:
        with self._lock:
            self._buf.append(event)
            if self._jsonl is not None:
                row = asdict(event)
                row["t_rel"] = event.t - self.t0
                line = json.dumps(row) + "\n"
                self._jsonl.write(line)
                self._jsonl_written += len(line)
                if self._rotate_bytes and self._jsonl_written >= self._rotate_bytes:
                    self._rotate_locked()
            # Snapshot under the lock: a subscriber registering right now
            # replays the buffer (including this event) and lands in the
            # *next* emit's snapshot — never both, so no double delivery.
            subs = self._subs
        for fn in subs:
            fn(event)
        return event

    def task_event(self, stage: str, result: Any, pool: Optional[str] = None, **info: Any) -> Event:
        """Record a lifecycle stage for a ``repro.core.result.Result``.
        ``pool`` overrides the requested pool (worker pools pass their own
        name so execution-side stages carry the executing pool). The
        Result's ``TraceContext`` (when present) lands in ``info`` so
        JSONL logs from different processes correlate into one trace."""
        trace = getattr(result, "trace", None)
        if trace is not None:
            info = {**trace.as_dict(), **info}
        return self.emit(
            Event(
                t=self._clock(),
                kind="task",
                stage=stage,
                task_id=result.task_id,
                method=result.method,
                topic=result.topic,
                pool=pool if pool is not None else getattr(result.resources, "pool", None),
                info=info,
            )
        )

    def cache_event(self, outcome: str, result: Any, pool: Optional[str] = None, **info: Any) -> Event:
        """Record a warm-worker cache ``hit``/``miss`` for a task's proxy
        resolution (``info`` carries ``worker_id``, the proxy ``key`` and
        its ``nbytes``)."""
        return self.emit(
            Event(
                t=self._clock(),
                kind="cache",
                stage=outcome,
                task_id=result.task_id,
                method=result.method,
                topic=result.topic,
                pool=pool,
                info=info,
            )
        )

    def gauge(self, name: str, value: float, pool: Optional[str] = None, **info: Any) -> Event:
        """Record a scalar sample (e.g. ``slots`` per pool, queue backlog)."""
        return self.emit(
            Event(t=self._clock(), kind="gauge", stage=name, pool=pool, value=float(value), info=info)
        )

    def realloc(self, src: str, dst: str, n: int, **info: Any) -> Event:
        return self.emit(
            Event(t=self._clock(), kind="realloc", stage="reallocated", pool=dst,
                  value=float(n), info={"src": src, "dst": dst, "n": n, **info})
        )

    def pool_resize(self, pool: str, old: int, new: int, **info: Any) -> Event:
        """Record an elastic worker-fleet change (``kind="pool_resize"``,
        stage ``grow``/``shrink``, value = the new worker count). Reports
        integrate the paired ``workers`` gauges to get capacity over
        time; the resize events carry the why (``info["reason"]``)."""
        return self.emit(
            Event(t=self._clock(), kind="pool_resize",
                  stage="grow" if new >= old else "shrink", pool=pool,
                  value=float(new), info={"old": old, "new": new, **info})
        )

    def surrogate_event(self, stage: str, value: Optional[float] = None, **info: Any) -> Event:
        """Record a surrogate-model lifecycle observation (``kind=
        "surrogate"``): ``retrain`` (value = training rmse; ``info``
        carries round/duration/n) and ``rerank`` (value = acquisition
        regret; ``info`` carries the policy and batch size). Consumers
        that predate this kind ignore it — reports must tolerate
        unknown kinds rather than assume a closed set."""
        return self.emit(
            Event(t=self._clock(), kind="surrogate", stage=stage,
                  value=None if value is None else float(value), info=info)
        )

    def profile(self, name: str, t_start: float, wall_s: float,
                device_s: Optional[float] = None, **info: Any) -> Event:
        """Record a profiled code span (``kind="profile"``): ``t`` is the
        span start, ``value`` the wall duration in seconds, and ``info``
        carries the post-``block_until_ready`` device time for JAX calls
        (dispatch wall vs. device compute). These become spans in the
        Perfetto export alongside the task lifecycle."""
        if device_s is not None:
            info = {"device_s": float(device_s), **info}
        return self.emit(
            Event(t=t_start, kind="profile", stage=name, value=float(wall_s), info=info)
        )

    def alert(self, stage: str, name: str, value: Optional[float] = None,
              severity: str = "page", pool: Optional[str] = None, **info: Any) -> Event:
        """Record an SLO/anomaly alert transition (``kind="alert"``):
        ``stage`` is the lifecycle edge (``pending``/``firing``/
        ``resolved``), ``name`` identifies the objective, ``value`` is the
        signal reading that drove the transition, and ``info`` carries the
        burn rates / window config. Alerts flow through the same log as
        task events, so they land in traces, reports, and the JSONL sink
        alongside the work they describe."""
        return self.emit(
            Event(t=self._clock(), kind="alert", stage=stage, pool=pool,
                  value=None if value is None else float(value),
                  info={"name": name, "severity": severity, **info})
        )

    def remediation(self, action: str, alert: str, ok: bool = True,
                    pool: Optional[str] = None, **info: Any) -> Event:
        """Record an auto-remediation attempt (``kind="remediation"``):
        ``action`` names the handler (e.g. ``elastic_pre_grow``),
        ``alert`` the firing objective that triggered it, and ``ok``
        whether the handler ran cleanly. Every closed observe→steer loop
        leaves one of these in the log, so soak invariants can assert the
        system *acted* on its alerts, not just raised them."""
        return self.emit(
            Event(t=self._clock(), kind="remediation", stage=action, pool=pool,
                  value=1.0 if ok else 0.0, info={"alert": alert, "ok": bool(ok), **info})
        )

    def campaign_state(self, campaign: str, state: str, **info: Any) -> Event:
        """Record a control-plane campaign transition (``kind=
        "campaign_state"``): ``stage`` is the new state (``submitted`` /
        ``staged`` / ``running`` / ``paused`` / ``done`` / ``failed``),
        ``topic`` carries the campaign id, and ``info`` the why (e.g.
        ``reason="preempted"``, granted slots). The control plane emits
        these into its own JSONL log, so a fleet's multi-campaign history
        reads out of one trace alongside pool/gauge events."""
        return self.emit(
            Event(t=self._clock(), kind="campaign_state", stage=state,
                  topic=campaign, info=info)
        )

    # ------------------------------------------------------------- consumers
    def subscribe(self, fn: Callable[[Event], None], replay: bool = True) -> None:
        """Register a streaming consumer; with ``replay`` it first receives
        every buffered event, so late subscribers see a consistent view."""
        with self._lock:
            if replay:
                for ev in list(self._buf):
                    fn(ev)
            self._subs = self._subs + [fn]

    def unsubscribe(self, fn: Callable[[Event], None]) -> None:
        with self._lock:
            self._subs = [s for s in self._subs if s is not fn]

    def events(self) -> List[Event]:
        with self._lock:
            return list(self._buf)

    def by_task(self) -> Dict[str, List[Event]]:
        out: Dict[str, List[Event]] = defaultdict(list)
        for ev in self.events():
            if ev.kind == "task" and ev.task_id is not None:
                out[ev.task_id].append(ev)
        return dict(out)

    def __len__(self) -> int:
        return len(self._buf)

    def _rotate_locked(self) -> None:
        """Size-based rotation (caller holds the lock): the active file
        moves to ``path.1``, older generations shift up, the oldest past
        ``rotate_keep`` is dropped, and a fresh active file opens."""
        self._jsonl.flush()
        self._jsonl.close()
        base = self._jsonl_path
        for i in range(self._rotate_keep, 0, -1):
            src = base if i == 1 else f"{base}.{i - 1}"
            dst = f"{base}.{i}"
            if os.path.exists(src):
                os.replace(src, dst)
        self._jsonl = open(base, "w", buffering=1)
        self._jsonl_written = 0

    def close(self) -> None:
        if self._jsonl is not None:
            with self._lock:
                if self._jsonl is None:  # lost the race with another closer
                    return
                self._jsonl.flush()
                try:
                    os.fsync(self._jsonl.fileno())
                except OSError:
                    pass  # not a real file (e.g. a StringIO in tests)
                self._jsonl.close()
                self._jsonl = None
        if self._atexit_cb is not None:
            try:
                atexit.unregister(self._atexit_cb)
            except Exception:
                pass
            self._atexit_cb = None

    def __enter__(self) -> "EventLog":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


def _grouped(log_or_by_task) -> Dict[str, List[Event]]:
    """Accept an EventLog or an already-grouped ``by_task()`` mapping, so
    callers validating several properties pay for one grouping pass."""
    if hasattr(log_or_by_task, "by_task"):
        return log_or_by_task.by_task()
    return log_or_by_task


def lifecycle_gaps(log_or_by_task) -> Dict[str, List[str]]:
    """Validate lifecycle completeness: for every task seen in the log
    (or pre-grouped ``by_task()`` mapping), return the stages that are
    missing from its expected chain.

    Tasks created server-side (retry clones carry a ``retried`` stage;
    speculative twins share the original task_id) are exempt from the
    client-side stages; tasks that failed terminally before reaching a
    worker are exempt from ``running``. An empty dict means every task
    has a complete ``submitted -> queued -> dispatched -> running ->
    completed|failed -> result_received`` record.
    """
    by_task = _grouped(log_or_by_task)
    # Originals superseded by a retry never produce their own final result.
    retried_origins = {
        ev.info.get("origin")
        for evs in by_task.values()
        for ev in evs
        if ev.stage == "retried"
    }
    gaps: Dict[str, List[str]] = {}
    for tid, evs in by_task.items():
        stages = {e.stage for e in evs}
        missing: List[str] = []
        if "retried" not in stages:  # retry clones skip the client submit path
            missing += [s for s in ("submitted", "queued") if s not in stages]
        ran = "running" in stages
        terminal_fail = "failed" in stages and "completed" not in stages
        if not (terminal_fail and not ran):  # pre-dispatch failures never run
            missing += [s for s in ("dispatched", "running") if s not in stages]
        if "completed" not in stages and "failed" not in stages:
            missing.append("completed|failed")
        superseded = tid in retried_origins
        if not superseded and "result_received" not in stages:
            missing.append("result_received")
        if missing:
            gaps[tid] = missing
    return gaps


def lifecycle_order_violations(log_or_by_task) -> List[str]:
    """Check per-task causal ordering: the first occurrence of each stage
    must be non-decreasing in ``STAGE_ORDER``. Returns human-readable
    violation strings (empty list = ordering holds)."""
    rank = {s: i for i, s in enumerate(STAGE_ORDER)}
    out: List[str] = []
    for tid, evs in _grouped(log_or_by_task).items():
        first: Dict[str, float] = {}
        for ev in evs:
            if ev.stage in rank and ev.stage not in first:
                first[ev.stage] = ev.t
        seq = sorted(first.items(), key=lambda kv: rank[kv[0]])
        for (s_a, t_a), (s_b, t_b) in zip(seq, seq[1:]):
            # completed/failed share a rank slot; skip comparing them.
            if {s_a, s_b} == {"completed", "failed"}:
                continue
            if t_b < t_a:
                out.append(f"{tid}: {s_b} (t={t_b:.6f}) before {s_a} (t={t_a:.6f})")
    return out
