"""Distributed trace assembly: per-task spans, critical-path attribution,
and Chrome/Perfetto trace-event export.

The event log records *points* (lifecycle stages); the paper's Fig. 7
reasons about *intervals* — where a task's wall time actually went. This
module turns grouped task events into six spans per task:

    queue-wait   submitted       -> picked_up       (sat in the request queue)
    pickup       picked_up       -> dispatched      (server routing/batching)
    dispatch     dispatched      -> running         (pool queue + worker handoff)
    run          running         -> completed|failed (the task function)
    result-wait  completed|failed-> result_received (result queue + transfer)
    decision     result_received -> decision_made   (the Thinker reacting)

and attributes each task's *critical span* (its longest interval), so an
overhead report says not just "queue-wait averaged 3 ms" but "queue-wait
dominated 80% of tasks".

Because a ``TraceContext`` rides on every ``Result`` and lands in each
event's ``info``, events emitted by different *processes* (the client's
log and a spawned ``ProcessTaskServer``'s JSONL log) carry the same
``trace_id``; ``merge_jsonl`` interleaves the files by timestamp
(``time.monotonic`` is CLOCK_MONOTONIC: one system-wide clock on Linux)
into one causal trace. ``to_perfetto`` renders tasks, per-site lanes,
and ``kind="profile"`` spans (JAX kernel / surrogate timings) as
Chrome trace-event JSON loadable at https://ui.perfetto.dev.

Span building degrades gracefully: missing stages skip the affected
spans (a killed run still renders), out-of-order pairs are flagged
rather than producing negative durations, and failed tasks end their
``run`` span at ``failed``.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

from .events import Event

# (span name, start stage(s), end stage(s)) — first occurrence of any
# alternative counts; completed/failed are alternatives at one position.
SPAN_DEFS: Tuple[Tuple[str, Tuple[str, ...], Tuple[str, ...]], ...] = (
    ("queue-wait", ("submitted",), ("picked_up",)),
    ("pickup", ("picked_up",), ("dispatched",)),
    ("dispatch", ("dispatched",), ("running",)),
    ("run", ("running",), ("completed", "failed")),
    ("result-wait", ("completed", "failed"), ("result_received",)),
    ("decision", ("result_received",), ("decision_made",)),
)

SPAN_NAMES: Tuple[str, ...] = tuple(name for name, _, _ in SPAN_DEFS)


@dataclass
class Span:
    """One interval of a task's life."""

    name: str
    t0: float
    t1: float
    site: str = "main"

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


@dataclass
class TaskTrace:
    """All spans of one task (one attempt: retry clones trace separately,
    linked by trace_id/parent_span_id)."""

    task_id: str
    method: Optional[str] = None
    pool: Optional[str] = None
    trace_id: Optional[str] = None
    span_id: Optional[str] = None
    parent_span_id: Optional[str] = None
    ok: bool = True
    spans: List[Span] = field(default_factory=list)
    flags: List[str] = field(default_factory=list)  # e.g. out-of-order stages

    @property
    def critical(self) -> Optional[str]:
        """The dominating (longest) span's name."""
        if not self.spans:
            return None
        return max(self.spans, key=lambda s: s.duration).name

    @property
    def total_s(self) -> float:
        return sum(s.duration for s in self.spans)


def _as_events(log_or_events: Any) -> List[Event]:
    if hasattr(log_or_events, "events"):
        return log_or_events.events()
    return list(log_or_events)


# --------------------------------------------------------------------------
# JSONL loading / cross-process merging
# --------------------------------------------------------------------------

_EVENT_FIELDS = ("t", "kind", "stage", "task_id", "method", "topic", "pool", "value", "info")


def load_jsonl(path: str, site: Optional[str] = None) -> List[Event]:
    """Load an ``EventLog`` JSONL sink back into ``Event`` objects.

    ``site`` (default: the file's basename minus ``.jsonl``) is stamped
    into each event's ``info`` so merged traces keep their provenance.
    Truncated final lines (a SIGKILL'd writer) are skipped, not fatal.
    """
    if site is None:
        site = os.path.basename(path)
        if site.endswith(".jsonl"):
            site = site[: -len(".jsonl")]
    events: List[Event] = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError:
                continue  # torn tail line from a killed writer
            kw = {k: row.get(k) for k in _EVENT_FIELDS}
            kw["info"] = dict(kw.get("info") or {})
            kw["info"].setdefault("site", site)
            events.append(Event(**kw))
    return events


def merge_jsonl(paths: Sequence[str]) -> List[Event]:
    """Merge several processes' JSONL logs into one trace, ordered by the
    shared monotonic clock."""
    events: List[Event] = []
    for p in paths:
        events.extend(load_jsonl(p))
    events.sort(key=lambda ev: ev.t)
    return events


# --------------------------------------------------------------------------
# Span building
# --------------------------------------------------------------------------


def build_task_traces(log_or_events: Any) -> List[TaskTrace]:
    """Group task events and cut each task's timeline into spans."""
    by_task: Dict[str, List[Event]] = {}
    for ev in _as_events(log_or_events):
        if ev.kind == "task" and ev.task_id is not None:
            by_task.setdefault(ev.task_id, []).append(ev)

    traces: List[TaskTrace] = []
    for tid, evs in by_task.items():
        tr = TaskTrace(task_id=tid)
        first: Dict[str, Event] = {}
        for ev in evs:
            if ev.stage not in first:
                first[ev.stage] = ev
            if tr.method is None and ev.method:
                tr.method = ev.method
            if tr.trace_id is None and ev.info.get("trace_id"):
                tr.trace_id = ev.info["trace_id"]
                tr.span_id = ev.info.get("span_id")
                tr.parent_span_id = ev.info.get("parent_span_id")
        # Execution-side pool (the executing WorkerPool) wins over the
        # requested pool carried by client-side stages.
        for stage in ("running", "completed", "failed", "submitted"):
            ev = first.get(stage)
            if ev is not None and ev.pool is not None:
                tr.pool = ev.pool
                break
        tr.ok = "failed" not in first or "completed" in first

        for name, starts, ends in SPAN_DEFS:
            a = next((first[s] for s in starts if s in first), None)
            b = next((first[s] for s in ends if s in first), None)
            if a is None or b is None:
                continue  # missing stage: skip the span, keep the rest
            if b.t < a.t:
                tr.flags.append(f"out-of-order:{name}")
                continue
            tr.spans.append(
                Span(name=name, t0=a.t, t1=b.t, site=str(a.info.get("site", "main")))
            )
        traces.append(tr)
    traces.sort(key=lambda t: (t.spans[0].t0 if t.spans else 0.0))
    return traces


def span_summary(traces: Iterable[TaskTrace]) -> Dict[str, Any]:
    """Fig.-7-style overhead breakdown with critical-path attribution:
    per-span count/mean/total seconds, the share of total traced time,
    and how many tasks each span dominated."""
    agg: Dict[str, Dict[str, float]] = {
        name: {"count": 0, "total_s": 0.0} for name in SPAN_NAMES
    }
    critical: Dict[str, int] = {}
    n_tasks = 0
    flagged = 0
    for tr in traces:
        n_tasks += 1
        if tr.flags:
            flagged += 1
        for sp in tr.spans:
            agg[sp.name]["count"] += 1
            agg[sp.name]["total_s"] += sp.duration
        crit = tr.critical
        if crit is not None:
            critical[crit] = critical.get(crit, 0) + 1
    grand = sum(a["total_s"] for a in agg.values()) or 1.0
    spans = {
        name: {
            "count": int(a["count"]),
            "mean_s": (a["total_s"] / a["count"]) if a["count"] else 0.0,
            "total_s": a["total_s"],
            "frac": a["total_s"] / grand,
        }
        for name, a in agg.items()
        if a["count"]
    }
    return {
        "tasks": n_tasks,
        "flagged": flagged,
        "spans": spans,
        "critical_path": dict(sorted(critical.items(), key=lambda kv: -kv[1])),
    }


# --------------------------------------------------------------------------
# Perfetto / Chrome trace-event export
# --------------------------------------------------------------------------


def to_perfetto(log_or_events: Any) -> Dict[str, Any]:
    """Render the event log as Chrome trace-event JSON (Perfetto-loadable).

    Layout: one *process* per site (the client's log, each spawned
    server's log), one *thread* lane per span type, "X" complete events
    in microseconds. ``kind="profile"`` events (kernel/surrogate
    timings) get their own process with a lane per profiled name.
    """
    events = _as_events(log_or_events)
    if not events:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    t0 = min(ev.t for ev in events)

    trace_events: List[Dict[str, Any]] = []
    pids: Dict[str, int] = {}
    tids: Dict[Tuple[str, str], int] = {}

    def pid_for(site: str) -> int:
        if site not in pids:
            pids[site] = len(pids) + 1
            trace_events.append(
                {"ph": "M", "name": "process_name", "pid": pids[site], "tid": 0,
                 "args": {"name": site}}
            )
        return pids[site]

    def tid_for(site: str, lane: str) -> int:
        key = (site, lane)
        if key not in tids:
            tids[key] = len(tids) + 1
            trace_events.append(
                {"ph": "M", "name": "thread_name", "pid": pid_for(site),
                 "tid": tids[key], "args": {"name": lane}}
            )
        return tids[key]

    for tr in build_task_traces(events):
        for sp in tr.spans:
            args: Dict[str, Any] = {"task_id": tr.task_id}
            if tr.pool:
                args["pool"] = tr.pool
            if tr.trace_id:
                args["trace_id"] = tr.trace_id
                args["span_id"] = tr.span_id
            if tr.parent_span_id:
                args["parent_span_id"] = tr.parent_span_id
            if not tr.ok:
                args["failed"] = True
            trace_events.append(
                {
                    "name": f"{tr.method or '?'}:{sp.name}",
                    "cat": "task",
                    "ph": "X",
                    "ts": (sp.t0 - t0) * 1e6,
                    "dur": max(sp.duration, 0.0) * 1e6,
                    "pid": pid_for(sp.site),
                    "tid": tid_for(sp.site, sp.name),
                    "args": args,
                }
            )

    for ev in events:
        if ev.kind != "profile" or ev.value is None:
            continue
        site = str(ev.info.get("site", "main"))
        args = {k: v for k, v in ev.info.items() if k != "site"}
        trace_events.append(
            {
                "name": ev.stage,
                "cat": "profile",
                "ph": "X",
                "ts": (ev.t - t0) * 1e6,
                "dur": max(float(ev.value), 0.0) * 1e6,
                "pid": pid_for(f"profile:{site}"),
                "tid": tid_for(f"profile:{site}", ev.stage),
                "args": args,
            }
        )

    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


def export_perfetto(
    inputs: Union[str, Sequence[str]], out_path: str
) -> Dict[str, Any]:
    """Merge one or more JSONL event logs and write Perfetto JSON."""
    paths = [inputs] if isinstance(inputs, str) else list(inputs)
    events = merge_jsonl(paths)
    doc = to_perfetto(events)
    with open(out_path, "w") as fh:
        json.dump(doc, fh)
    return doc


# --------------------------------------------------------------------------
# Profiling hooks (used by kernel_bench / DeepEnsemble)
# --------------------------------------------------------------------------


def profiled_call(
    log: Optional[Any],
    name: str,
    fn,
    *args: Any,
    sync=None,
    **info: Any,
):
    """Run ``fn(*args)`` and emit a ``profile`` event around it.

    ``sync`` is called on the return value before the clock stops (pass
    ``jax.block_until_ready`` so the span covers device compute, not
    just async dispatch); the pre-sync wall time is recorded as
    ``dispatch_s`` and the post-sync remainder as ``device_s``. With
    ``log=None`` this is a zero-overhead passthrough.
    """
    if log is None:
        out = fn(*args)
        if sync is not None:
            out = sync(out)
        return out
    import time as _time

    t0 = _time.monotonic()
    out = fn(*args)
    t1 = _time.monotonic()
    device_s = None
    if sync is not None:
        out = sync(out)
        t2 = _time.monotonic()
        device_s = t2 - t1
        info.setdefault("dispatch_s", t1 - t0)
        wall = t2 - t0
    else:
        wall = t1 - t0
    log.profile(name, t_start=t0, wall_s=wall, device_s=device_s, **info)
    return out


__all__ = [
    "SPAN_DEFS",
    "SPAN_NAMES",
    "Span",
    "TaskTrace",
    "build_task_traces",
    "span_summary",
    "load_jsonl",
    "merge_jsonl",
    "to_perfetto",
    "export_perfetto",
    "profiled_call",
]
