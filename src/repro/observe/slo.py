"""Streaming SLO engine: declarative objectives, burn-rate alerting.

An ``SLOSpec`` is a list of ``SLOObjective``s — "p99 task latency under
1 s", "proc-pool utilization above 50%", "result-loss rate under 1%",
"queue backlog under 100", "retrain cadence under budget" — each
evaluated continuously against the live ``MetricsAggregator`` over a
pair of sliding windows (Google-SRE multi-window burn-rate alerting):

  * every sample is classified good/bad against the objective's
    threshold; ``burn = bad_fraction / error_budget`` per window;
  * the alert goes **pending** when the fast window (default 5 m) burns
    hot, **firing** when the slow window (default 1 h) confirms it
    (transient blips never page), and **resolved** once the fast window
    cools below ``resolve_burn`` (hysteresis — no flapping);
  * every transition is written into the ``EventLog`` as an ``alert``
    event, so alerts appear in traces, reports, Prometheus, and the
    JSONL record alongside the work they describe.

Signals come from two places. *Event-driven* objectives (``latency``,
``loss_rate``) sample from the aggregator's derived-sample stream — one
good/bad observation per completed task, twin-deduped. *Tick-driven*
objectives (``backlog``, ``utilization``, ``gauge``,
``retrain_cadence``) are polled by the engine thread each
``interval_s``. A ``latency`` objective with budget ``0.01`` is exactly
a windowed p99 bound: at most 1% of tasks may exceed the threshold.

``SLOEngine.on_fire`` registers auto-remediation handlers (match by
objective name, signal, or ``"*"``), invoked once per pending→firing
transition and recorded as ``remediation`` events — the closed
observe→steer loop the paper argues for.
"""

from __future__ import annotations

import logging
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from .events import EventLog
from .metrics import MetricsAggregator

logger = logging.getLogger("repro.observe.slo")

_SIGNALS = ("latency", "loss_rate", "backlog", "utilization", "gauge", "retrain_cadence")
# Tick-driven signals are polled; the rest stream from the aggregator.
_TICK_SIGNALS = frozenset(("backlog", "utilization", "gauge", "retrain_cadence"))


@dataclass
class SLOObjective:
    """One declarative objective.

    ``kind="ceiling"`` means values above ``threshold`` are bad;
    ``"floor"`` means values below it are. ``budget`` is the tolerated
    bad fraction (for ``loss_rate`` the threshold *is* the budget —
    "loss rate under threshold" is already a fraction). ``pool`` /
    ``method`` / ``gauge`` scope the signal; ``min_samples`` keeps a
    near-empty window from alerting on noise.
    """

    name: str
    signal: str
    threshold: float = 0.0
    kind: str = "ceiling"
    pool: Optional[str] = None
    method: Optional[str] = None
    gauge: Optional[str] = None
    budget: float = 0.1
    fast_window_s: float = 300.0
    slow_window_s: float = 3600.0
    burn_threshold: float = 1.0
    resolve_burn: float = 0.5
    min_samples: int = 5
    severity: str = "page"

    def __post_init__(self) -> None:
        if self.signal not in _SIGNALS:
            raise ValueError(f"SLO {self.name!r}: unknown signal {self.signal!r} "
                             f"(expected one of {_SIGNALS})")
        if self.kind not in ("ceiling", "floor"):
            raise ValueError(f"SLO {self.name!r}: kind must be 'ceiling' or 'floor'")
        if self.signal == "gauge" and not self.gauge:
            raise ValueError(f"SLO {self.name!r}: signal='gauge' requires a gauge name")
        if self.signal == "loss_rate" and not (0.0 < self.threshold <= 1.0):
            raise ValueError(f"SLO {self.name!r}: loss_rate threshold is a fraction in (0, 1]")
        if not (0.0 < self.budget <= 1.0):
            raise ValueError(f"SLO {self.name!r}: budget must be in (0, 1]")
        if self.fast_window_s >= self.slow_window_s:
            raise ValueError(f"SLO {self.name!r}: fast window must be shorter than slow")

    @property
    def effective_budget(self) -> float:
        return self.threshold if self.signal == "loss_rate" else self.budget

    def violated(self, value: float) -> bool:
        return value > self.threshold if self.kind == "ceiling" else value < self.threshold

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "name": self.name, "signal": self.signal, "threshold": self.threshold,
            "kind": self.kind, "budget": self.budget,
            "fast_window_s": self.fast_window_s, "slow_window_s": self.slow_window_s,
            "burn_threshold": self.burn_threshold, "resolve_burn": self.resolve_burn,
            "min_samples": self.min_samples, "severity": self.severity,
        }
        for k in ("pool", "method", "gauge"):
            if getattr(self, k) is not None:
                d[k] = getattr(self, k)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SLOObjective":
        return cls(**dict(d))


def default_objectives() -> List[SLOObjective]:
    """A sane starter set for an ``[observe.slo]`` table with no explicit
    objectives: p99-style latency, loss rate, and backlog ceilings."""
    return [
        SLOObjective(name="task-latency", signal="latency", threshold=1.0,
                     budget=0.01, severity="page"),
        SLOObjective(name="result-loss", signal="loss_rate", threshold=0.01,
                     severity="page"),
        SLOObjective(name="queue-backlog", signal="backlog", threshold=100.0,
                     budget=0.1, severity="ticket"),
    ]


@dataclass
class SLOSpec:
    """A bag of objectives plus the engine's evaluation cadence."""

    objectives: List[SLOObjective] = field(default_factory=default_objectives)
    interval_s: float = 0.25

    @classmethod
    def from_any(cls, value: Any) -> "SLOSpec":
        """Normalize spec-file shapes: ``True``/``{}`` → defaults, a list
        of objective dicts, or a full ``{"objectives": [...]}`` mapping."""
        if isinstance(value, cls):
            return value
        if value is True or value is None:
            return cls()
        if isinstance(value, (list, tuple)):
            return cls(objectives=[_norm_objective(o) for o in value])
        if isinstance(value, Mapping):
            d = dict(value)
            objectives = d.pop("objectives", None)
            spec = cls(interval_s=float(d.pop("interval_s", 0.25)))
            if d:
                raise ValueError(f"unknown SLO spec keys: {sorted(d)}")
            if objectives is not None:
                spec.objectives = [_norm_objective(o) for o in objectives]
            return spec
        raise ValueError(f"cannot build SLOSpec from {type(value).__name__}")

    def to_dict(self) -> Dict[str, Any]:
        return {"interval_s": self.interval_s,
                "objectives": [o.to_dict() for o in self.objectives]}


def _norm_objective(o: Any) -> SLOObjective:
    if isinstance(o, SLOObjective):
        return o
    if isinstance(o, Mapping):
        return SLOObjective.from_dict(o)
    raise ValueError(f"cannot build SLOObjective from {type(o).__name__}")


class _BurnWindow:
    """Sliding window of (t, bad) observations with an O(1) burn query."""

    def __init__(self, horizon_s: float) -> None:
        self.horizon_s = horizon_s
        self._q: "deque[Tuple[float, bool]]" = deque()
        self._bad = 0

    def add(self, t: float, bad: bool) -> None:
        self._q.append((t, bad))
        if bad:
            self._bad += 1

    def _evict(self, now: float) -> None:
        cutoff = now - self.horizon_s
        q = self._q
        while q and q[0][0] < cutoff:
            _, bad = q.popleft()
            if bad:
                self._bad -= 1

    def burn(self, now: float, budget: float, min_samples: int) -> Optional[float]:
        """bad_fraction / budget, or None when the window is too thin."""
        self._evict(now)
        n = len(self._q)
        if n < max(1, min_samples):
            return None
        return (self._bad / n) / budget

    def clear(self) -> None:
        self._q.clear()
        self._bad = 0


class _ObjectiveState:
    def __init__(self, obj: SLOObjective) -> None:
        self.obj = obj
        self.fast = _BurnWindow(obj.fast_window_s)
        self.slow = _BurnWindow(obj.slow_window_s)
        self.state = "ok"
        self.since: Optional[float] = None       # entered current state
        self.last_fired_t: Optional[float] = None
        self.fired_count = 0
        self.resolved_count = 0
        self.value: Optional[float] = None       # last raw signal reading
        self.fast_burn: Optional[float] = None
        self.slow_burn: Optional[float] = None

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.obj.name, "signal": self.obj.signal,
            "severity": self.obj.severity, "state": self.state,
            "threshold": self.obj.threshold, "kind": self.obj.kind,
            "pool": self.obj.pool, "value": self.value,
            "fast_burn": self.fast_burn, "slow_burn": self.slow_burn,
            "since": self.since, "fired_count": self.fired_count,
            "resolved_count": self.resolved_count,
        }


class SLOEngine:
    """Evaluate an ``SLOSpec`` against live metrics; alert and remediate.

    The engine shares a ``MetricsAggregator`` with the exporter/ops
    server (or builds its own from the log), registers a derived-sample
    listener for event-driven objectives, and runs a daemon tick thread
    for polled ones. ``transitions`` records every state change
    (including silent pending→ok de-escalations) for post-hoc gates.
    """

    def __init__(
        self,
        log: Optional[EventLog],
        spec: Any = None,
        aggregator: Optional[MetricsAggregator] = None,
        slots_by_pool: Optional[Dict[str, int]] = None,
        anomaly: Optional[Any] = None,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.log = log
        self.spec = SLOSpec.from_any(spec)
        self.agg = aggregator if aggregator is not None else MetricsAggregator(log)
        self.slots_by_pool = dict(slots_by_pool or {})
        self.anomaly = anomaly
        self._clock = clock
        self._lock = threading.Lock()
        self._states = [_ObjectiveState(o) for o in self.spec.objectives]
        self.transitions: List[Dict[str, Any]] = []
        self._handlers: List[Tuple[str, Callable[[Dict[str, Any]], Any], str]] = []
        self.remediations_run = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.agg.add_listener(self._on_sample)

    # ----------------------------------------------------------- remediation
    def on_fire(self, selector: str, fn: Callable[[Dict[str, Any]], Any],
                label: Optional[str] = None) -> None:
        """Register a remediation handler. ``selector`` matches the
        objective's name, its signal, or ``"*"``; the handler receives
        the alert dict on each pending→firing transition."""
        self._handlers.append((selector, fn, label or getattr(fn, "__name__", "handler")))

    def _remediate(self, st: _ObjectiveState) -> None:
        alert = st.to_dict()
        for selector, fn, label in self._handlers:
            if selector not in ("*", st.obj.name, st.obj.signal):
                continue
            ok, detail = True, None
            try:
                detail = fn(alert)
            except Exception as exc:  # noqa: BLE001 - a broken handler must not kill the engine
                ok, detail = False, f"{type(exc).__name__}: {exc}"
                logger.exception("remediation %s for alert %s raised", label, st.obj.name)
            self.remediations_run += 1
            if self.log is not None:
                self.log.remediation(label, alert=st.obj.name, ok=ok,
                                     pool=st.obj.pool, detail=detail)

    # ------------------------------------------------------------- sampling
    def _on_sample(self, sample: Dict[str, object]) -> None:
        kind = sample.get("type")
        with self._lock:
            for st in self._states:
                obj = st.obj
                if obj.signal == "latency" and kind == "latency":
                    if obj.method is not None and sample.get("method") != obj.method:
                        continue
                    if obj.pool is not None and sample.get("pool") != obj.pool:
                        continue
                    seconds = float(sample["seconds"])  # type: ignore[arg-type]
                    st.value = seconds
                    bad = obj.violated(seconds)
                elif obj.signal == "loss_rate" and kind == "delivery":
                    if obj.method is not None and sample.get("method") != obj.method:
                        continue
                    if obj.pool is not None and sample.get("pool") != obj.pool:
                        continue
                    bad = not bool(sample.get("ok", True))
                else:
                    continue
                t = float(sample.get("t") or self._clock())
                st.fast.add(t, bad)
                st.slow.add(t, bad)

    def _sample_value(self, obj: SLOObjective) -> Optional[float]:
        """Current reading for a tick-driven objective (None = no data,
        skip this tick — an empty system is neither good nor bad)."""
        if obj.signal == "backlog":
            if obj.pool is not None:
                return float(self.agg.backlog(obj.pool))
            pools = self.agg.pool_stats()
            return float(max((st.backlog for st in pools.values()), default=0))
        if obj.signal == "utilization":
            return self._utilization_value(obj)
        if obj.signal == "gauge":
            by_pool = self.agg.gauges().get(obj.gauge or "")
            if not by_pool:
                return None
            if obj.pool is not None:
                return by_pool.get(obj.pool)
            if len(by_pool) == 1:
                return next(iter(by_pool.values()))
            vals = by_pool.values()
            return max(vals) if obj.kind == "ceiling" else min(vals)
        if obj.signal == "retrain_cadence":
            with self.agg._lock:
                retrains = [ev.t for ev in self.agg.surrogate_events if ev.stage == "retrain"]
            if not retrains:
                return None
            return self._clock() - retrains[-1]
        return None

    def _utilization_value(self, obj: SLOObjective) -> Optional[float]:
        """Instantaneous busy fraction (running / capacity). Sampled only
        while the scoped pools have outstanding work — an idle tail must
        not breach a utilization floor."""
        pools = self.agg.pool_stats()
        gauges = self.agg.gauges()
        names = [obj.pool] if obj.pool is not None else sorted(pools)
        worst: Optional[float] = None
        outstanding = 0
        for name in names:
            st = pools.get(name)
            if st is None:
                continue
            outstanding += st.backlog + st.running
            cap = (gauges.get("workers", {}).get(name)
                   or gauges.get("slots", {}).get(name)
                   or self.slots_by_pool.get(name))
            if not cap:
                continue
            frac = st.running / float(cap)
            worst = frac if worst is None else min(worst, frac)
        if outstanding == 0:
            return None
        return worst

    # ----------------------------------------------------------------- tick
    def tick(self, now: Optional[float] = None) -> None:
        now = self._clock() if now is None else now
        with self._lock:
            for st in self._states:
                obj = st.obj
                if obj.signal in _TICK_SIGNALS:
                    value = self._sample_value(obj)
                    if value is not None:
                        st.value = value
                        bad = obj.violated(value)
                        st.fast.add(now, bad)
                        st.slow.add(now, bad)
                self._advance(st, now)
        if self.anomaly is not None:
            self.anomaly.tick(now)

    def _advance(self, st: _ObjectiveState, now: float) -> None:
        obj = st.obj
        budget = obj.effective_budget
        st.fast_burn = st.fast.burn(now, budget, obj.min_samples)
        st.slow_burn = st.slow.burn(now, budget, obj.min_samples)
        hot_fast = st.fast_burn is not None and st.fast_burn >= obj.burn_threshold
        hot_slow = st.slow_burn is not None and st.slow_burn >= obj.burn_threshold
        # Cooling is judged on the fast window alone (hysteresis via
        # resolve_burn); a drained window (no recent samples) is cool —
        # no data means no ongoing violation.
        cool = st.fast_burn is None or st.fast_burn < obj.resolve_burn * obj.burn_threshold
        if st.state == "ok":
            if hot_fast and hot_slow:
                self._transition(st, "firing", now)
            elif hot_fast:
                self._transition(st, "pending", now)
        elif st.state == "pending":
            if hot_fast and hot_slow:
                self._transition(st, "firing", now)
            elif cool:
                self._transition(st, "ok", now, emit=False)
        elif st.state == "firing":
            if cool:
                self._transition(st, "ok", now)

    def _transition(self, st: _ObjectiveState, to: str, now: float, emit: bool = True) -> None:
        obj = st.obj
        frm, st.state = st.state, to
        duration = (now - st.since) if st.since is not None else None
        st.since = now
        rec: Dict[str, Any] = {"t": now, "name": obj.name, "from": frm, "to": to,
                               "value": st.value, "fast_burn": st.fast_burn,
                               "slow_burn": st.slow_burn}
        if to == "firing":
            st.fired_count += 1
            st.last_fired_t = now
        elif frm == "firing":
            st.resolved_count += 1
            if st.last_fired_t is not None:
                rec["firing_s"] = now - st.last_fired_t
        self.transitions.append(rec)
        stage = {"firing": "firing", "pending": "pending"}.get(to, "resolved")
        if emit and self.log is not None:
            info: Dict[str, Any] = {"signal": obj.signal, "threshold": obj.threshold,
                                    "from": frm}
            if st.fast_burn is not None:
                info["fast_burn"] = round(st.fast_burn, 4)
            if st.slow_burn is not None:
                info["slow_burn"] = round(st.slow_burn, 4)
            if "firing_s" in rec:
                info["firing_s"] = round(rec["firing_s"], 6)
            self.log.alert(stage, obj.name, value=st.value,
                           severity=obj.severity, pool=obj.pool, **info)
        logger.info("slo: %s %s -> %s (value=%s fast=%s slow=%s)",
                    obj.name, frm, to, st.value, st.fast_burn, st.slow_burn)
        if to == "firing":
            self._remediate(st)

    # ------------------------------------------------------------ accessors
    def alerts(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [st.to_dict() for st in self._states]

    def firing(self) -> List[str]:
        with self._lock:
            return [st.obj.name for st in self._states if st.state == "firing"]

    def settle(self, timeout_s: float = 5.0, poll_s: Optional[float] = None) -> bool:
        """Tick until nothing is firing (or timeout). Call after a run's
        work drains so resolution events land before teardown. Parks on
        the engine's stop event between ticks, so ``stop()`` interrupts
        a settle immediately instead of waiting out the poll interval."""
        poll = poll_s if poll_s is not None else max(0.01, self.spec.interval_s)
        deadline = self._clock() + timeout_s
        while True:
            self.tick()
            if not self.firing():
                return True
            if self._clock() >= deadline:
                return False
            if self._stop.wait(poll):
                return False

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "SLOEngine":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True, name="slo-engine")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.tick()
            except Exception:  # noqa: BLE001 - the evaluator must outlive bad samples
                logger.exception("slo tick failed")
            self._stop.wait(self.spec.interval_s)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def rebind(self, log: Optional[EventLog],
               aggregator: Optional[MetricsAggregator] = None) -> None:
        """Repoint at a fresh log/aggregator (checkpoint resume): windows
        and alert states reset — the old log's history is another run."""
        with self._lock:
            self.log = log
            self._states = [_ObjectiveState(o) for o in self.spec.objectives]
        self.agg.remove_listener(self._on_sample)
        self.agg = aggregator if aggregator is not None else MetricsAggregator(log)
        self.agg.add_listener(self._on_sample)


__all__ = [
    "SLOObjective",
    "SLOSpec",
    "SLOEngine",
    "default_objectives",
]
