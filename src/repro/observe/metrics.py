"""Streaming aggregation of the workflow event log.

``MetricsAggregator`` consumes ``Event``s one at a time (subscribe it to
an ``EventLog`` or feed it a recorded trace) and maintains:

  * **per-pool stats** — in-flight counts, queue backlog (submitted but
    not yet running), completed/failed totals, and the busy-slot-seconds
    integral that utilization timelines are built from;
  * **per-method latency histograms** — log-spaced streaming histograms
    of compute time with approximate quantiles;
  * **overhead breakdown** — the paper's timeline decomposition of each
    task into queue / dispatch / compute / result-communication spans;
  * **capacity integrals** — piecewise-constant integration of per-pool
    ``slots`` gauges, so per-pool utilization stays correct while an
    ``AdaptiveReallocator`` moves slots mid-run.

All state is O(pools + methods + in-flight tasks): per-task marks are
dropped once the task's result is received, so the aggregator can watch
arbitrarily long campaigns.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from .events import Event, EventLog


class LatencyHistogram:
    """Fixed log-spaced bucket histogram with streaming quantiles."""

    def __init__(self, lo: float = 1e-4, hi: float = 1e3, n_buckets: int = 64) -> None:
        self._log_lo = math.log(lo)
        self._log_hi = math.log(hi)
        self._n = n_buckets
        self.counts = [0] * (n_buckets + 2)  # + underflow / overflow
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def _bucket(self, x: float) -> int:
        if x <= 0 or math.log(x) < self._log_lo:
            return 0
        if math.log(x) >= self._log_hi:
            return self._n + 1
        frac = (math.log(x) - self._log_lo) / (self._log_hi - self._log_lo)
        return 1 + int(frac * self._n)

    def _bucket_upper(self, i: int) -> float:
        if i <= 0:
            return math.exp(self._log_lo)
        if i >= self._n + 1:
            return math.inf
        return math.exp(self._log_lo + (self._log_hi - self._log_lo) * i / self._n)

    def observe(self, x: float) -> None:
        self.counts[self._bucket(x)] += 1
        self.count += 1
        self.total += x
        self.min = x if self.min is None else min(self.min, x)
        self.max = x if self.max is None else max(self.max, x)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Approximate quantile: upper edge of the bucket holding rank q."""
        if not self.count:
            return 0.0
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                return min(self._bucket_upper(i), self.max if self.max is not None else math.inf)
        return self.max or 0.0


@dataclass
class PoolStats:
    pool: str
    submitted: int = 0
    backlog: int = 0          # submitted/queued/dispatched but not yet running
    running: int = 0
    completed: int = 0
    failed: int = 0
    busy_seconds: float = 0.0  # integral of (tasks running) over time


@dataclass
class CacheStats:
    """Warm-worker cache counters for one method."""

    hits: int = 0
    misses: int = 0
    bytes_saved: int = 0   # fabric bytes NOT re-fetched thanks to hits

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclass
class BatchStats:
    """Dispatch batch occupancy (from ``batch_occupancy`` gauges)."""

    batches: int = 0
    tasks: int = 0
    max_occupancy: int = 0

    @property
    def mean_occupancy(self) -> float:
        return self.tasks / self.batches if self.batches else 0.0


@dataclass
class SpanStats:
    """Mean/total accumulator for one overhead span."""

    count: int = 0
    total: float = 0.0

    def add(self, x: float) -> None:
        self.count += 1
        self.total += x

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


@dataclass
class _Capacity:
    """Piecewise-constant capacity track for one pool."""

    value: float = 0.0
    since: Optional[float] = None
    integral: float = 0.0

    def set(self, t: float, value: float) -> None:
        if self.since is not None:
            self.integral += self.value * (t - self.since)
        self.value = value
        self.since = t

    def integral_until(self, t: float) -> float:
        extra = self.value * (t - self.since) if self.since is not None else 0.0
        return self.integral + extra


# Overhead spans: (name, start stage, end stage).
_SPANS: Tuple[Tuple[str, str, str], ...] = (
    ("queue", "submitted", "dispatched"),
    ("dispatch", "dispatched", "running"),
    ("compute", "running", "completed"),
    ("result", "completed", "result_received"),
)

# Stages that may introduce per-task transient state. Later stages never
# (re)create it: a straggler twin finishing after ``result_received``
# already dropped the task's marks must not resurrect them (that would
# leak one dict per task and re-count the task as a fresh completion).
_INTRO_STAGES = frozenset(
    ("submitted", "queued", "picked_up", "dispatched", "retried", "speculated")
)


class MetricsAggregator:
    """Consume events, expose live workflow metrics. Thread-safe."""

    def __init__(self, log: Optional[EventLog] = None) -> None:
        self._lock = threading.Lock()
        self._pools: Dict[str, PoolStats] = {}
        self._methods: Dict[str, LatencyHistogram] = {}
        self._spans: Dict[str, SpanStats] = {}
        self._capacity: Dict[str, _Capacity] = {}
        self._cache: Dict[str, CacheStats] = {}
        self._batches: Dict[str, BatchStats] = {}
        # transient per-task state, dropped at result_received; running
        # intervals key on (task_id, worker_id) so speculative copies
        # executing concurrently stay distinct
        self._marks: Dict[str, Dict[str, float]] = {}
        self._run_pool: Dict[Tuple[str, Optional[int]], str] = {}
        self._run_start: Dict[Tuple[str, Optional[int]], float] = {}
        self.t_first: Optional[float] = None
        self.t_last: Optional[float] = None
        self.reallocations: List[Event] = []
        self.surrogate_events: List[Event] = []
        # Elastic worker fleets: ``workers`` gauges integrate to capacity
        # over time (the denominator of fleet utilization); the resize
        # events carry old/new/reason for the report.
        self._fleet: Dict[str, _Capacity] = {}
        self.pool_resizes: List[Event] = []
        # Last-seen value of every gauge, keyed (name, pool) — generic
        # gauges (e.g. the elastic scaler's ``arrival_rate``) surface in
        # snapshots/Prometheus without bespoke handling per gauge.
        self._gauges: Dict[Tuple[str, Optional[str]], float] = {}
        # Profiled code spans (kernel/surrogate timings): total wall per name.
        self._profiles: Dict[str, SpanStats] = {}
        # Alert/remediation events (SLO engine, anomaly detector) are
        # first-class: kept verbatim for reports plus a per-alert latest
        # state so snapshots/Prometheus can say what is firing *now*.
        self.alert_events: List[Event] = []
        self.remediation_events: List[Event] = []
        self._alert_state: Dict[str, Dict[str, object]] = {}
        # Derived-sample listeners: called OUTSIDE the aggregator lock
        # with small dicts ({"type": "latency"|"delivery", ...}) as tasks
        # complete — the SLO engine and anomaly detector consume these
        # instead of re-deriving latency from raw events (the twin-dedup
        # logic lives here once).
        self._listeners: List[Callable[[Dict[str, object]], None]] = []
        # Forward-compat: kinds this aggregator does not understand are
        # counted, never dropped silently or crashed on — newer emitters
        # may share a log with older consumers.
        self.unknown_kinds: Dict[str, int] = {}
        if log is not None:
            log.subscribe(self.observe, replay=True)

    def add_listener(self, fn: Callable[[Dict[str, object]], None]) -> None:
        """Register a derived-sample consumer (copy-on-write, like the
        EventLog subscriber list — safe against concurrent observe)."""
        with self._lock:
            self._listeners = self._listeners + [fn]

    def remove_listener(self, fn: Callable[[Dict[str, object]], None]) -> None:
        with self._lock:
            self._listeners = [f for f in self._listeners if f is not fn]

    # ----------------------------------------------------------------- ingest
    def _pool(self, name: Optional[str]) -> PoolStats:
        name = name or "default"
        st = self._pools.get(name)
        if st is None:
            st = self._pools[name] = PoolStats(pool=name)
        return st

    def observe(self, ev: Event) -> None:
        samples: List[Dict[str, object]] = []
        with self._lock:
            listeners = self._listeners
            self._observe_locked(ev, samples)
        # Derived samples are delivered outside the lock so listeners may
        # freely call back into accessors (which take it).
        for fn in listeners:
            for s in samples:
                fn(s)

    def _observe_locked(self, ev: Event, samples: List[Dict[str, object]]) -> None:
        self.t_first = ev.t if self.t_first is None else min(self.t_first, ev.t)
        self.t_last = ev.t if self.t_last is None else max(self.t_last, ev.t)
        if ev.kind == "gauge":
            if ev.value is not None:
                self._gauges[(ev.stage, ev.pool)] = float(ev.value)
            if ev.stage == "slots" and ev.pool is not None:
                self._capacity.setdefault(ev.pool, _Capacity()).set(ev.t, ev.value or 0.0)
            elif ev.stage == "workers" and ev.pool is not None:
                self._fleet.setdefault(ev.pool, _Capacity()).set(ev.t, ev.value or 0.0)
            elif ev.stage == "batch_occupancy":
                st = self._batches.setdefault(ev.info.get("method") or "?", BatchStats())
                n = int(ev.value or 0)
                st.batches += 1
                st.tasks += n
                st.max_occupancy = max(st.max_occupancy, n)
            return
        if ev.kind == "cache":
            cs = self._cache.setdefault(ev.method or "?", CacheStats())
            if ev.stage == "hit":
                cs.hits += 1
                cs.bytes_saved += int(ev.info.get("nbytes") or 0)
            else:
                cs.misses += 1
            return
        if ev.kind == "realloc":
            self.reallocations.append(ev)
            return
        if ev.kind == "pool_resize":
            self.pool_resizes.append(ev)
            return
        if ev.kind == "surrogate":
            self.surrogate_events.append(ev)
            return
        if ev.kind == "profile":
            self._profiles.setdefault(ev.stage, SpanStats()).add(float(ev.value or 0.0))
            return
        if ev.kind == "alert":
            self.alert_events.append(ev)
            name = str(ev.info.get("name") or "?")
            st = self._alert_state.setdefault(
                name, {"name": name, "state": "ok", "severity": "page", "transitions": 0}
            )
            st["state"] = ev.stage if ev.stage in ("pending", "firing") else "ok"
            st["severity"] = ev.info.get("severity", st["severity"])
            st["t"] = ev.t
            st["value"] = ev.value
            st["transitions"] = int(st["transitions"]) + 1  # type: ignore[call-overload]
            return
        if ev.kind == "remediation":
            self.remediation_events.append(ev)
            return
        if ev.kind != "task":
            self.unknown_kinds[ev.kind] = self.unknown_kinds.get(ev.kind, 0) + 1
            return
        if ev.task_id is None:
            return

        tid, stage = ev.task_id, ev.stage
        marks = self._marks.get(tid)
        # "first" = first time this stage is seen for a still-tracked
        # task; speculative twins share a task_id, so their duplicate
        # running/completed events must not re-count the task.
        first = marks is not None and stage not in marks
        if marks is None and stage in _INTRO_STAGES:
            marks = self._marks[tid] = {}
            first = True
        if marks is not None:
            marks.setdefault(stage, ev.t)

        if stage == "submitted":
            st = self._pool(ev.pool)
            st.submitted += 1
            st.backlog += 1
        elif stage == "running":
            # Pool name on running/completed events is the executing
            # WorkerPool's name — the ground truth for busy accounting.
            # Busy intervals key on (task, worker) so concurrent
            # speculative copies are each accounted for.
            pool = ev.pool or "default"
            self._pool(pool).running += 1
            key = (tid, ev.info.get("worker_id"))
            self._run_pool[key] = pool
            self._run_start[key] = ev.t
            if first:  # only the first copy leaves the backlog
                # Backlog was counted under the *requested* pool.
                origin = self._pool(ev.info.get("requested_pool") or pool)
                if origin.backlog > 0:
                    origin.backlog -= 1
        elif stage in ("completed", "failed"):
            key = (tid, ev.info.get("worker_id"))
            pool = self._run_pool.pop(key, ev.pool or "default")
            st = self._pool(pool)
            start = self._run_start.pop(key, None)
            if start is not None:
                # Every copy's worker time is real busy time, even a
                # speculative loser's — count it all.
                st.busy_seconds += ev.t - start
                if st.running > 0:
                    st.running -= 1
            elif marks is not None and "running" not in marks:
                # failed before running (e.g. unknown method): clear backlog
                if st.backlog > 0:
                    st.backlog -= 1
            if stage == "completed":
                if first:  # one completion per task, not per copy
                    st.completed += 1
                    hist = self._methods.get(ev.method or "?")
                    if hist is None:
                        hist = self._methods[ev.method or "?"] = LatencyHistogram()
                    if start is not None:
                        hist.observe(ev.t - start)
                        samples.append({"type": "latency", "t": ev.t, "method": ev.method or "?",
                                        "pool": pool, "seconds": ev.t - start})
                    samples.append({"type": "delivery", "t": ev.t, "method": ev.method or "?",
                                    "pool": pool, "ok": True})
            elif first:
                st.failed += 1
                samples.append({"type": "delivery", "t": ev.t, "method": ev.method or "?",
                                "pool": pool, "ok": False})
        elif stage == "result_received":
            if marks is not None:
                for name, a, b in _SPANS:
                    if a in marks and b in marks and marks[b] >= marks[a]:
                        self._spans.setdefault(name, SpanStats()).add(marks[b] - marks[a])
            # Drop transient state: keeps memory O(in-flight). Later
            # stages (decision_made, a straggler loser's completion)
            # find no marks and are ignored rather than re-created.
            self._marks.pop(tid, None)

    # -------------------------------------------------------------- accessors
    def pool_stats(self) -> Dict[str, PoolStats]:
        with self._lock:
            return {k: PoolStats(**vars(v)) for k, v in self._pools.items()}

    def method_stats(self) -> Dict[str, Dict[str, float]]:
        with self._lock:
            return {
                m: {
                    "count": h.count,
                    "mean_s": h.mean,
                    "p50_s": h.quantile(0.5),
                    "p95_s": h.quantile(0.95),
                    "min_s": h.min or 0.0,
                    "max_s": h.max or 0.0,
                }
                for m, h in self._methods.items()
            }

    def method_histogram(self, method: str) -> Optional[LatencyHistogram]:
        with self._lock:
            return self._methods.get(method)

    def overhead(self) -> Dict[str, Dict[str, float]]:
        """Per-span mean/total seconds: queue, dispatch, compute, result."""
        with self._lock:
            return {
                name: {"mean_s": s.mean, "total_s": s.total, "count": s.count}
                for name, s in self._spans.items()
            }

    def cache_stats(self) -> Dict[str, CacheStats]:
        """Warm-worker cache hit/miss counters per method, plus a
        ``total`` roll-up (hit_rate is the cache-hit-rate gauge)."""
        with self._lock:
            out = {m: CacheStats(**vars(c)) for m, c in self._cache.items()}
        total = CacheStats()
        for c in out.values():
            total.hits += c.hits
            total.misses += c.misses
            total.bytes_saved += c.bytes_saved
        out["total"] = total
        return out

    def batch_stats(self) -> Dict[str, BatchStats]:
        """Dispatch batch occupancy per method, plus a ``total`` roll-up
        (mean_occupancy is the batch-occupancy gauge)."""
        with self._lock:
            out = {m: BatchStats(**vars(b)) for m, b in self._batches.items()}
        total = BatchStats()
        for b in out.values():
            total.batches += b.batches
            total.tasks += b.tasks
            total.max_occupancy = max(total.max_occupancy, b.max_occupancy)
        out["total"] = total
        return out

    def surrogate_stats(self) -> Dict[str, object]:
        """Summary of surrogate lifecycle events: retrain count/cadence,
        the prediction-error (rmse) trajectory, and the acquisition-regret
        trajectory. Empty-ish dict when no surrogate ran."""
        with self._lock:
            evs = list(self.surrogate_events)
        retrains = [ev for ev in evs if ev.stage == "retrain"]
        reranks = [ev for ev in evs if ev.stage == "rerank"]
        ts = [ev.t for ev in retrains]
        cadence = (
            [round(b - a, 6) for a, b in zip(ts, ts[1:])] if len(ts) > 1 else []
        )
        return {
            "retrains": len(retrains),
            "retrain_cadence_s": cadence,
            "rmse": [ev.value for ev in retrains if ev.value is not None],
            "regret": [ev.value for ev in reranks if ev.value is not None],
            "policy": next(
                (ev.info.get("policy") for ev in reversed(reranks) if ev.info.get("policy")),
                None,
            ),
        }

    def gauges(self) -> Dict[str, Dict[str, float]]:
        """Last-seen value of every gauge: ``{name: {pool: value}}``
        (pool ``""`` for gauges without one)."""
        with self._lock:
            items = list(self._gauges.items())
        out: Dict[str, Dict[str, float]] = {}
        for (name, pool), value in items:
            out.setdefault(name, {})[pool or ""] = value
        return out

    def profile_stats(self) -> Dict[str, Dict[str, float]]:
        """Profiled code spans (``kind="profile"``): count/mean/total wall
        seconds per profiled name."""
        with self._lock:
            return {
                name: {"count": s.count, "mean_s": s.mean, "total_s": s.total}
                for name, s in self._profiles.items()
            }

    def alert_stats(self) -> Dict[str, object]:
        """Alert/remediation roll-up: transition counts, which objectives
        are firing right now, and per-alert latest state."""
        with self._lock:
            events = list(self.alert_events)
            states = {k: dict(v) for k, v in self._alert_state.items()}
            remediations = len(self.remediation_events)
            remediations_ok = sum(
                1 for e in self.remediation_events if e.info.get("ok", True)
            )
        return {
            "events": len(events),
            "fired": sum(1 for e in events if e.stage == "firing"),
            "resolved": sum(1 for e in events if e.stage == "resolved"),
            "firing": sorted(k for k, v in states.items() if v["state"] == "firing"),
            "states": states,
            "remediations": remediations,
            "remediations_ok": remediations_ok,
        }

    def backlog(self, pool: str) -> int:
        with self._lock:
            st = self._pools.get(pool)
            return st.backlog if st else 0

    def makespan(self) -> float:
        with self._lock:
            if self.t_first is None or self.t_last is None:
                return 0.0
            return self.t_last - self.t_first

    def capacity_slot_seconds(self, pool: str, until: Optional[float] = None) -> Optional[float]:
        """Integral of the pool's ``slots`` gauge over the observed window
        (None when no gauge was ever recorded for the pool)."""
        with self._lock:
            cap = self._capacity.get(pool)
            if cap is None:
                return None
            return cap.integral_until(until if until is not None else (self.t_last or 0.0))

    def fleet_worker_seconds(self, pool: str, until: Optional[float] = None) -> Optional[float]:
        """Integral of the pool's ``workers`` gauge — elastic worker-fleet
        capacity over time (None when the fleet was never gauged)."""
        with self._lock:
            cap = self._fleet.get(pool)
            if cap is None:
                return None
            return cap.integral_until(until if until is not None else (self.t_last or 0.0))

    def fleet_utilization(self) -> Dict[str, float]:
        """Busy-fraction per pool against the *worker fleet* capacity
        integral (resize-aware), plus a ``total`` roll-up. Only pools
        with ``workers`` gauges appear — the elastic acceptance metric:
        same busy seconds over a smaller capacity integral is the win."""
        with self._lock:
            pools = list(self._pools.items())
        busy_total = 0.0
        cap_total = 0.0
        out: Dict[str, float] = {}
        for name, st in pools:
            cap = self.fleet_worker_seconds(name)
            if cap is None or cap <= 0:
                continue
            out[name] = st.busy_seconds / cap
            busy_total += st.busy_seconds
            cap_total += cap
        if cap_total > 0:
            out["total"] = busy_total / cap_total
        return out

    def utilization(
        self,
        total_slots: Optional[int] = None,
        slots_by_pool: Optional[Dict[str, int]] = None,
    ) -> Dict[str, float]:
        """Busy-fraction per pool (and ``total``) over the observed window.

        Pool capacity comes from, in order of preference: recorded
        ``slots`` gauges (reallocation-aware), recorded ``workers``
        gauges (elastic-fleet resize-aware), the static
        ``slots_by_pool`` mapping, or — for ``total`` only —
        ``total_slots``. The gauge integrals matter for elastic pools:
        a static denominator would report >100% utilization the moment
        the fleet grows past its initial size.
        """
        span = self.makespan()
        out: Dict[str, float] = {}
        if span <= 0:
            return out
        busy_total = 0.0
        busy_covered = 0.0
        cap_total = 0.0
        with self._lock:
            pools = dict(self._pools)
            gauged = set(self._capacity) | set(self._fleet)
        # Every pool with known capacity counts toward the total — a
        # declared pool that sat idle is exactly the waste a utilization
        # report exists to expose, so zero-busy pools stay in the
        # denominator. Only busy time with *unknown* capacity is excluded
        # (it would otherwise inflate the total past 100%).
        names = set(pools) | gauged | set(slots_by_pool or {})
        for name in sorted(names):
            st = pools.get(name)
            busy = st.busy_seconds if st is not None else 0.0
            busy_total += busy
            cap_ss = self.capacity_slot_seconds(name)
            if cap_ss is None:
                cap_ss = self.fleet_worker_seconds(name)
            if cap_ss is None and slots_by_pool and name in slots_by_pool:
                cap_ss = slots_by_pool[name] * span
            if cap_ss and cap_ss > 0:
                out[name] = busy / cap_ss
                cap_total += cap_ss
                busy_covered += busy
        if total_slots:
            out["total"] = busy_total / (total_slots * span)
        elif cap_total > 0:
            out["total"] = busy_covered / cap_total
        return out

    # --------------------------------------------------------------- export
    def snapshot(self, slots_by_pool: Optional[Dict[str, int]] = None) -> Dict[str, object]:
        """One JSON-safe dict of every live metric (the periodic snapshot
        the ``MetricsExporter`` writes)."""
        cache = {
            m: {"hits": c.hits, "misses": c.misses,
                "hit_rate": c.hit_rate, "bytes_saved": c.bytes_saved}
            for m, c in self.cache_stats().items()
        }
        batches = {
            m: {"batches": b.batches, "tasks": b.tasks,
                "mean_occupancy": b.mean_occupancy, "max_occupancy": b.max_occupancy}
            for m, b in self.batch_stats().items()
        }
        return {
            "makespan_s": self.makespan(),
            "pools": {name: dict(vars(st)) for name, st in self.pool_stats().items()},
            "methods": self.method_stats(),
            "overhead": self.overhead(),
            "utilization": self.utilization(slots_by_pool=slots_by_pool),
            "fleet_utilization": self.fleet_utilization(),
            "cache": cache,
            "batches": batches,
            "gauges": self.gauges(),
            "profiles": self.profile_stats(),
            "alerts": self.alert_stats(),
            "unknown_kinds": dict(self.unknown_kinds),
        }

    def prometheus_text(self, slots_by_pool: Optional[Dict[str, int]] = None) -> str:
        """Render the live metrics in Prometheus text exposition format
        (scrape it from a file, or serve the string over HTTP)."""

        def esc(v: str) -> str:
            return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")

        lines: List[str] = []

        def series(name: str, kind: str, help_: str, rows: List[Tuple[Dict[str, str], float]]) -> None:
            if not rows:
                return
            lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {kind}")
            for labels, value in rows:
                lab = ",".join(f'{k}="{esc(str(v))}"' for k, v in labels.items())
                lab = "{" + lab + "}" if lab else ""
                lines.append(f"{name}{lab} {value:.9g}")

        pools = self.pool_stats()
        for fld, kind, help_ in (
            ("submitted", "counter", "Tasks submitted per pool"),
            ("completed", "counter", "Tasks completed per pool"),
            ("failed", "counter", "Tasks failed per pool"),
            ("backlog", "gauge", "Tasks submitted but not yet running"),
            ("running", "gauge", "Tasks currently running"),
            ("busy_seconds", "counter", "Busy worker-slot seconds per pool"),
        ):
            series(
                f"repro_pool_{fld}", kind, help_,
                [({"pool": name}, float(getattr(st, fld))) for name, st in sorted(pools.items())],
            )
        series(
            "repro_pool_utilization", "gauge", "Busy fraction of pool capacity",
            [({"pool": name}, v) for name, v in sorted(self.utilization(slots_by_pool=slots_by_pool).items())],
        )
        methods = self.method_stats()
        series(
            "repro_method_latency_seconds_count", "counter", "Completed tasks per method",
            [({"method": m}, float(s["count"])) for m, s in sorted(methods.items())],
        )
        series(
            "repro_method_latency_seconds_sum", "counter", "Total compute seconds per method",
            [({"method": m}, s["mean_s"] * s["count"]) for m, s in sorted(methods.items())],
        )
        series(
            "repro_method_latency_seconds", "summary", "Compute-latency quantiles per method",
            [
                ({"method": m, "quantile": q}, s[f"p{int(float(q) * 100)}_s"])
                for m, s in sorted(methods.items())
                for q in ("0.5", "0.95")
            ],
        )
        series(
            "repro_overhead_span_seconds_total", "counter",
            "Total seconds per lifecycle span (queue/dispatch/compute/result)",
            [({"span": name}, s["total_s"]) for name, s in sorted(self.overhead().items())],
        )
        cache = self.cache_stats()
        series(
            "repro_cache_hits_total", "counter", "Warm-worker cache hits per method",
            [({"method": m}, float(c.hits)) for m, c in sorted(cache.items())],
        )
        series(
            "repro_cache_misses_total", "counter", "Warm-worker cache misses per method",
            [({"method": m}, float(c.misses)) for m, c in sorted(cache.items())],
        )
        series(
            "repro_profile_seconds_total", "counter", "Profiled span wall seconds",
            [({"name": n}, p["total_s"]) for n, p in sorted(self.profile_stats().items())],
        )
        series(
            "repro_gauge", "gauge", "Last-seen value of each workflow gauge",
            [
                ({"name": name, "pool": pool}, value)
                for name, by_pool in sorted(self.gauges().items())
                for pool, value in sorted(by_pool.items())
            ],
        )
        alerts = self.alert_stats()
        if alerts["events"]:
            series("repro_alerts_fired_total", "counter", "Alert firing transitions",
                   [({}, float(alerts["fired"]))])
            series("repro_alerts_resolved_total", "counter", "Alert resolved transitions",
                   [({}, float(alerts["resolved"]))])
        series(
            "repro_alert_firing", "gauge", "1 while the named alert is firing",
            [
                ({"name": name, "severity": str(alerts["states"][name]["severity"])}, 1.0)
                for name in alerts["firing"]
            ],
        )
        if alerts["remediations"]:
            series("repro_remediations_total", "counter", "Auto-remediation attempts",
                   [({}, float(alerts["remediations"]))])
        series("repro_makespan_seconds", "gauge", "Observed event-log window", [({}, self.makespan())])
        return "\n".join(lines) + "\n"
