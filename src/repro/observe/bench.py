"""Benchmark trajectory: ``BENCH_<suite>.json`` recording + diffing.

CI gates answer "did the suite pass?"; they lose the *trajectory* — how
the overhead x-factor, steering gain, utilization, and kernel timings
move across PRs. ``BenchRecorder`` gives every suite in
``benchmarks/run.py`` one write path:

    rec = BenchRecorder("overhead", out_dir="bench_out")
    rec.metric("warm_batched_speedup_x", 9.3, unit="x", gate=(">=", 2.0))
    path = rec.finish()          # -> bench_out/BENCH_overhead.json

The file carries the git commit, a wall-clock timestamp, an environment
fingerprint (python/jax/numpy versions, platform, JAX backend), every
metric with its optional gate threshold and per-metric pass/fail, and a
suite-level verdict. ``bench_diff(old, new)`` compares two recordings
per-metric; a metric with a gate regresses when it moves against the
gate's direction by more than ``rel_tol``, an ungated metric is flagged
as changed only. ``python -m repro.observe bench diff OLD NEW`` is the
CLI (soft-fail annotation in CI; hard gates stay in the suites).
"""

from __future__ import annotations

import json
import os
import platform
import subprocess
import time
from typing import Any, Dict, List, Optional, Tuple

SCHEMA_VERSION = 1

_OPS = {
    ">=": lambda v, t: v >= t,
    "<=": lambda v, t: v <= t,
    ">": lambda v, t: v > t,
    "<": lambda v, t: v < t,
}


def git_commit(cwd: Optional[str] = None) -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10, cwd=cwd,
        )
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def env_fingerprint() -> Dict[str, Any]:
    """What the numbers were measured on — enough to explain a diff that
    is really an environment change."""
    fp: Dict[str, Any] = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count(),
    }
    try:
        import jax

        fp["jax"] = jax.__version__
        fp["jax_backend"] = jax.default_backend()
    except Exception:  # noqa: BLE001 - fingerprinting must never fail a suite
        fp["jax"] = None
    try:
        import numpy

        fp["numpy"] = numpy.__version__
    except Exception:  # noqa: BLE001
        fp["numpy"] = None
    return fp


class BenchRecorder:
    """Accumulates one suite's metrics and writes ``BENCH_<name>.json``."""

    def __init__(self, name: str, out_dir: str = ".", meta: Optional[Dict[str, Any]] = None) -> None:
        self.name = name
        self.out_dir = out_dir
        self.meta = dict(meta or {})
        self.metrics: Dict[str, Dict[str, Any]] = {}
        self.t0 = time.time()
        self.path: Optional[str] = None

    def metric(
        self,
        name: str,
        value: float,
        unit: Optional[str] = None,
        gate: Optional[Tuple[str, float]] = None,
        **extra: Any,
    ) -> None:
        """Record one metric; ``gate=(op, threshold)`` (op in >=, <=, >, <)
        attaches the suite's acceptance bound and per-metric pass/fail."""
        row: Dict[str, Any] = {"value": float(value)}
        if unit:
            row["unit"] = unit
        if gate is not None:
            op, threshold = gate
            if op not in _OPS:
                raise ValueError(f"unknown gate op {op!r} (use one of {sorted(_OPS)})")
            row["gate"] = {"op": op, "threshold": float(threshold)}
            row["passed"] = bool(_OPS[op](float(value), float(threshold)))
        self.metrics[name] = {**row, **extra}

    def finish(self, ok: Optional[bool] = None, error: Optional[str] = None) -> str:
        """Write ``BENCH_<name>.json``; suite verdict = every gated metric
        passed AND the suite itself ran clean (``ok``)."""
        gates_passed = all(m.get("passed", True) for m in self.metrics.values())
        doc = {
            "schema": SCHEMA_VERSION,
            "name": self.name,
            "commit": git_commit(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime(self.t0)),
            "duration_s": round(time.time() - self.t0, 3),
            "env": env_fingerprint(),
            "metrics": self.metrics,
            "gates_passed": gates_passed,
            "passed": gates_passed and (ok if ok is not None else True),
        }
        if error:
            doc["error"] = error
        os.makedirs(self.out_dir, exist_ok=True)
        self.path = os.path.join(self.out_dir, f"BENCH_{self.name}.json")
        tmp = f"{self.path}.tmp"
        with open(tmp, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")
        os.replace(tmp, self.path)
        return self.path


def load_bench(path: str) -> Dict[str, Any]:
    with open(path) as fh:
        doc = json.load(fh)
    if "metrics" not in doc or "name" not in doc:
        raise ValueError(f"{path} is not a BENCH_*.json recording")
    return doc


def bench_diff(old: Dict[str, Any], new: Dict[str, Any], rel_tol: float = 0.05) -> Dict[str, Any]:
    """Per-metric comparison of two recordings of the same suite.

    A *gated* metric regresses when it moves against its gate direction
    by more than ``rel_tol`` (relative) — e.g. a ``>=`` speedup dropping
    5%+ regresses, rising is an improvement. Ungated metrics are
    reported as changed/unchanged only (no direction is knowable).
    """
    out: Dict[str, Any] = {
        "suite": new.get("name"),
        "old_commit": old.get("commit"),
        "new_commit": new.get("commit"),
        "metrics": {},
        "regressions": [],
        "improvements": [],
        "added": sorted(set(new["metrics"]) - set(old["metrics"])),
        "removed": sorted(set(old["metrics"]) - set(new["metrics"])),
    }
    for name in sorted(set(old["metrics"]) & set(new["metrics"])):
        ov = float(old["metrics"][name]["value"])
        nv = float(new["metrics"][name]["value"])
        delta = nv - ov
        rel = delta / abs(ov) if ov else (0.0 if nv == 0 else float("inf"))
        gate = new["metrics"][name].get("gate") or old["metrics"][name].get("gate")
        status = "unchanged"
        if gate is not None:
            higher_better = gate["op"] in (">=", ">")
            worse = rel < -rel_tol if higher_better else rel > rel_tol
            better = rel > rel_tol if higher_better else rel < -rel_tol
            if worse:
                status = "regressed"
                out["regressions"].append(name)
            elif better:
                status = "improved"
                out["improvements"].append(name)
        elif abs(rel) > rel_tol:
            status = "changed"
        row: Dict[str, Any] = {
            "old": ov, "new": nv,
            "delta": delta, "rel": rel, "status": status,
        }
        if gate is not None:
            row["gate"] = gate
            row["passed"] = new["metrics"][name].get("passed")
        out["metrics"][name] = row
    out["ok"] = not out["regressions"]
    return out


def render_diff(diff: Dict[str, Any]) -> str:
    """Human-readable diff table (what the CI annotation prints)."""
    lines = [
        f"bench diff · suite={diff.get('suite')} "
        f"({(diff.get('old_commit') or '?')[:9]} -> {(diff.get('new_commit') or '?')[:9]})"
    ]
    width = max((len(n) for n in diff["metrics"]), default=6)
    for name, row in diff["metrics"].items():
        rel = row["rel"]
        rel_s = f"{rel:+.1%}" if abs(rel) != float("inf") else "new"
        mark = {"regressed": "✗", "improved": "✓", "changed": "~", "unchanged": " "}[row["status"]]
        lines.append(
            f"  {mark} {name:<{width}}  {row['old']:>12.6g} -> {row['new']:>12.6g}"
            f"  ({rel_s}) {row['status']}"
        )
    for name in diff["added"]:
        lines.append(f"  + {name} (new metric)")
    for name in diff["removed"]:
        lines.append(f"  - {name} (removed metric)")
    lines.append(
        "verdict: " + ("OK" if diff["ok"] else f"REGRESSED: {', '.join(diff['regressions'])}")
    )
    return "\n".join(lines)


def diff_paths(old_path: str, new_path: str, rel_tol: float = 0.05) -> Dict[str, Any]:
    return bench_diff(load_bench(old_path), load_bench(new_path), rel_tol=rel_tol)


def match_baselines(old_dir: str, new_dir: str) -> List[Tuple[str, str]]:
    """Pair ``BENCH_*.json`` files by suite name across two directories."""
    def index(d: str) -> Dict[str, str]:
        out = {}
        if os.path.isdir(d):
            for fn in sorted(os.listdir(d)):
                if fn.startswith("BENCH_") and fn.endswith(".json"):
                    out[fn] = os.path.join(d, fn)
        return out

    old_idx, new_idx = index(old_dir), index(new_dir)
    return [(old_idx[k], new_idx[k]) for k in sorted(set(old_idx) & set(new_idx))]


def collect_bench(paths: List[str]) -> List[Dict[str, Any]]:
    """Load every ``BENCH_*.json`` under the given files/directories
    (directories are scanned non-recursively; bad files are skipped —
    a trajectory should aggregate whatever survives, not die on one
    truncated artifact)."""
    docs: List[Dict[str, Any]] = []
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            files.extend(
                os.path.join(p, fn)
                for fn in sorted(os.listdir(p))
                if fn.startswith("BENCH_") and fn.endswith(".json")
            )
        else:
            files.append(p)
    for path in files:
        try:
            docs.append(load_bench(path))
        except (OSError, ValueError, json.JSONDecodeError):
            continue
    return docs


def build_trajectory(docs: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Fold per-run recordings into one commit-ordered trajectory.

    Output shape::

        {"schema": 1,
         "suites": {suite: {
             "runs":   [{"commit", "timestamp", "duration_s", "passed"}, ...],
             "series": {metric: [{"commit", "timestamp", "value",
                                  "passed"?}, ...]}}}}

    Runs are ordered by timestamp (recording wall-clock), so appending
    each CI run's artifact yields per-metric series a dashboard can plot
    straight across PRs. Duplicate (commit, timestamp) runs of a suite
    collapse to the last one seen.
    """
    by_suite: Dict[str, Dict[Tuple[Optional[str], Optional[str]], Dict[str, Any]]] = {}
    for doc in docs:
        key = (doc.get("commit"), doc.get("timestamp"))
        by_suite.setdefault(str(doc.get("name")), {})[key] = doc
    suites: Dict[str, Any] = {}
    for suite, runs_by_key in sorted(by_suite.items()):
        runs = sorted(runs_by_key.values(), key=lambda d: (d.get("timestamp") or "", d.get("commit") or ""))
        series: Dict[str, List[Dict[str, Any]]] = {}
        run_rows: List[Dict[str, Any]] = []
        for doc in runs:
            run_rows.append({
                "commit": doc.get("commit"),
                "timestamp": doc.get("timestamp"),
                "duration_s": doc.get("duration_s"),
                "passed": doc.get("passed"),
            })
            for metric, row in sorted(doc.get("metrics", {}).items()):
                point: Dict[str, Any] = {
                    "commit": doc.get("commit"),
                    "timestamp": doc.get("timestamp"),
                    "value": row.get("value"),
                }
                if "passed" in row:
                    point["passed"] = row["passed"]
                series.setdefault(metric, []).append(point)
        suites[suite] = {"runs": run_rows, "series": series}
    return {"schema": SCHEMA_VERSION, "suites": suites}


__all__ = [
    "BenchRecorder",
    "bench_diff",
    "build_trajectory",
    "collect_bench",
    "diff_paths",
    "env_fingerprint",
    "git_commit",
    "load_bench",
    "match_baselines",
    "render_diff",
    "SCHEMA_VERSION",
]
