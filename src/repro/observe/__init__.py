"""repro.observe — workflow telemetry + adaptive resource reallocation.

The paper's first scaling pillar is *steering strategies that maximize
node utilization*; its evaluation rests on per-task lifecycle traces.
This subsystem provides both: a structured event log every core
component emits into, streaming metrics over it, and an adaptive
reallocator that closes the loop by moving slots toward demand.

Quick wiring::

    from repro.core import LocalColmenaQueues, TaskServer
    from repro.observe import EventLog, MetricsAggregator, build_report

    log = EventLog(jsonl_path="run.jsonl")       # optional persistent sink
    queues = LocalColmenaQueues(event_log=log)   # client-side stages
    server = TaskServer(queues, methods).start() # server/worker stages
    ... run a thinker ...
    print(render_text(build_report(log, total_slots=8)))

Event schema
------------
Every record is an ``Event`` (see ``events.py``), JSONL-serialized when a
sink path is given. Fields:

===========  ============================================================
``t``        ``time.monotonic()`` seconds at emission (``t_rel`` in the
             JSONL sink is relative to log creation)
``kind``     ``task`` (lifecycle stage), ``gauge`` (named scalar sample,
             e.g. ``slots``, ``workers`` — the elastic fleet size — or
             ``batch_occupancy``), ``cache`` (warm-worker cache
             ``hit``/``miss``), ``realloc`` (steering-slot move),
             ``pool_resize`` (elastic worker-fleet ``grow``/``shrink``;
             value = new size, info carries old/new/reason), or
             ``surrogate`` (model lifecycle: ``retrain`` with
             value=rmse, ``rerank`` with value=acquisition regret), or
             ``profile`` (a timed span: ``t`` = start, ``value`` = wall
             seconds, ``stage`` = span name, ``info["device_s"]`` =
             post-``block_until_ready`` device time — emitted by
             ``EventLog.profile`` around kernel / ensemble calls),
             ``alert`` (an SLO/anomaly transition: stage ``pending``/
             ``firing``/``resolved``, ``info["name"]`` the objective,
             ``info["severity"]`` ``page``/``ticket``/``advisory``), or
             ``remediation`` (an auto-remediation attempt: ``stage`` =
             handler label, ``info`` carries the alert name and ``ok``).
             The kind set is OPEN: consumers must tolerate (count, not
             crash on) kinds they do not model — see
             ``MetricsAggregator.unknown_kinds``
``stage``    lifecycle stage for tasks — in causal order: ``submitted``,
             ``queued``, ``picked_up``, ``dispatched``, ``running``,
             ``completed``/``failed``, ``result_received``,
             ``decision_made``; plus out-of-band ``retried`` /
             ``speculated`` / ``reallocated``. For gauges: the gauge
             name (e.g. ``slots``).
``task_id``  the ``Result.task_id`` (``task`` events only; speculative
             twins share the original's id, retry clones get a fresh id
             linked via ``info["origin"]``)
``method``   task-server method name
``topic``    result-queue topic
``pool``     requested pool on client-side stages; the *executing*
             WorkerPool name on ``running``/``completed``/``failed``
``value``    gauge value / slots moved
``info``     free-form extras (``worker_id``, failure kind, ``src``/
             ``dst`` of a reallocation, ...)
===========  ============================================================

Emission points: ``ColmenaQueues.send_inputs`` (submitted, queued),
``ColmenaQueues.get_task`` (picked_up), ``WorkerPool.submit``
(dispatched), the worker loop (running, completed, failed),
``TaskServer`` (retried, speculated), ``ColmenaQueues.get_result``
(result_received), ``BaseThinker`` result processors (decision_made),
``ResourceCounter`` (``slots`` gauges on allocation changes).

Cross-process note: ``event_log`` is process-local (it is dropped on
pickling). With ``PipeColmenaQueues`` each side records its own stages —
a spawned ``ProcessTaskServer`` child opens its own JSONL sink
(``ObserveSpec.resolved_server_jsonl``) — and a ``TraceContext`` minted
at ``send_inputs`` rides on the ``Result`` across the boundary, so
``trace.merge_jsonl`` reassembles the sinks into one causal trace
(``python -m repro.observe trace a.jsonl b.jsonl -o trace.json``).
"""

from .anomaly import AnomalyDetector, AnomalySpec
from .bench import (
    BenchRecorder,
    bench_diff,
    build_trajectory,
    env_fingerprint,
    load_bench,
    render_diff,
)
from .events import (
    AUX_STAGES,
    Event,
    EventLog,
    STAGE_ORDER,
    lifecycle_gaps,
    lifecycle_order_violations,
)
from .export import ExportSpec, MetricsExporter
from .ops import OpsServer
from .metrics import (
    BatchStats,
    CacheStats,
    LatencyHistogram,
    MetricsAggregator,
    PoolStats,
)
from .reallocator import (
    AdaptiveReallocator,
    ElasticPolicy,
    ElasticScaler,
    EMABacklogPolicy,
    GreedyBacklogPolicy,
    Move,
    PoolView,
    ReallocationPolicy,
    ReallocatorMixin,
)
from .report import build_report, dump_json, render_text
from .slo import SLOEngine, SLOObjective, SLOSpec, default_objectives
from .synthetic import PoolWorkloadThinker, run_bursty, run_pool_workload, run_two_pool
from .trace import (
    Span,
    TaskTrace,
    build_task_traces,
    export_perfetto,
    load_jsonl,
    merge_jsonl,
    profiled_call,
    span_summary,
    to_perfetto,
)

__all__ = [
    "AdaptiveReallocator",
    "AnomalyDetector",
    "AnomalySpec",
    "AUX_STAGES",
    "BatchStats",
    "bench_diff",
    "BenchRecorder",
    "build_report",
    "build_task_traces",
    "build_trajectory",
    "default_objectives",
    "CacheStats",
    "dump_json",
    "env_fingerprint",
    "export_perfetto",
    "ExportSpec",
    "load_bench",
    "merge_jsonl",
    "MetricsExporter",
    "OpsServer",
    "profiled_call",
    "render_diff",
    "Span",
    "span_summary",
    "TaskTrace",
    "to_perfetto",
    "ElasticPolicy",
    "ElasticScaler",
    "EMABacklogPolicy",
    "Event",
    "EventLog",
    "GreedyBacklogPolicy",
    "LatencyHistogram",
    "lifecycle_gaps",
    "lifecycle_order_violations",
    "load_jsonl",
    "MetricsAggregator",
    "Move",
    "PoolStats",
    "PoolView",
    "PoolWorkloadThinker",
    "ReallocationPolicy",
    "ReallocatorMixin",
    "render_text",
    "run_bursty",
    "run_pool_workload",
    "run_two_pool",
    "SLOEngine",
    "SLOObjective",
    "SLOSpec",
    "STAGE_ORDER",
]
