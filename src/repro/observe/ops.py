"""Live ops endpoint: HTTP metrics/health/alerts over stdlib http.server.

Until now every signal left the process through disk (JSONL logs,
atomic ``metrics.prom``/``snapshot.json`` writes). ``OpsServer`` serves
the same ``MetricsAggregator`` **live** — no disk round-trip, no
staleness window — from a daemon thread:

  ===============  ======================================================
  ``GET /metrics``   Prometheus text exposition (scrape target)
  ``GET /healthz``   liveness — 200 unless the app has stopped
  ``GET /readyz``    readiness — 200 only while ``state == "ready"``
  ``GET /snapshot``  the full JSON metrics snapshot
  ``GET /alerts``    SLO + anomaly alert states (firing/pending/ok)
  ``GET /``          endpoint index
  ===============  ======================================================

Lifecycle awareness comes from ``set_state``: ``ColmenaApp`` drives
``starting → ready → draining → stopped`` around its own start/stop, so
a load balancer (or the future campaign control plane) can hold traffic
during startup and drain before teardown. ``port=0`` binds an ephemeral
port (read it back from ``.port`` / ``.url``) — the right default for
tests and multi-campaign hosts.
"""

from __future__ import annotations

import json
import logging
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional

from .metrics import MetricsAggregator

logger = logging.getLogger("repro.observe.ops")

_STATES = ("starting", "ready", "draining", "stopped")


class OpsServer:
    """Serve live workflow health over HTTP (stdlib only, daemon thread)."""

    def __init__(
        self,
        aggregator: Optional[MetricsAggregator] = None,
        slots_by_pool: Optional[Dict[str, int]] = None,
        slo: Optional[Any] = None,
        anomaly: Optional[Any] = None,
        host: str = "127.0.0.1",
        port: int = 0,
    ) -> None:
        self.agg = aggregator
        self.slots_by_pool = dict(slots_by_pool or {})
        self.slo = slo
        self.anomaly = anomaly
        self.host = host
        self.port = port
        self._state = "starting"
        self._state_t = time.monotonic()
        self._t0 = time.monotonic()
        self._lock = threading.Lock()
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- lifecycle
    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def set_state(self, state: str) -> None:
        if state not in _STATES:
            raise ValueError(f"unknown ops state {state!r} (expected one of {_STATES})")
        with self._lock:
            if state != self._state:
                logger.info("ops: state %s -> %s", self._state, state)
                self._state = state
                self._state_t = time.monotonic()

    def start(self) -> "OpsServer":
        if self._httpd is not None:
            return self
        server = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt: str, *args: Any) -> None:  # noqa: N802
                logger.debug("ops: %s", fmt % args)

            def do_GET(self) -> None:  # noqa: N802
                try:
                    server._route(self)
                except BrokenPipeError:
                    pass  # client went away mid-response
                except Exception:  # noqa: BLE001 - one bad request must not kill serving
                    logger.exception("ops request %s failed", self.path)
                    try:
                        self.send_error(500)
                    except Exception:  # noqa: BLE001
                        pass

        self._httpd = ThreadingHTTPServer((self.host, self.port), Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.1},
            daemon=True, name="ops-server",
        )
        self._thread.start()
        logger.info("ops: serving on http://%s:%d", self.host, self.port)
        return self

    def stop(self) -> None:
        httpd, self._httpd = self._httpd, None
        if httpd is not None:
            httpd.shutdown()
            httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def rebind(self, aggregator: Optional[MetricsAggregator]) -> None:
        """Repoint at a fresh aggregator after ``rebind_event_log``."""
        self.agg = aggregator

    # --------------------------------------------------------------- routing
    def _route(self, req: BaseHTTPRequestHandler) -> None:
        path = req.path.split("?", 1)[0].rstrip("/") or "/"
        if path == "/metrics":
            if self.agg is None:
                self._send(req, 503, "text/plain; charset=utf-8", "no aggregator\n")
                return
            text = self.agg.prometheus_text(slots_by_pool=self.slots_by_pool or None)
            self._send(req, 200, "text/plain; version=0.0.4; charset=utf-8", text)
        elif path == "/healthz":
            state = self.state
            code = 503 if state == "stopped" else 200
            self._send_json(req, code, self._health_body(state))
        elif path == "/readyz":
            state = self.state
            code = 200 if state == "ready" else 503
            self._send_json(req, code, self._health_body(state))
        elif path == "/snapshot":
            if self.agg is None:
                self._send_json(req, 503, {"error": "no aggregator"})
                return
            self._send_json(req, 200, self.agg.snapshot(slots_by_pool=self.slots_by_pool or None))
        elif path == "/alerts":
            self._send_json(req, 200, self._alerts_body())
        elif path == "/":
            self._send_json(req, 200, {
                "state": self.state,
                "endpoints": ["/metrics", "/healthz", "/readyz", "/snapshot", "/alerts"],
            })
        else:
            self._send_json(req, 404, {"error": f"unknown path {path!r}"})

    def _health_body(self, state: str) -> Dict[str, Any]:
        now = time.monotonic()
        with self._lock:
            in_state_s = now - self._state_t
        return {"state": state, "uptime_s": round(now - self._t0, 3),
                "in_state_s": round(in_state_s, 3)}

    def _alerts_body(self) -> Dict[str, Any]:
        alerts: List[Dict[str, Any]] = []
        firing: List[str] = []
        if self.slo is not None:
            alerts.extend(self.slo.alerts())
            firing.extend(self.slo.firing())
        if self.anomaly is not None:
            alerts.extend(self.anomaly.alerts())
            firing.extend(self.anomaly.firing())
        return {"alerts": alerts, "firing": sorted(firing)}

    # ---------------------------------------------------------------- output
    @staticmethod
    def _send(req: BaseHTTPRequestHandler, code: int, ctype: str, body: str) -> None:
        data = body.encode("utf-8")
        req.send_response(code)
        req.send_header("Content-Type", ctype)
        req.send_header("Content-Length", str(len(data)))
        req.end_headers()
        req.wfile.write(data)

    @classmethod
    def _send_json(cls, req: BaseHTTPRequestHandler, code: int, body: Dict[str, Any]) -> None:
        cls._send(req, code, "application/json; charset=utf-8",
                  json.dumps(body, indent=2, default=str) + "\n")


__all__ = ["OpsServer"]
