"""Adaptive resource reallocation: the paper's utilization-maximizing
steering, driven by live telemetry.

``AdaptiveReallocator`` watches per-pool state (idle slots, backlog,
allocation) and moves ``ResourceCounter`` slots between pools — e.g.
simulation <-> ML — through a pluggable policy:

  * ``GreedyBacklogPolicy`` — move idle slots from pools with no waiting
    work to the most backlogged pool, as many per tick as are free;
  * ``EMABacklogPolicy`` — exponential-moving-average backlog pressure
    with hysteresis, shifting one slot at a time toward the pool whose
    *smoothed* demand per slot is highest (predictive: reacts to trends,
    not instantaneous spikes).

Backlog can come from a user probe (``backlog=lambda pool: ...``, e.g. a
Thinker's pending-work count) or from a ``MetricsAggregator`` watching
the event log (submitted-but-not-running tasks per pool).

Use it standalone (``start()``/``stop()`` runs a daemon thread) or mix
``ReallocatorMixin`` into a ``BaseThinker`` so the reallocation loop runs
as one of the thinker's own agents and shuts down with it.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..core.thinker import ResourceCounter, agent
from .events import EventLog
from .metrics import MetricsAggregator


@dataclass
class PoolView:
    """Snapshot of one pool, handed to the policy each tick."""

    name: str
    allocation: int   # slots currently assigned to the pool (busy + free)
    free: int         # idle slots
    backlog: int      # work waiting for a slot


@dataclass
class Move:
    src: str
    dst: str
    n: int


class ReallocationPolicy:
    """Interface: inspect pool views, optionally propose a slot move."""

    def decide(self, views: Sequence[PoolView]) -> Optional[Move]:
        raise NotImplementedError


class GreedyBacklogPolicy(ReallocationPolicy):
    """Shift idle capacity to the most backlogged pool.

    A pool donates only when it has free slots and no backlog of its own;
    the most backlogged pool receives as many slots as the donor can
    spare (bounded by the backlog itself).
    """

    def __init__(self, min_backlog: int = 1) -> None:
        self.min_backlog = min_backlog

    def decide(self, views: Sequence[PoolView]) -> Optional[Move]:
        needy = [v for v in views if v.backlog >= self.min_backlog]
        if not needy:
            return None
        dst = max(needy, key=lambda v: (v.backlog, -v.allocation))
        donors = [v for v in views if v.name != dst.name and v.free > 0 and v.backlog == 0]
        if not donors:
            return None
        src = max(donors, key=lambda v: v.free)
        n = min(src.free, dst.backlog)
        return Move(src.name, dst.name, n) if n > 0 else None


class EMABacklogPolicy(ReallocationPolicy):
    """Predictive balancing on smoothed backlog-per-slot pressure.

    Keeps an EMA of each pool's backlog and moves a slot from the pool
    with the lowest smoothed pressure (which must have an idle slot) to
    the highest, but only when the gap exceeds ``hysteresis`` — avoiding
    thrash on noisy, bursty arrival patterns.
    """

    def __init__(self, alpha: float = 0.3, hysteresis: float = 1.0) -> None:
        self.alpha = alpha
        self.hysteresis = hysteresis
        self._ema: Dict[str, float] = {}

    def pressure(self, view: PoolView) -> float:
        return self._ema.get(view.name, 0.0) / max(view.allocation, 1)

    def decide(self, views: Sequence[PoolView]) -> Optional[Move]:
        for v in views:
            prev = self._ema.get(v.name, float(v.backlog))
            self._ema[v.name] = self.alpha * v.backlog + (1 - self.alpha) * prev
        dst = max(views, key=self.pressure)
        donors = [v for v in views if v.name != dst.name and v.free > 0]
        if not donors:
            return None
        src = min(donors, key=self.pressure)
        if self.pressure(dst) - self.pressure(src) <= self.hysteresis / max(dst.allocation, 1):
            return None
        return Move(src.name, dst.name, 1)


class AdaptiveReallocator:
    """Watch live metrics; move ResourceCounter slots toward demand."""

    def __init__(
        self,
        rec: ResourceCounter,
        pools: Optional[Sequence[str]] = None,
        policy: Optional[ReallocationPolicy] = None,
        backlog: Optional[Callable[[str], int]] = None,
        metrics: Optional[MetricsAggregator] = None,
        interval: float = 0.02,
        min_slots: Optional[Dict[str, int]] = None,
        event_log: Optional[EventLog] = None,
        acquire_timeout: float = 0.05,
    ) -> None:
        if backlog is None and metrics is None:
            raise ValueError("need a backlog probe or a MetricsAggregator")
        self.rec = rec
        self.pool_names = list(pools) if pools is not None else rec.pools()
        self.policy = policy or GreedyBacklogPolicy()
        self.metrics = metrics
        self._backlog = backlog if backlog is not None else metrics.backlog
        self.interval = interval
        self.min_slots = dict(min_slots or {})
        self.event_log = event_log
        self.acquire_timeout = acquire_timeout
        self.moves: List[Tuple[float, str, str, int]] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def rebind_event_log(self, log: EventLog) -> None:
        """Re-point telemetry at ``log`` (``repro.app``'s two-phase
        benchmarks): moves are emitted there, and a metrics-driven
        backlog probe is re-derived from a fresh aggregator subscribed
        to it. A user-supplied ``backlog`` callable is left alone."""
        self.event_log = log
        if self.metrics is not None:
            self.metrics = MetricsAggregator(log)
            self._backlog = self.metrics.backlog

    # ------------------------------------------------------------------ state
    def views(self) -> List[PoolView]:
        return [
            PoolView(
                name=p,
                allocation=self.rec.allocation(p),
                free=self.rec.available(p),
                backlog=int(self._backlog(p)),
            )
            for p in self.pool_names
        ]

    # ------------------------------------------------------------------- tick
    def step(self) -> bool:
        """One policy tick; returns True when a move happened."""
        views = self.views()
        move = self.policy.decide(views)
        if move is None:
            return False
        by_name = {v.name: v for v in views}
        src = by_name.get(move.src)
        if src is None or move.src == move.dst:
            return False
        spare = src.allocation - self.min_slots.get(move.src, 0)
        n = max(0, min(move.n, src.free, spare))
        if n <= 0:
            return False
        # Only idle slots move: acquire() with a short timeout never yanks
        # capacity out from under a running task.
        if not self.rec.reallocate(move.src, move.dst, n, timeout=self.acquire_timeout,
                                   stop_event=self._stop):
            return False
        self.moves.append((time.monotonic(), move.src, move.dst, n))
        if self.event_log is not None:
            self.event_log.realloc(move.src, move.dst, n)
        return True

    # -------------------------------------------------------------- lifecycle
    def run(self, stop: Optional[threading.Event] = None) -> None:
        stop = stop or self._stop
        while not stop.is_set() and not self._stop.is_set():
            self.step()
            stop.wait(self.interval)

    def start(self) -> "AdaptiveReallocator":
        self._thread = threading.Thread(target=self.run, daemon=True, name="adaptive-reallocator")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)


class ReallocatorMixin:
    """Mix into a ``BaseThinker`` subclass; set ``self.reallocator`` to an
    ``AdaptiveReallocator`` before ``run()`` and the reallocation loop
    runs as a non-critical agent, stopping when the thinker finishes."""

    reallocator: Optional[AdaptiveReallocator] = None

    @agent(critical=False)
    def reallocation_agent(self) -> None:
        r = self.reallocator
        if r is None:
            return
        while not self.done.is_set():
            r.step()
            self.done.wait(r.interval)


# --------------------------------------------------------------------------
# Elastic worker fleets
# --------------------------------------------------------------------------


@dataclass
class ElasticPolicy:
    """Knobs for the worker-fleet autoscaler: the ``AdaptiveReallocator``
    idea applied to the fleet itself. Grow whenever dispatched work waits
    for a worker; shrink after ``idle_grace_ticks`` consecutive ticks
    with idle workers and nothing queued — hysteresis so a gap between
    bursts does not thrash the fleet."""

    interval: float = 0.05        # seconds between ticks
    step: int = 1                 # max workers added/removed per tick
    idle_grace_ticks: int = 3     # consecutive idle ticks before a shrink
    # Arrival-rate anticipation (the EMABacklogPolicy trick applied to
    # the fleet): smooth the per-pool dispatch rate off the event log and
    # pre-grow when the work expected within ``lookahead_s`` exceeds the
    # pool's idle headroom — the fleet is already larger when the burst's
    # tail lands instead of reacting one queue-depth late. ``rate_alpha=0``
    # disables anticipation (pure reactive scaling, the old behavior).
    rate_alpha: float = 0.3       # EMA smoothing of the arrival rate
    lookahead_s: float = 0.2      # horizon over which expected arrivals count

    def to_dict(self) -> Dict[str, float]:
        return {"interval": self.interval, "step": self.step,
                "idle_grace_ticks": self.idle_grace_ticks,
                "rate_alpha": self.rate_alpha, "lookahead_s": self.lookahead_s}


class ElasticScaler:
    """Resize elastic ``WorkerPool``s toward demand, within each pool's
    ``PoolSpec`` [min, max] band.

    Demand is read straight off the pools (``queued()`` = dispatched
    work waiting for a worker; busy/idle from worker states) — the
    binding signal for fleet sizing, where the reallocator's event-log
    backlog measures *steering-slot* pressure. Every change goes through
    ``WorkerPool.resize`` and is recorded as a ``pool_resize`` event
    plus a ``workers`` gauge, so reports integrate true capacity over
    time. When a ``ResourceCounter`` is supplied, steering-slot capacity
    for same-named pools is grown/shrunk in lockstep so task submitters
    see the extra workers.
    """

    def __init__(
        self,
        pools: Dict[str, Any],               # name -> repro.core.WorkerPool
        specs: Dict[str, Any],               # name -> repro.core.PoolSpec
        policy: Optional[ElasticPolicy] = None,
        event_log: Optional[EventLog] = None,
        rec: Optional[ResourceCounter] = None,
    ) -> None:
        unknown = set(pools) - set(specs)
        if unknown:
            raise ValueError(f"pools without specs: {sorted(unknown)}")
        self.pools = dict(pools)
        self.specs = dict(specs)
        self.policy = policy or ElasticPolicy()
        self.event_log = event_log
        self.rec = rec
        self.resizes: List[Tuple[float, str, int, int]] = []
        self._idle_ticks: Dict[str, int] = {p: 0 for p in pools}
        # Arrival-rate EMA: count ``dispatched`` events per pool off the
        # event log (the executing-pool signal), smooth per tick.
        self._arrival_lock = threading.Lock()
        self._arrival_counts: Dict[str, int] = {p: 0 for p in pools}
        self._rate_ema: Dict[str, float] = {p: 0.0 for p in pools}
        self._rate_t: Optional[float] = None
        self._arrival_sub: Optional[Callable] = None
        if event_log is not None and self.policy.rate_alpha > 0:
            self._arrival_sub = self._on_event
            event_log.subscribe(self._arrival_sub, replay=False)
        # Steering slots the counter still owes back after a fleet shrink
        # (rec.shrink is all-or-nothing and only takes idle slots; a
        # failed shrink is retried every tick, never dropped — otherwise
        # one timed-out shrink would desync slots from workers forever).
        self._rec_debt: Dict[str, int] = {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ gauges
    def emit_baseline(self) -> None:
        """Gauge every fleet's starting size so capacity integration has
        a left edge (mirrors ``ResourceCounter.event_log``'s baseline)."""
        if self.event_log is None:
            return
        for name, pool in self.pools.items():
            self.event_log.gauge("workers", pool.n_workers, pool=name)

    def _on_event(self, ev: Any) -> None:
        """Event-log subscriber (inline at emit: stay tiny): count
        per-pool task arrivals for the rate EMA."""
        if ev.kind == "task" and ev.stage == "dispatched" and ev.pool in self._arrival_counts:
            with self._arrival_lock:
                self._arrival_counts[ev.pool] += 1

    def _update_rates(self) -> None:
        """Fold this tick's arrival counts into the per-pool rate EMA and
        gauge it (``arrival_rate``, tasks/s) into metrics snapshots."""
        now = time.monotonic()
        if self._rate_t is None:
            self._rate_t = now
            return
        dt = now - self._rate_t
        if dt <= 0:
            return
        self._rate_t = now
        alpha = self.policy.rate_alpha
        with self._arrival_lock:
            counts = dict(self._arrival_counts)
            for p in self._arrival_counts:
                self._arrival_counts[p] = 0
        for name, n in counts.items():
            inst = n / dt
            self._rate_ema[name] = alpha * inst + (1 - alpha) * self._rate_ema[name]
            if self.event_log is not None and (inst or self._rate_ema[name] > 1e-3):
                self.event_log.gauge("arrival_rate", self._rate_ema[name], pool=name)

    def expected_arrivals(self, name: str) -> float:
        """Tasks expected within the policy's lookahead window."""
        return self._rate_ema.get(name, 0.0) * self.policy.lookahead_s

    def rebind_event_log(self, log: EventLog) -> None:
        """Move telemetry (and the arrival-rate subscription) to ``log``
        (``repro.app``'s two-phase benchmarks)."""
        if self._arrival_sub is not None and self.event_log is not None:
            unsub = getattr(self.event_log, "unsubscribe", None)
            if unsub is not None:
                unsub(self._arrival_sub)
            self._arrival_sub = None
        self.event_log = log
        if log is not None and self.policy.rate_alpha > 0:
            self._arrival_sub = self._on_event
            log.subscribe(self._arrival_sub, replay=False)

    # ------------------------------------------------------------------- tick
    def _decide(self, name: str, pool: Any) -> Optional[int]:
        """Target size for one pool this tick, or None to hold."""
        spec = self.specs[name]
        current = pool.n_workers
        queued = pool.queued()
        busy = sum(1 for w in pool.worker_states() if w.busy and w.alive)
        idle = max(0, current - busy)
        expected = self.expected_arrivals(name)
        if queued > 0:
            self._idle_ticks[name] = 0
            # Grow toward queued + anticipated work, not just the queue:
            # mid-burst the fleet pre-grows ahead of arrivals instead of
            # chasing the queue one step at a time.
            demand = queued + int(expected)
            target = spec.clamp(current + min(self.policy.step, demand))
            return target if target != current else None
        if expected > idle:
            # Nothing queued *yet*, but the smoothed arrival rate says the
            # idle headroom will not absorb the next lookahead window.
            self._idle_ticks[name] = 0
            target = spec.clamp(current + min(self.policy.step, int(expected - idle) + 1))
            return target if target != current else None
        if idle > 0:
            if expected >= 0.5:
                self._idle_ticks[name] = 0  # arrivals imminent: hold capacity
                return None
            self._idle_ticks[name] += 1
            if self._idle_ticks[name] >= self.policy.idle_grace_ticks:
                self._idle_ticks[name] = 0
                target = spec.clamp(current - min(self.policy.step, idle))
                return target if target != current else None
        else:
            self._idle_ticks[name] = 0
        return None

    def step(self) -> bool:
        """One autoscaler tick over every pool; True when any resize
        happened."""
        changed = False
        self._update_rates()
        self._settle_rec_debt()
        for name, pool in self.pools.items():
            target = self._decide(name, pool)
            if target is None:
                continue
            old, new = pool.resize(target)
            if new == old:
                continue
            self._sync_rec(name, old, new)
            changed = True
            self.resizes.append((time.monotonic(), name, old, new))
            if self.event_log is not None:
                self.event_log.pool_resize(
                    name, old, new,
                    reason="backlog" if new > old else "idle",
                )
                self.event_log.gauge("workers", new, pool=name)
        return changed

    def pre_grow(self, pool: Optional[str] = None, n: Optional[int] = None,
                 reason: str = "slo_alert") -> int:
        """Grow the named pool (or every pool) by up to ``n`` slots
        (default: the policy step) ahead of demand — the remediation
        hook a firing backlog alert calls. Unlike ``step`` this does not
        consult the queue: the alert already established the demand.
        Returns the total number of slots actually added."""
        step = self.policy.step if n is None else max(1, int(n))
        grown = 0
        targets = (
            {pool: self.pools[pool]} if pool is not None and pool in self.pools
            else self.pools
        )
        for name, p in targets.items():
            spec = self.specs[name]
            old = p.n_workers
            target = spec.clamp(old + step)
            if target == old:
                continue
            old, new = p.resize(target)
            if new == old:
                continue
            self._sync_rec(name, old, new)
            grown += new - old
            self.resizes.append((time.monotonic(), name, old, new))
            if self.event_log is not None:
                self.event_log.pool_resize(name, old, new, reason=reason)
                self.event_log.gauge("workers", new, pool=name)
        return grown

    def _sync_rec(self, name: str, old: int, new: int) -> None:
        """Keep steering-slot capacity in step with the fleet. A shrink
        only removes *idle* slots (never yanks capacity out from under a
        submitted task), so slots that cannot be reclaimed right now are
        booked as debt and settled on later ticks; a grow pays down debt
        before adding fresh capacity."""
        rec = self.rec
        if rec is None or name not in rec.pools():
            return
        if new > old:
            n = new - old
            settled = min(self._rec_debt.get(name, 0), n)
            if settled:
                self._rec_debt[name] -= settled
                n -= settled
            if n:
                rec.grow(name, n)
        else:
            self._rec_debt[name] = self._rec_debt.get(name, 0) + (old - new)
            self._settle_rec_debt(only=name)

    def _settle_rec_debt(self, only: Optional[str] = None) -> None:
        """Reclaim owed steering slots as they fall idle, one at a time,
        without blocking the scaler loop."""
        rec = self.rec
        if rec is None:
            return
        for name, owed in list(self._rec_debt.items()):
            if only is not None and name != only:
                continue
            while owed > 0 and rec.shrink(name, 1, timeout=0):
                owed -= 1
            self._rec_debt[name] = owed

    # -------------------------------------------------------------- lifecycle
    def run(self, stop: Optional[threading.Event] = None) -> None:
        stop = stop or self._stop
        self.emit_baseline()
        while not stop.is_set() and not self._stop.is_set():
            self.step()
            stop.wait(self.policy.interval)

    def start(self) -> "ElasticScaler":
        self._thread = threading.Thread(target=self.run, daemon=True, name="elastic-scaler")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        if self._arrival_sub is not None and self.event_log is not None:
            unsub = getattr(self.event_log, "unsubscribe", None)
            if unsub is not None:
                unsub(self._arrival_sub)
            self._arrival_sub = None
