"""Streaming anomaly detection: EWMA + z-score advisory alerts.

Static SLO thresholds catch absolute violations; regressions *relative
to the campaign's own recent behavior* — latency creeping up, arrival
rate collapsing, cache hit rate falling off a cliff — need a baseline
learned online. ``AnomalyDetector`` keeps an exponentially-weighted
mean/variance per watched series and raises an **advisory** alert when
a reading lands more than ``z_threshold`` standard deviations from the
learned mean (resolving with hysteresis at ``resolve_z``).

Advisory alerts flow through the same ``EventLog.alert`` channel as SLO
alerts (``severity="advisory"``) so they land in traces, reports, and
``GET /alerts`` — but they are deliberately excluded from remediation:
an anomaly is a prompt for a human (or a future policy), not a trigger.

The detector is tick-driven. Standalone it runs its own daemon thread
(``start()``/``stop()``); composed with an ``SLOEngine`` it is ticked
by the engine's evaluation loop (one clock, ordered transitions).
"""

from __future__ import annotations

import logging
import math
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from .events import EventLog
from .metrics import MetricsAggregator

logger = logging.getLogger("repro.observe.anomaly")

_SERIES = ("latency", "arrival_rate", "cache_hit_rate")


@dataclass
class AnomalySpec:
    """Knobs for the detector. ``series`` selects which signals to watch;
    ``min_samples`` readings must arrive before a series can alert."""

    alpha: float = 0.1
    z_threshold: float = 4.0
    resolve_z: float = 2.0
    min_samples: int = 20
    interval_s: float = 0.5
    series: Tuple[str, ...] = _SERIES

    def __post_init__(self) -> None:
        if not (0.0 < self.alpha <= 1.0):
            raise ValueError("anomaly alpha must be in (0, 1]")
        if self.resolve_z > self.z_threshold:
            raise ValueError("anomaly resolve_z must not exceed z_threshold")
        unknown = set(self.series) - set(_SERIES)
        if unknown:
            raise ValueError(f"unknown anomaly series: {sorted(unknown)}")
        self.series = tuple(self.series)

    @classmethod
    def from_any(cls, value: Any) -> "AnomalySpec":
        if isinstance(value, cls):
            return value
        if value is True or value is None:
            return cls()
        if isinstance(value, Mapping):
            d = dict(value)
            if "series" in d:
                d["series"] = tuple(d["series"])
            return cls(**d)
        raise ValueError(f"cannot build AnomalySpec from {type(value).__name__}")

    def to_dict(self) -> Dict[str, Any]:
        return {"alpha": self.alpha, "z_threshold": self.z_threshold,
                "resolve_z": self.resolve_z, "min_samples": self.min_samples,
                "interval_s": self.interval_s, "series": list(self.series)}


class _Ewma:
    """Streaming EW mean/variance; ``score`` is the z of a new reading
    against the baseline *before* it is absorbed."""

    def __init__(self, alpha: float) -> None:
        self.alpha = alpha
        self.n = 0
        self.mean = 0.0
        self.var = 0.0

    def score(self, x: float) -> float:
        if self.n == 0:
            return 0.0
        std = math.sqrt(self.var)
        if std <= 1e-12:
            return 0.0
        return (x - self.mean) / std

    def update(self, x: float) -> None:
        if self.n == 0:
            self.mean = x
        else:
            diff = x - self.mean
            incr = self.alpha * diff
            self.mean += incr
            self.var = (1.0 - self.alpha) * (self.var + diff * incr)
        self.n += 1


class _SeriesState:
    def __init__(self, name: str, alpha: float) -> None:
        self.name = name
        self.ewma = _Ewma(alpha)
        self.active = False
        self.last_z: Optional[float] = None
        self.last_value: Optional[float] = None
        self.fired_count = 0

    def to_dict(self) -> Dict[str, Any]:
        return {"name": f"anomaly:{self.name}", "signal": "anomaly",
                "severity": "advisory",
                "state": "firing" if self.active else "ok",
                "value": self.last_value, "z": self.last_z,
                "mean": self.ewma.mean, "n": self.ewma.n,
                "fired_count": self.fired_count}


class AnomalyDetector:
    """Watch derived metrics for statistical surprises."""

    def __init__(
        self,
        log: Optional[EventLog],
        spec: Any = None,
        aggregator: Optional[MetricsAggregator] = None,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.log = log
        self.spec = AnomalySpec.from_any(spec)
        self.agg = aggregator if aggregator is not None else MetricsAggregator(log)
        self._clock = clock
        self._lock = threading.Lock()
        self._states = {name: _SeriesState(name, self.spec.alpha) for name in self.spec.series}
        # Latency samples accumulate between ticks (mean per tick is the
        # series reading); cache counters diff tick-over-tick.
        self._lat_sum = 0.0
        self._lat_n = 0
        self._cache_seen = (0, 0)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if "latency" in self._states:
            self.agg.add_listener(self._on_sample)

    def _on_sample(self, sample: Dict[str, object]) -> None:
        if sample.get("type") != "latency":
            return
        with self._lock:
            self._lat_sum += float(sample["seconds"])  # type: ignore[arg-type]
            self._lat_n += 1

    # ----------------------------------------------------------------- tick
    def _readings(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        if "latency" in self._states:
            with self._lock:
                if self._lat_n:
                    out["latency"] = self._lat_sum / self._lat_n
                    self._lat_sum, self._lat_n = 0.0, 0
        if "arrival_rate" in self._states:
            by_pool = self.agg.gauges().get("arrival_rate")
            if by_pool:
                out["arrival_rate"] = sum(by_pool.values()) / len(by_pool)
        if "cache_hit_rate" in self._states:
            total = self.agg.cache_stats()["total"]
            prev_h, prev_m = self._cache_seen
            dh, dm = total.hits - prev_h, total.misses - prev_m
            if dh + dm > 0:
                self._cache_seen = (total.hits, total.misses)
                out["cache_hit_rate"] = dh / (dh + dm)
        return out

    def tick(self, now: Optional[float] = None) -> None:
        now = self._clock() if now is None else now
        for name, value in self._readings().items():
            st = self._states[name]
            z = st.ewma.score(value)
            warmed = st.ewma.n >= self.spec.min_samples
            st.ewma.update(value)
            st.last_z, st.last_value = z, value
            if not warmed:
                continue
            if not st.active and abs(z) >= self.spec.z_threshold:
                st.active = True
                st.fired_count += 1
                self._emit("firing", st, value, z)
            elif st.active and abs(z) <= self.spec.resolve_z:
                st.active = False
                self._emit("resolved", st, value, z)

    def _emit(self, stage: str, st: _SeriesState, value: float, z: float) -> None:
        logger.info("anomaly: %s %s (value=%.6g z=%.2f mean=%.6g)",
                    st.name, stage, value, z, st.ewma.mean)
        if self.log is not None:
            self.log.alert(stage, f"anomaly:{st.name}", value=value,
                           severity="advisory", signal="anomaly",
                           z=round(z, 3), mean=st.ewma.mean)

    # ------------------------------------------------------------ accessors
    def alerts(self) -> List[Dict[str, Any]]:
        return [st.to_dict() for st in self._states.values()]

    def firing(self) -> List[str]:
        return [f"anomaly:{n}" for n, st in self._states.items() if st.active]

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "AnomalyDetector":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True, name="anomaly-detector")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.tick()
            except Exception:  # noqa: BLE001
                logger.exception("anomaly tick failed")
            self._stop.wait(self.spec.interval_s)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def rebind(self, log: Optional[EventLog],
               aggregator: Optional[MetricsAggregator] = None) -> None:
        self.agg.remove_listener(self._on_sample)
        self.log = log
        self.agg = aggregator if aggregator is not None else MetricsAggregator(log)
        with self._lock:
            self._lat_sum, self._lat_n = 0.0, 0
            self._cache_seen = (0, 0)
        if "latency" in self._states:
            self.agg.add_listener(self._on_sample)


__all__ = ["AnomalySpec", "AnomalyDetector"]
