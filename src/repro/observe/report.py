"""Utilization / overhead reports rendered from the event log.

``build_report`` replays an ``EventLog`` through a ``MetricsAggregator``
and returns a plain-dict report (JSON-serializable) with the paper's
evaluation quantities: makespan, per-pool busy time and utilization,
per-method latency stats, the queue/dispatch/compute/result overhead
breakdown, reallocation history, and a lifecycle-completeness check.
``render_text`` pretty-prints it for benchmark output.
"""

from __future__ import annotations

import json
from typing import Dict, Optional

from .events import EventLog, lifecycle_gaps, lifecycle_order_violations
from .metrics import MetricsAggregator
from .trace import build_task_traces, span_summary


def build_report(
    log: EventLog,
    total_slots: Optional[int] = None,
    slots_by_pool: Optional[Dict[str, int]] = None,
) -> dict:
    # One snapshot of the buffer; aggregate, count stages, and group by
    # task in a single pass instead of re-copying the log per consumer.
    events = log.events()
    agg = MetricsAggregator()
    counts: Dict[str, int] = {}
    kinds: Dict[str, int] = {}
    by_task: Dict[str, list] = {}
    for ev in events:
        agg.observe(ev)
        kinds[ev.kind] = kinds.get(ev.kind, 0) + 1
        if ev.kind == "task":
            counts[ev.stage] = counts.get(ev.stage, 0) + 1
            if ev.task_id is not None:
                by_task.setdefault(ev.task_id, []).append(ev)

    pools = {}
    for name, st in sorted(agg.pool_stats().items()):
        pools[name] = {
            "submitted": st.submitted,
            "completed": st.completed,
            "failed": st.failed,
            "busy_s": round(st.busy_seconds, 6),
            "backlog_final": st.backlog,
        }
    util = agg.utilization(total_slots=total_slots, slots_by_pool=slots_by_pool)
    for name, u in util.items():
        if name in pools:
            pools[name]["utilization"] = round(u, 4)

    gaps = lifecycle_gaps(by_task)
    ooo = lifecycle_order_violations(by_task)

    report = {
        "makespan_s": round(agg.makespan(), 6),
        "events": len(events),
        "event_kinds": kinds,
        "stage_counts": counts,
        "pools": pools,
        "utilization": {k: round(v, 4) for k, v in util.items()},
        "methods": {
            m: {k: (round(v, 6) if isinstance(v, float) else v) for k, v in s.items()}
            for m, s in sorted(agg.method_stats().items())
        },
        "overhead": {
            name: {k: round(v, 6) for k, v in s.items()}
            for name, s in agg.overhead().items()
        },
        "reallocations": [
            {"t": round(ev.t, 6), **ev.info} for ev in agg.reallocations
        ],
        "lifecycle": {
            "complete": not gaps,
            "ordered": not ooo,
            "gaps": gaps,
            "order_violations": ooo,
        },
    }
    # Fig.-7-style fine-grained span breakdown with critical-path
    # attribution (which interval dominated each task's wall time).
    trace = span_summary(build_task_traces(events))
    if trace["tasks"]:
        report["trace"] = {
            "tasks": trace["tasks"],
            "flagged": trace["flagged"],
            "spans": {
                name: {k: round(v, 6) for k, v in s.items()}
                for name, s in trace["spans"].items()
            },
            "critical_path": trace["critical_path"],
        }
    profiles = agg.profile_stats()
    if profiles:
        report["profiles"] = {
            name: {k: round(v, 6) for k, v in s.items()}
            for name, s in sorted(profiles.items())
        }
    if agg.surrogate_events:
        report["surrogate"] = agg.surrogate_stats()
    if agg.alert_events or agg.remediation_events:
        alerts = agg.alert_stats()
        report["alerts"] = {
            "fired": alerts["fired"],
            "resolved": alerts["resolved"],
            "still_firing": alerts["firing"],
            "remediations": alerts["remediations"],
            "remediations_ok": alerts["remediations_ok"],
            "timeline": [
                {"t": round(ev.t, 6), "stage": ev.stage,
                 "name": ev.info.get("name"), "severity": ev.info.get("severity"),
                 "value": ev.value}
                for ev in agg.alert_events
            ],
        }
    if agg.unknown_kinds:
        # Forward-compat: kinds this build of observe does not model are
        # surfaced (counted under event_kinds too) rather than dropped.
        report["unknown_kinds"] = dict(agg.unknown_kinds)
    return report


def render_text(report: dict) -> str:
    # Defensive throughout: reports may come from a newer/older build of
    # ``build_report`` (extra sections, unknown event kinds, missing
    # keys) — render what is recognized, summarize what is not.
    lines = []
    lines.append(f"makespan         {report.get('makespan_s', 0.0):.3f} s   "
                 f"({report.get('events', 0)} events)")
    util = report.get("utilization", {})
    if "total" in util:
        lines.append(f"utilization      total {util['total']:.1%}")
    pools = report.get("pools", {})
    if pools:
        lines.append("pools:")
        for name, p in pools.items():
            u = f"  util {p['utilization']:.1%}" if "utilization" in p else ""
            lines.append(
                f"  {name:<12} done {p.get('completed', 0):>5}  failed {p.get('failed', 0):>3}  "
                f"busy {p.get('busy_s', 0.0):.2f} s{u}"
            )
    methods = report.get("methods", {})
    if methods:
        lines.append("methods:")
        for m, s in methods.items():
            lines.append(
                f"  {m:<14} n={s.get('count', 0):<5} "
                f"mean {s.get('mean_s', 0.0)*1e3:8.2f} ms  "
                f"p50 {s.get('p50_s', 0.0)*1e3:8.2f} ms  "
                f"p95 {s.get('p95_s', 0.0)*1e3:8.2f} ms"
            )
    overhead = report.get("overhead", {})
    if overhead:
        lines.append("overhead breakdown (mean per task):")
        for name in ("queue", "dispatch", "compute", "result"):
            s = overhead.get(name)
            if s:
                lines.append(f"  {name:<10} {s.get('mean_s', 0.0)*1e3:8.2f} ms  "
                             f"(total {s.get('total_s', 0.0):.2f} s)")
    trace = report.get("trace")
    if trace and trace.get("spans"):
        n = trace.get("tasks", 0)
        crit = trace.get("critical_path", {})
        lines.append(f"task spans ({n} task(s), critical path in [ ]):")
        for name, s in trace["spans"].items():
            share = crit.get(name, 0)
            frac = f"  [{share / n:.0%} of tasks]" if n and share else ""
            lines.append(
                f"  {name:<12} {s.get('mean_s', 0.0)*1e3:8.2f} ms mean  "
                f"{s.get('frac', 0.0):5.1%} of traced time{frac}"
            )
        if trace.get("flagged"):
            lines.append(f"  ({trace['flagged']} task(s) had out-of-order events)")
    profiles = report.get("profiles")
    if profiles:
        lines.append("profiled spans:")
        for name, s in profiles.items():
            lines.append(
                f"  {name:<22} n={s.get('count', 0):<4} "
                f"mean {s.get('mean_s', 0.0)*1e3:8.2f} ms  "
                f"(total {s.get('total_s', 0.0):.2f} s)"
            )
    if report.get("reallocations"):
        moves = ", ".join(f"{m['src']}->{m['dst']} x{m['n']}" for m in report["reallocations"])
        lines.append(f"reallocations:   {moves}")
    sur = report.get("surrogate")
    if sur:
        cadence = sur.get("retrain_cadence_s") or []
        cad = f", cadence ~{sum(cadence)/len(cadence):.2f} s" if cadence else ""
        rmse = sur.get("rmse") or []
        rm = f"  rmse {rmse[0]:.3f} -> {rmse[-1]:.3f}" if rmse else ""
        regret = sur.get("regret") or []
        rg = f"  regret {regret[0]:.3f} -> {regret[-1]:.3f}" if regret else ""
        pol = f" [{sur['policy']}]" if sur.get("policy") else ""
        lines.append(f"surrogate:       {sur.get('retrains', 0)} retrain(s){cad}{rm}{rg}{pol}")
    alerts = report.get("alerts")
    if alerts:
        still = alerts.get("still_firing") or []
        tail = f", STILL FIRING: {', '.join(still)}" if still else ""
        lines.append(
            f"alerts:          {alerts.get('fired', 0)} fired, "
            f"{alerts.get('resolved', 0)} resolved, "
            f"{alerts.get('remediations', 0)} remediation(s){tail}"
        )
    if report.get("unknown_kinds"):
        other = ", ".join(f"{k} x{n}" for k, n in sorted(report["unknown_kinds"].items()))
        lines.append(f"other events:    {other} (kinds unknown to this build)")
    lc = report.get("lifecycle")
    if lc:
        lines.append(
            "lifecycle:       "
            + ("complete & ordered" if lc.get("complete") and lc.get("ordered")
               else f"{len(lc.get('gaps', ()))} gap(s), "
                    f"{len(lc.get('order_violations', ()))} order violation(s)")
        )
    return "\n".join(lines)


def dump_json(report: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
