"""Utilization / overhead reports rendered from the event log.

``build_report`` replays an ``EventLog`` through a ``MetricsAggregator``
and returns a plain-dict report (JSON-serializable) with the paper's
evaluation quantities: makespan, per-pool busy time and utilization,
per-method latency stats, the queue/dispatch/compute/result overhead
breakdown, reallocation history, and a lifecycle-completeness check.
``render_text`` pretty-prints it for benchmark output.
"""

from __future__ import annotations

import json
from typing import Dict, Optional

from .events import EventLog, lifecycle_gaps, lifecycle_order_violations
from .metrics import MetricsAggregator


def build_report(
    log: EventLog,
    total_slots: Optional[int] = None,
    slots_by_pool: Optional[Dict[str, int]] = None,
) -> dict:
    # One snapshot of the buffer; aggregate, count stages, and group by
    # task in a single pass instead of re-copying the log per consumer.
    events = log.events()
    agg = MetricsAggregator()
    counts: Dict[str, int] = {}
    by_task: Dict[str, list] = {}
    for ev in events:
        agg.observe(ev)
        if ev.kind == "task":
            counts[ev.stage] = counts.get(ev.stage, 0) + 1
            if ev.task_id is not None:
                by_task.setdefault(ev.task_id, []).append(ev)

    pools = {}
    for name, st in sorted(agg.pool_stats().items()):
        pools[name] = {
            "submitted": st.submitted,
            "completed": st.completed,
            "failed": st.failed,
            "busy_s": round(st.busy_seconds, 6),
            "backlog_final": st.backlog,
        }
    util = agg.utilization(total_slots=total_slots, slots_by_pool=slots_by_pool)
    for name, u in util.items():
        if name in pools:
            pools[name]["utilization"] = round(u, 4)

    gaps = lifecycle_gaps(by_task)
    ooo = lifecycle_order_violations(by_task)

    return {
        "makespan_s": round(agg.makespan(), 6),
        "events": len(events),
        "stage_counts": counts,
        "pools": pools,
        "utilization": {k: round(v, 4) for k, v in util.items()},
        "methods": {
            m: {k: (round(v, 6) if isinstance(v, float) else v) for k, v in s.items()}
            for m, s in sorted(agg.method_stats().items())
        },
        "overhead": {
            name: {k: round(v, 6) for k, v in s.items()}
            for name, s in agg.overhead().items()
        },
        "reallocations": [
            {"t": round(ev.t, 6), **ev.info} for ev in agg.reallocations
        ],
        "lifecycle": {
            "complete": not gaps,
            "ordered": not ooo,
            "gaps": gaps,
            "order_violations": ooo,
        },
    }


def render_text(report: dict) -> str:
    lines = []
    lines.append(f"makespan         {report['makespan_s']:.3f} s   "
                 f"({report['events']} events)")
    util = report.get("utilization", {})
    if "total" in util:
        lines.append(f"utilization      total {util['total']:.1%}")
    lines.append("pools:")
    for name, p in report["pools"].items():
        u = f"  util {p['utilization']:.1%}" if "utilization" in p else ""
        lines.append(
            f"  {name:<12} done {p['completed']:>5}  failed {p['failed']:>3}  "
            f"busy {p['busy_s']:.2f} s{u}"
        )
    if report["methods"]:
        lines.append("methods:")
        for m, s in report["methods"].items():
            lines.append(
                f"  {m:<14} n={s['count']:<5} mean {s['mean_s']*1e3:8.2f} ms  "
                f"p50 {s['p50_s']*1e3:8.2f} ms  p95 {s['p95_s']*1e3:8.2f} ms"
            )
    if report["overhead"]:
        lines.append("overhead breakdown (mean per task):")
        for name in ("queue", "dispatch", "compute", "result"):
            s = report["overhead"].get(name)
            if s:
                lines.append(f"  {name:<10} {s['mean_s']*1e3:8.2f} ms  (total {s['total_s']:.2f} s)")
    if report["reallocations"]:
        moves = ", ".join(f"{m['src']}->{m['dst']} x{m['n']}" for m in report["reallocations"])
        lines.append(f"reallocations:   {moves}")
    lc = report["lifecycle"]
    lines.append(
        "lifecycle:       "
        + ("complete & ordered" if lc["complete"] and lc["ordered"]
           else f"{len(lc['gaps'])} gap(s), {len(lc['order_violations'])} order violation(s)")
    )
    return "\n".join(lines)


def dump_json(report: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
