"""CLI for the observability layer.

    # Merge one or more JSONL event logs into a Perfetto-loadable trace
    python -m repro.observe trace events.jsonl events.server.jsonl -o trace.json

    # Text report (incl. span breakdown) over the same logs
    python -m repro.observe report events.jsonl events.server.jsonl

    # Compare two benchmark recordings (or two directories of them)
    python -m repro.observe bench diff BENCH_old.json BENCH_new.json
    python -m repro.observe bench diff benchmarks/baselines bench_out --fail-on-regress

    # Fold per-run recordings into one commit-ordered trajectory.json
    python -m repro.observe bench trajectory benchmarks/baselines bench_out -o trajectory.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List


def _cmd_trace(args: argparse.Namespace) -> int:
    from .trace import export_perfetto, merge_jsonl, span_summary, build_task_traces

    doc = export_perfetto(args.inputs, args.out)
    events = merge_jsonl(args.inputs)
    summary = span_summary(build_task_traces(events))
    n_spans = sum(1 for e in doc["traceEvents"] if e.get("ph") == "X")
    print(
        f"wrote {args.out}: {n_spans} spans from {summary['tasks']} task(s) "
        f"across {len(args.inputs)} log(s) — load it at https://ui.perfetto.dev"
    )
    if summary["critical_path"]:
        top = next(iter(summary["critical_path"]))
        print(f"critical path: {top} dominates {summary['critical_path'][top]}/{summary['tasks']} tasks")
    return 0


def _cmd_report(args: argparse.Namespace) -> int:
    from .events import EventLog
    from .report import build_report, render_text
    from .trace import merge_jsonl

    log = EventLog(capacity=1 << 22)
    for ev in merge_jsonl(args.inputs):
        log.emit(ev)
    report = build_report(log)
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        print(render_text(report))
    return 0


def _cmd_bench_diff(args: argparse.Namespace) -> int:
    from .bench import diff_paths, match_baselines, render_diff

    pairs: List = []
    if os.path.isdir(args.old) and os.path.isdir(args.new):
        pairs = match_baselines(args.old, args.new)
        if not pairs:
            print(f"no matching BENCH_*.json files between {args.old} and {args.new}")
            return 2
    else:
        pairs = [(args.old, args.new)]
    regressed = False
    for old_path, new_path in pairs:
        diff = diff_paths(old_path, new_path, rel_tol=args.rel_tol)
        print(render_diff(diff))
        print()
        regressed = regressed or not diff["ok"]
    if regressed and args.fail_on_regress:
        return 1
    return 0


def _cmd_bench_trajectory(args: argparse.Namespace) -> int:
    from .bench import build_trajectory, collect_bench

    docs = collect_bench(args.inputs)
    if not docs:
        print(f"no BENCH_*.json recordings found under: {', '.join(args.inputs)}")
        return 2
    traj = build_trajectory(docs)
    with open(args.out, "w") as fh:
        json.dump(traj, fh, indent=2, sort_keys=True)
        fh.write("\n")
    for suite, data in traj["suites"].items():
        print(f"  {suite}: {len(data['runs'])} run(s), {len(data['series'])} metric series")
    print(f"wrote {args.out}: {len(traj['suites'])} suite(s) from {len(docs)} recording(s)")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.observe", description=__doc__)
    sub = parser.add_subparsers(dest="cmd", required=True)

    p_trace = sub.add_parser("trace", help="export JSONL event log(s) as Perfetto JSON")
    p_trace.add_argument("inputs", nargs="+", help="one or more EventLog JSONL files")
    p_trace.add_argument("-o", "--out", default="trace.json", help="output trace file")
    p_trace.set_defaults(fn=_cmd_trace)

    p_rep = sub.add_parser("report", help="text report over JSONL event log(s)")
    p_rep.add_argument("inputs", nargs="+", help="one or more EventLog JSONL files")
    p_rep.add_argument("--json", action="store_true", help="print the JSON report")
    p_rep.set_defaults(fn=_cmd_report)

    p_bench = sub.add_parser("bench", help="benchmark-trajectory tools")
    bench_sub = p_bench.add_subparsers(dest="bench_cmd", required=True)
    p_diff = bench_sub.add_parser("diff", help="compare two BENCH_*.json recordings")
    p_diff.add_argument("old", help="baseline file or directory of BENCH_*.json")
    p_diff.add_argument("new", help="new file or directory of BENCH_*.json")
    p_diff.add_argument("--rel-tol", type=float, default=0.05,
                        help="relative movement tolerated before flagging (default 5%%)")
    p_diff.add_argument("--fail-on-regress", action="store_true",
                        help="exit 1 when any gated metric regressed")
    p_diff.set_defaults(fn=_cmd_bench_diff)
    p_traj = bench_sub.add_parser(
        "trajectory", help="aggregate BENCH_*.json recordings into trajectory.json")
    p_traj.add_argument("inputs", nargs="+",
                        help="BENCH_*.json files and/or directories of them")
    p_traj.add_argument("-o", "--out", default="trajectory.json",
                        help="output trajectory file")
    p_traj.set_defaults(fn=_cmd_bench_trajectory)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
