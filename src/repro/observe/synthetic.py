"""Synthetic imbalanced-pool workloads for benchmarking the reallocator.

``PoolWorkloadThinker`` drains fixed per-pool work lists through
slot-gated task submitters (one per pool, installed dynamically), so the
``ResourceCounter`` split — not the executor — is the binding resource,
exactly the regime where the paper's adaptive steering pays off: a
static split strands slots on a pool whose work has drained, while an
``AdaptiveReallocator`` shifts them to the backlogged pool.

``run_pool_workload`` wires the full stack (event log -> queues -> task
server -> thinker [-> reallocator]) and returns the event-log report;
``run_two_pool`` is the canonical sim/ml instance used by
``benchmarks/utilization.py`` and the acceptance test.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..core.queues import LocalColmenaQueues
from ..core.executors import WorkerPool
from ..core.result import ResourceRequest, Result
from ..core.task_server import TaskServer
from ..core.thinker import BaseThinker, ResourceCounter, result_processor
from .events import EventLog
from .reallocator import AdaptiveReallocator, GreedyBacklogPolicy, ReallocationPolicy, ReallocatorMixin
from .report import build_report

WorkItem = Tuple[tuple, dict]


class PoolWorkloadThinker(ReallocatorMixin, BaseThinker):
    """Drain per-pool work lists; submissions gated by per-pool slots.

    ``allocations`` sets the initial slot split; ``work`` maps each pool
    to its task list; ``methods`` maps each pool to the task-server
    method it calls. One task submitter per pool is installed at
    construction time. When a pool's list drains, its submitter parks on
    ``done`` after returning the held slot — leaving the slot free for
    the reallocator to move.
    """

    def __init__(
        self,
        queues: LocalColmenaQueues,
        allocations: Dict[str, int],
        work: Dict[str, Sequence[WorkItem]],
        methods: Dict[str, str],
        reallocator: Optional[AdaptiveReallocator] = None,
    ) -> None:
        pool_names = list(allocations)
        rec = ResourceCounter(sum(allocations.values()), pools=pool_names)
        for pool in pool_names[1:]:  # initial split (all slots start in pool 0)
            if allocations[pool]:
                rec.reallocate(pool_names[0], pool, allocations[pool])
        super().__init__(queues, rec)
        self.reallocator = reallocator
        self._methods = dict(methods)
        self._work: Dict[str, List[WorkItem]] = {p: list(reversed(list(w))) for p, w in work.items()}
        self._expected = sum(len(w) for w in self._work.values())
        self._n_done = 0
        self._lock = threading.Lock()
        self.results: List[Result] = []
        for pool in pool_names:
            self._install_submitter(pool)

    # ----------------------------------------------------------- submitters
    def _install_submitter(self, pool: str) -> None:
        def submit() -> None:
            self._submit_one(pool)

        submit.__name__ = f"submit_{pool}"
        submit._colmena_kind = "task_submitter"
        submit._colmena_opts = {"task_type": pool, "n_slots": 1}
        setattr(self, f"submit_{pool}", submit)

    def _submit_one(self, pool: str) -> None:
        with self._lock:
            queue = self._work[pool]
            item = queue.pop() if queue else None
        if item is None:
            # Pool drained for good: hand the slot back and park until
            # shutdown so the reallocator can migrate the idle capacity.
            self.rec.release(pool, 1)
            self.done.wait()
            return
        args, kwargs = item
        self.queues.send_inputs(
            *args,
            method=self._methods[pool],
            keyword_args=kwargs,
            resources=ResourceRequest(pool=pool),
            task_info={"slot_pool": pool},
        )

    def pending(self, pool: str) -> int:
        with self._lock:
            return len(self._work.get(pool, ()))

    # -------------------------------------------------------------- results
    @result_processor()
    def _on_result(self, result: Result) -> None:
        self.rec.release(result.task_info.get("slot_pool", "default"), 1)
        self.results.append(result)
        with self._lock:
            self._n_done += 1
            finished = self._n_done >= self._expected
        if finished:
            self.done.set()


def run_pool_workload(
    allocations: Dict[str, int],
    work: Dict[str, Sequence[WorkItem]],
    methods: Dict[str, str],
    task_fns: Dict[str, Callable[..., Any]],
    adaptive: bool = False,
    policy: Optional[ReallocationPolicy] = None,
    interval: float = 0.01,
    jsonl_path: Optional[str] = None,
    workers_per_pool: Optional[int] = None,
    timeout: float = 120.0,
) -> Tuple[dict, EventLog, PoolWorkloadThinker]:
    """Run one campaign; returns (report, event_log, thinker).

    Worker pools are oversized (``workers_per_pool`` defaults to the
    total slot count) so the ResourceCounter split is the only binding
    resource, matching the paper's node-allocation model.
    """
    total = sum(allocations.values())
    n_workers = workers_per_pool or total
    log = EventLog(jsonl_path=jsonl_path)
    queues = LocalColmenaQueues(event_log=log)
    pools = {p: WorkerPool(p, n_workers) for p in allocations}
    pools.setdefault("default", WorkerPool("default", 1))
    server = TaskServer(queues, dict(task_fns), pools=pools)

    thinker = PoolWorkloadThinker(queues, allocations, work, methods)
    thinker.rec.event_log = log  # record per-pool slot gauges for the report
    if adaptive:
        thinker.reallocator = AdaptiveReallocator(
            thinker.rec,
            pools=list(allocations),
            policy=policy or GreedyBacklogPolicy(),
            backlog=thinker.pending,
            interval=interval,
            event_log=log,
        )
    server.start()
    try:
        thinker.run(timeout=timeout)
    finally:
        server.stop()
        log.close()
    report = build_report(log, total_slots=total)
    return report, log, thinker


def _sleep_task(duration: float) -> float:
    time.sleep(duration)
    return duration


def run_two_pool(
    n_slots: int = 6,
    n_sim: int = 36,
    n_ml: int = 6,
    task_s: float = 0.03,
    ml_share: Optional[int] = None,
    adaptive: bool = False,
    policy: Optional[ReallocationPolicy] = None,
    jsonl_path: Optional[str] = None,
) -> Tuple[dict, EventLog, PoolWorkloadThinker]:
    """The canonical imbalanced sim/ml workload: many short ``sim`` tasks,
    few ``ml`` tasks, slots split evenly by default. The static split
    strands the ml slots once ml work drains (~utilization loss the
    adaptive policy recovers)."""
    ml_slots = n_slots // 2 if ml_share is None else ml_share
    allocations = {"sim": n_slots - ml_slots, "ml": ml_slots}
    work = {
        "sim": [((task_s,), {}) for _ in range(n_sim)],
        "ml": [((task_s,), {}) for _ in range(n_ml)],
    }
    methods = {"sim": "sim_task", "ml": "ml_task"}
    fns = {"sim_task": _sleep_task, "ml_task": _sleep_task}
    return run_pool_workload(
        allocations, work, methods, fns,
        adaptive=adaptive, policy=policy, jsonl_path=jsonl_path,
    )
