"""Synthetic workloads for benchmarking the reallocator and elastic fleets.

``PoolWorkloadThinker`` drains fixed per-pool work lists through
slot-gated task submitters (one per pool, installed dynamically), so the
``ResourceCounter`` split — not the executor — is the binding resource,
exactly the regime where the paper's adaptive steering pays off: a
static split strands slots on a pool whose work has drained, while an
``AdaptiveReallocator`` shifts them to the backlogged pool.

``run_pool_workload`` wires the full stack (event log -> queues -> task
server -> thinker [-> reallocator]) and returns the event-log report;
``run_two_pool`` is the canonical sim/ml instance used by
``benchmarks/utilization.py`` and the acceptance test. Pools are built
from ``PoolSpec``s (pass ``pool_specs=`` to shape warm/prefetch knobs),
so synthetic replays compose their fleets exactly like app-composed
campaigns.

``run_bursty`` is the elastic-fleet counterpart: the *worker fleet* —
not the slot split — is the binding resource under a bursty arrival
pattern, and an ``ElasticScaler`` grows/shrinks the fleet within the
``PoolSpec`` band while a static fleet idles through the gaps (the
elastic-vs-static acceptance comparison).
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..core.queues import LocalColmenaQueues
from ..core.executors import PoolSpec, WorkerPool
from ..core.result import ResourceRequest, Result
from ..core.task_server import TaskServer
from ..core.thinker import BaseThinker, ResourceCounter, result_processor
from .events import EventLog
from .reallocator import (
    AdaptiveReallocator,
    ElasticPolicy,
    ElasticScaler,
    GreedyBacklogPolicy,
    ReallocationPolicy,
    ReallocatorMixin,
)
from .report import build_report

WorkItem = Tuple[tuple, dict]


class PoolWorkloadThinker(ReallocatorMixin, BaseThinker):
    """Drain per-pool work lists; submissions gated by per-pool slots.

    ``allocations`` sets the initial slot split; ``work`` maps each pool
    to its task list; ``methods`` maps each pool to the task-server
    method it calls. One task submitter per pool is installed at
    construction time. When a pool's list drains, its submitter parks on
    ``done`` after returning the held slot — leaving the slot free for
    the reallocator to move.
    """

    def __init__(
        self,
        queues: LocalColmenaQueues,
        allocations: Dict[str, int],
        work: Dict[str, Sequence[WorkItem]],
        methods: Dict[str, str],
        reallocator: Optional[AdaptiveReallocator] = None,
    ) -> None:
        pool_names = list(allocations)
        rec = ResourceCounter(sum(allocations.values()), pools=pool_names)
        for pool in pool_names[1:]:  # initial split (all slots start in pool 0)
            if allocations[pool]:
                rec.reallocate(pool_names[0], pool, allocations[pool])
        super().__init__(queues, rec)
        self.reallocator = reallocator
        self._methods = dict(methods)
        self._work: Dict[str, List[WorkItem]] = {p: list(reversed(list(w))) for p, w in work.items()}
        self._expected = sum(len(w) for w in self._work.values())
        self._n_done = 0
        self._lock = threading.Lock()
        self.results: List[Result] = []
        for pool in pool_names:
            self._install_submitter(pool)

    # ----------------------------------------------------------- submitters
    def _install_submitter(self, pool: str) -> None:
        def submit() -> None:
            self._submit_one(pool)

        submit.__name__ = f"submit_{pool}"
        submit._colmena_kind = "task_submitter"
        submit._colmena_opts = {"task_type": pool, "n_slots": 1}
        setattr(self, f"submit_{pool}", submit)

    def _submit_one(self, pool: str) -> None:
        with self._lock:
            queue = self._work[pool]
            item = queue.pop() if queue else None
        if item is None:
            # Pool drained for good: hand the slot back and park until
            # shutdown so the reallocator can migrate the idle capacity.
            self.rec.release(pool, 1)
            self.done.wait()
            return
        args, kwargs = item
        self.queues.send_inputs(
            *args,
            method=self._methods[pool],
            keyword_args=kwargs,
            resources=ResourceRequest(pool=pool),
            task_info={"slot_pool": pool},
        )

    def pending(self, pool: str) -> int:
        with self._lock:
            return len(self._work.get(pool, ()))

    # -------------------------------------------------------------- results
    @result_processor()
    def _on_result(self, result: Result) -> None:
        self.rec.release(result.task_info.get("slot_pool", "default"), 1)
        self.results.append(result)
        with self._lock:
            self._n_done += 1
            finished = self._n_done >= self._expected
        if finished:
            self.done.set()


def run_pool_workload(
    allocations: Dict[str, int],
    work: Dict[str, Sequence[WorkItem]],
    methods: Dict[str, str],
    task_fns: Dict[str, Callable[..., Any]],
    adaptive: bool = False,
    policy: Optional[ReallocationPolicy] = None,
    interval: float = 0.01,
    jsonl_path: Optional[str] = None,
    workers_per_pool: Optional[int] = None,
    pool_specs: Optional[Dict[str, PoolSpec]] = None,
    timeout: float = 120.0,
) -> Tuple[dict, EventLog, PoolWorkloadThinker]:
    """Run one campaign; returns (report, event_log, thinker).

    Pools are composed from ``PoolSpec``s — the same resource vocabulary
    as ``repro.app`` — so warm/prefetch knobs shape synthetic replays
    exactly like app-composed pools. By default each pool is oversized
    (``workers_per_pool`` defaults to the total slot count) so the
    ResourceCounter split is the only binding resource, matching the
    paper's node-allocation model; pass ``pool_specs`` to override any
    pool's spec wholesale.
    """
    total = sum(allocations.values())
    n_workers = workers_per_pool or total
    log = EventLog(jsonl_path=jsonl_path)
    queues = LocalColmenaQueues(event_log=log)
    specs = {p: PoolSpec(p, n_workers) for p in allocations}
    specs.setdefault("default", PoolSpec("default", 1))
    specs.update(pool_specs or {})
    pools = {name: ps.build(event_log=log) for name, ps in specs.items()}
    server = TaskServer(queues, dict(task_fns), pools=pools)

    thinker = PoolWorkloadThinker(queues, allocations, work, methods)
    thinker.rec.event_log = log  # record per-pool slot gauges for the report
    if adaptive:
        thinker.reallocator = AdaptiveReallocator(
            thinker.rec,
            pools=list(allocations),
            policy=policy or GreedyBacklogPolicy(),
            backlog=thinker.pending,
            interval=interval,
            event_log=log,
        )
    server.start()
    try:
        thinker.run(timeout=timeout)
    finally:
        server.stop()
        log.close()
    report = build_report(log, total_slots=total)
    return report, log, thinker


def _sleep_task(duration: float) -> float:
    time.sleep(duration)
    return duration


def run_two_pool(
    n_slots: int = 6,
    n_sim: int = 36,
    n_ml: int = 6,
    task_s: float = 0.03,
    ml_share: Optional[int] = None,
    adaptive: bool = False,
    policy: Optional[ReallocationPolicy] = None,
    jsonl_path: Optional[str] = None,
) -> Tuple[dict, EventLog, PoolWorkloadThinker]:
    """The canonical imbalanced sim/ml workload: many short ``sim`` tasks,
    few ``ml`` tasks, slots split evenly by default. The static split
    strands the ml slots once ml work drains (~utilization loss the
    adaptive policy recovers)."""
    ml_slots = n_slots // 2 if ml_share is None else ml_share
    allocations = {"sim": n_slots - ml_slots, "ml": ml_slots}
    work = {
        "sim": [((task_s,), {}) for _ in range(n_sim)],
        "ml": [((task_s,), {}) for _ in range(n_ml)],
    }
    methods = {"sim": "sim_task", "ml": "ml_task"}
    fns = {"sim_task": _sleep_task, "ml_task": _sleep_task}
    return run_pool_workload(
        allocations, work, methods, fns,
        adaptive=adaptive, policy=policy, jsonl_path=jsonl_path,
    )


# --------------------------------------------------------------------------
# Bursty elastic-fleet workload
# --------------------------------------------------------------------------


def run_bursty(
    elastic: bool,
    n_bursts: int = 3,
    burst_size: int = 18,
    gap_s: float = 0.35,
    task_s: float = 0.03,
    min_size: int = 1,
    max_size: int = 6,
    policy: Optional[ElasticPolicy] = None,
    jsonl_path: Optional[str] = None,
) -> dict:
    """Drive a bursty arrival pattern through one pool; the worker fleet
    is the binding resource.

    ``elastic=False`` pins the fleet at ``max_size`` for the whole run —
    it absorbs each burst fast but idles through every gap.
    ``elastic=True`` starts at ``min_size`` and lets an ``ElasticScaler``
    grow into each burst and shrink through each gap within the
    ``PoolSpec`` band. Both runs execute identical work, so the
    acceptance comparison is utilization = busy seconds over the
    integral of the ``workers`` gauge: elastic pays for capacity only
    while there is work to run.

    Returns ``{"utilization": float, "busy_s": ..., "capacity_ws": ...,
    "makespan_s": ..., "resizes": int, "completed": int, "report": dict}``.
    """
    log = EventLog(jsonl_path=jsonl_path)
    queues = LocalColmenaQueues(event_log=log)
    if elastic:
        spec = PoolSpec("burst", size=min_size, min_size=min_size, max_size=max_size)
    else:
        spec = PoolSpec("burst", size=max_size)
    pool = spec.build(event_log=log)
    server = TaskServer(queues, {"burst_task": _sleep_task}, pools={"burst": pool})
    scaler: Optional[ElasticScaler] = None
    if elastic:
        scaler = ElasticScaler(
            {"burst": pool}, {"burst": spec},
            policy=policy or ElasticPolicy(interval=0.01, step=2, idle_grace_ticks=3),
            event_log=log,
        )
    else:
        log.gauge("workers", pool.n_workers, pool="burst")

    total = n_bursts * burst_size
    done = threading.Event()
    n_done = [0]
    lock = threading.Lock()

    def drain() -> None:
        while not done.is_set():
            r = queues.get_result(timeout=1.0)
            if r is None:
                continue
            with lock:
                n_done[0] += 1
                if n_done[0] >= total:
                    done.set()

    drainer = threading.Thread(target=drain, daemon=True, name="bursty-drain")
    server.start()
    if scaler is not None:
        scaler.start()
    drainer.start()
    try:
        for burst in range(n_bursts):
            if burst:
                time.sleep(gap_s)
            for _ in range(burst_size):
                queues.send_inputs(task_s, method="burst_task",
                                   resources=ResourceRequest(pool="burst"))
        done.wait(timeout=120.0)
    finally:
        done.set()
        if scaler is not None:
            scaler.stop()
        # Close the capacity integral at the fleet's final size.
        log.gauge("workers", pool.n_workers, pool="burst")
        server.stop()
        log.close()
        drainer.join(timeout=2.0)

    report = build_report(log)
    from .metrics import MetricsAggregator

    agg = MetricsAggregator()
    for ev in log.events():
        agg.observe(ev)
    busy = agg.pool_stats().get("burst")
    busy_s = busy.busy_seconds if busy else 0.0
    capacity_ws = agg.fleet_worker_seconds("burst") or 0.0
    util = agg.fleet_utilization().get("burst", 0.0)
    return {
        "utilization": util,
        "busy_s": busy_s,
        "capacity_ws": capacity_ws,
        "makespan_s": agg.makespan(),
        "resizes": len(scaler.resizes) if scaler is not None else 0,
        "completed": n_done[0],
        "report": report,
    }
