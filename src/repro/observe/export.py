"""Metrics export: periodic Prometheus text + JSON snapshots on disk.

``MetricsExporter`` subscribes a ``MetricsAggregator`` to the event log
and, on a background cadence, writes two files into ``ExportSpec.dir``:

  * ``metrics.prom`` — Prometheus text exposition format (point a
    node-exporter textfile collector, or any file scraper, at it);
  * ``snapshot.json`` — the full ``MetricsAggregator.snapshot()`` dict
    plus a wall-clock timestamp (the machine-readable sibling of the
    text report).

Writes are atomic (tmp file + ``os.replace``) so a scraper never reads
a half-written exposition, and a final write happens at ``stop()`` so
short runs always leave a complete last snapshot. Wired through
``ObserveSpec(export=...)`` — a directory string, a dict of knobs, or
an ``ExportSpec``.
"""

from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional

from .events import EventLog
from .metrics import MetricsAggregator


@dataclass
class ExportSpec:
    """Knobs for the periodic metrics exporter."""

    dir: str
    interval_s: float = 1.0
    prometheus: bool = True      # write metrics.prom
    snapshots: bool = True       # write snapshot.json
    # Keep a history of timestamped snapshots (snapshot_<n>.json) in
    # addition to the rolling latest; 0 keeps only the latest.
    history: int = 0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "dir": self.dir,
            "interval_s": self.interval_s,
            "prometheus": self.prometheus,
            "snapshots": self.snapshots,
            "history": self.history,
        }


def _atomic_write(path: str, text: str) -> None:
    tmp = f"{path}.tmp"
    with open(tmp, "w") as fh:
        fh.write(text)
    os.replace(tmp, path)


class MetricsExporter:
    """Periodically renders a live ``MetricsAggregator`` to disk."""

    def __init__(
        self,
        log: EventLog,
        spec: ExportSpec,
        slots_by_pool: Optional[Dict[str, int]] = None,
        aggregator: Optional[MetricsAggregator] = None,
    ) -> None:
        self.spec = spec
        self.slots_by_pool = dict(slots_by_pool or {})
        self.agg = aggregator if aggregator is not None else MetricsAggregator(log)
        self.writes = 0
        self._seq = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        os.makedirs(spec.dir, exist_ok=True)

    # ------------------------------------------------------------------ write
    def write_once(self) -> None:
        spec = self.spec
        if spec.prometheus:
            _atomic_write(
                os.path.join(spec.dir, "metrics.prom"),
                self.agg.prometheus_text(slots_by_pool=self.slots_by_pool),
            )
        if spec.snapshots:
            snap = self.agg.snapshot(slots_by_pool=self.slots_by_pool)
            snap["ts"] = time.time()
            text = json.dumps(snap)
            _atomic_write(os.path.join(spec.dir, "snapshot.json"), text)
            if spec.history:
                self._seq += 1
                _atomic_write(
                    os.path.join(spec.dir, f"snapshot_{self._seq % spec.history:04d}.json"),
                    text,
                )
        self.writes += 1

    # -------------------------------------------------------------- lifecycle
    def start(self) -> "MetricsExporter":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True, name="metrics-exporter")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.spec.interval_s):
            try:
                self.write_once()
            except OSError:
                pass  # disk hiccup: retry on the next tick

    def stop(self) -> None:
        self._stop.set()
        thread, self._thread = self._thread, None
        if thread is not None:
            thread.join(timeout=2)
        # Final write so short-lived runs still leave a complete snapshot.
        try:
            self.write_once()
        except OSError:
            pass

    def rebind(self, log: EventLog, aggregator: Optional[MetricsAggregator] = None) -> None:
        """Point the exporter at a fresh event log (benchmarks that swap
        logs between a warm-up and a measured phase; checkpoint resume).
        The replacement aggregator subscribes only to the *new* log, so
        events still arriving on the old one are never double-counted.
        Pass ``aggregator`` to share one instance with the ops server /
        SLO engine instead of building a private one."""
        self.agg = aggregator if aggregator is not None else MetricsAggregator(log)


__all__ = ["ExportSpec", "MetricsExporter"]
