"""KV / recurrent-state caches: definitions, update, decode attention.

Cache sharding prefers kv-head sharding over the model axis and falls
back to head_dim sharding when the head count does not divide the axis
(e.g. llama3's 8 kv heads on a 16-way model axis shard head_dim 128 ->
8 per device), keeping the 32k-token cache within per-chip HBM.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import decode_attention
from .layers import ParamDef, rope, shard


def attn_cache_defs(cfg: ModelConfig, batch: int, max_len: int,
                    kv: Optional[int] = None) -> Dict[str, ParamDef]:
    kvh = kv if kv is not None else cfg.n_kv_heads
    shape = (batch, kvh, max_len, cfg.head_dim)
    # kv-head sharding when it divides the model axis; otherwise shard the
    # cache LENGTH (flash-decode style): scores stay sequence-sharded and
    # only tiny (B,H) softmax stats + (B,H,hd) partial outputs cross chips.
    logical = ("batch", "cache_kv_heads", "cache_seq", None)
    return {
        "k": ParamDef(shape, logical, init="zeros"),
        "v": ParamDef(shape, logical, init="zeros"),
    }


def update_cache(cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                 k_new: jnp.ndarray, v_new: jnp.ndarray,
                 lengths: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Insert one token per sequence at position lengths[b].

    cache: (B, KV, S, hd); new: (B, 1, KV, hd); lengths: (B,).
    Implemented as a one-hot scatter (SPMD-friendly: no gather/scatter
    ops that would force resharding of the 32k cache)."""
    S = cache_k.shape[2]
    onehot = jax.nn.one_hot(lengths, S, dtype=cache_k.dtype)        # (B, S)
    k_b = k_new.swapaxes(1, 2)                                       # (B, KV, 1, hd)
    v_b = v_new.swapaxes(1, 2)
    sel = onehot[:, None, :, None]                                   # (B, 1, S, 1)
    cache_k = cache_k * (1 - sel) + sel * k_b
    cache_v = cache_v * (1 - sel) + sel * v_b
    return cache_k, cache_v


def decode_attention_step(
    cfg: ModelConfig,
    p: Dict[str, jnp.ndarray],
    cache_l: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                      # (B, 1, D) normed input
    lengths: jnp.ndarray,                # (B,)
    *,
    window: Optional[int] = None,
    use_rope: bool = True,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """GQA attention for one new token against the cache."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])                      # (B, 1, H, hd)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if use_rope:
        pos = lengths[:, None]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

    ck, cv = update_cache(cache_l["k"], cache_l["v"], k, v, lengths)
    ck = shard(ck, "batch", "cache_kv_heads", "cache_seq", None)
    cv = shard(cv, "batch", "cache_kv_heads", "cache_seq", None)

    # replicate the (tiny) single-token q across the model axis so the
    # score einsum keeps the (huge) cache sequence-sharded in place.
    q_rep = shard(q[:, 0], "batch", None, None)
    out = decode_attention(
        q_rep,                                                       # (B, H, hd)
        ck, cv, lengths + 1, window=window,
    )                                                                # (B, H, hd)
    out = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
    return shard(out, "batch", "seq", "embed"), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Windowed (ring-buffer) cache for local attention (griffin)
# ---------------------------------------------------------------------------


def ring_cache_defs(cfg: ModelConfig, batch: int, window: int) -> Dict[str, ParamDef]:
    kvh = cfg.n_kv_heads
    shape = (batch, kvh, window, cfg.head_dim)
    logical = ("batch", "cache_kv_heads", "cache_seq", None)
    return {
        "k": ParamDef(shape, logical, init="zeros"),
        "v": ParamDef(shape, logical, init="zeros"),
    }


def ring_decode_attention_step(
    cfg: ModelConfig,
    p: Dict[str, jnp.ndarray],
    cache_l: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    lengths: jnp.ndarray,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Local attention with a fixed ``window``-slot ring buffer.

    Keys are roped at their *absolute* position before storage; attention
    over a set of (k, v) is permutation-invariant, so slot order never
    matters and the buffer stays O(window) for 500k-token decodes."""
    window = cache_l["k"].shape[2]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    pos = lengths[:, None]
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    slots = lengths % window
    ck, cv = update_cache(cache_l["k"], cache_l["v"], k, v, slots)
    ck = shard(ck, "batch", "cache_kv_heads", "cache_seq", None)
    cv = shard(cv, "batch", "cache_kv_heads", "cache_seq", None)
    valid = jnp.minimum(lengths + 1, window)
    q_rep = shard(q[:, 0], "batch", None, None)
    out = decode_attention(q_rep, ck, cv, valid)
    out = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
    return shard(out, "batch", "seq", "embed"), {"k": ck, "v": cv}
