"""RecurrentGemma (Griffin): RG-LRU recurrent blocks + local attention, 1:2.

Layer pattern repeats (recurrent, recurrent, local_attn) — cfg.attn_every
= 3. The recurrent block is Griffin's gated unit: two linear branches,
one through a short causal depthwise conv then the RG-LRU diagonal
recurrence (``repro.kernels.rglru_scan``), one through a GeLU gate.
Local attention is sliding-window MQA with RoPE. Every layer is followed
by a GeGLU MLP. Decode state is O(1) per recurrent layer (conv tail +
LRU state) and O(window) per attention layer (ring-buffer KV cache) —
sub-quadratic, so this family runs the long_500k shape.

Layers are heterogeneous, so the stack is unrolled (26 layers) rather
than scanned; remat applies per block.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import rglru_scan
from .layers import (
    ParamDef,
    attention_block,
    attn_defs,
    cross_entropy,
    embed_tokens,
    mlp_block,
    mlp_defs,
    rms_norm,
    shard,
    unembed,
)
from .kvcache import ring_cache_defs, ring_decode_attention_step
from .transformer import norm_def, apply_norm

RGLRU_C = 8.0


def is_attn_layer(cfg: ModelConfig, i: int) -> bool:
    return cfg.attn_every > 0 and (i % cfg.attn_every) == (cfg.attn_every - 1)


def recurrent_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    w = cfg.conv_width
    return {
        "w_in_x": ParamDef((d, d), ("embed_w", "state")),       # recurrence branch
        "w_in_g": ParamDef((d, d), ("embed_w", "state")),       # gate branch
        "conv_w": ParamDef((w, d), (None, "state")),            # depthwise causal conv
        "conv_b": ParamDef((d,), ("state",), init="zeros"),
        "lru_input_gate": ParamDef((d, d), ("state", "state2")),
        "lru_rec_gate": ParamDef((d, d), ("state", "state2")),
        "lru_log_lambda": ParamDef((d,), (None,), init="normal", scale=0.5),
        "w_out": ParamDef((d, d), ("state", "embed_w")),
    }


def layer_defs(cfg: ModelConfig, i: int) -> Dict[str, Any]:
    temporal = (
        {"kind_attn": attn_defs(cfg)} if is_attn_layer(cfg, i)
        else {"kind_rec": recurrent_defs(cfg)}
    )
    return {
        "ln1": norm_def(cfg),
        "temporal": temporal,
        "ln2": norm_def(cfg),
        "ffn": mlp_defs(cfg),
    }


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "embed": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed_w")),
        "final_norm": norm_def(cfg),
        "layers": [layer_defs(cfg, i) for i in range(cfg.n_layers)],
    }


# ---------------------------------------------------------------------------
# RG-LRU + conv
# ---------------------------------------------------------------------------


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: jnp.ndarray = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x: (B,S,D), w: (W,D). ``tail``: (B,W-1,D)
    carries the last W-1 inputs for decode. Returns (y, new_tail)."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(W))
    return y + b, xp[:, -(W - 1):]


def _rglru(cfg: ModelConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray,
           h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) -> (y, h_final)."""
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["lru_rec_gate"]))
    i = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["lru_input_gate"]))
    log_a = (-RGLRU_C * jax.nn.softplus(p["lru_log_lambda"]) * r).astype(jnp.float32)
    gated = i * x
    scale = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)).astype(x.dtype)
    y, h_final = rglru_scan(log_a.astype(x.dtype), scale * gated, h0)
    return y, h_final


def recurrent_block(cfg, p, x, state):
    """state: dict(conv (B,W-1,D), h (B,D)). x normed (B,S,D)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_in_g"]), approximate=True)
    u = jnp.einsum("bsd,de->bse", x, p["w_in_x"])
    u = shard(u, "batch", "seq", "state")
    u, conv_tail = _causal_conv(u, p["conv_w"], p["conv_b"], state["conv"])
    y, h_final = _rglru(cfg, p, u, state["h"])
    out = jnp.einsum("bsd,de->bse", y * gate, p["w_out"])
    return shard(out, "batch", "seq", "embed"), {"conv": conv_tail, "h": h_final}


def _zero_rec_state(cfg, B, dtype):
    return {
        "conv": jnp.zeros((B, cfg.conv_width - 1, cfg.d_model), dtype),
        "h": jnp.zeros((B, cfg.d_model), dtype),
    }


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def _layer_train(cfg, i, p, x, positions):
    y = apply_norm(cfg, p["ln1"], x)
    if is_attn_layer(cfg, i):
        t = attention_block(cfg, p["temporal"]["kind_attn"], y, positions,
                            causal=True, window=cfg.local_window)
    else:
        t, _ = recurrent_block(cfg, p["temporal"]["kind_rec"], y,
                               _zero_rec_state(cfg, x.shape[0], x.dtype))
    x = x + t
    y = apply_norm(cfg, p["ln2"], x)
    return x + mlp_block(cfg, p["ffn"], y)


def forward(cfg: ModelConfig, params, batch, *, last_only: bool = False):
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, scale_by_dim=cfg.embed_scale)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
    for i, lp in enumerate(params["layers"]):
        blk = functools.partial(_layer_train, cfg, i)
        if cfg.remat != "none":
            blk = jax.checkpoint(blk, prevent_cse=False)
        x = blk(lp, x, positions)
    x = apply_norm(cfg, params["final_norm"], x)
    if last_only:
        x = x[:, -1:]
    logits = unembed(x, params["embed"], valid=cfg.vocab_size)   # tied
    return logits, {}


def loss_fn(cfg: ModelConfig, params, batch):
    logits, _ = forward(cfg, params, batch)
    loss = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"loss": loss, "ce_loss": loss}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    layers: List[Dict[str, Any]] = []
    window = min(cfg.local_window, max_len)
    for i in range(cfg.n_layers):
        if is_attn_layer(cfg, i):
            layers.append({"attn": ring_cache_defs(cfg, batch, window)})
        else:
            layers.append({
                "conv": ParamDef((batch, cfg.conv_width - 1, cfg.d_model),
                                 ("batch", None, "state"), init="zeros"),
                "h": ParamDef((batch, cfg.d_model), ("batch", "state"), init="zeros"),
            })
    return {"layers": layers}


def decode_step(cfg: ModelConfig, params, cache, tokens, lengths):
    x = embed_tokens(params["embed"], tokens, scale_by_dim=cfg.embed_scale)
    new_layers = []
    for i, (lp, cl) in enumerate(zip(params["layers"], cache["layers"])):
        y = apply_norm(cfg, lp["ln1"], x)
        if is_attn_layer(cfg, i):
            t, kv = ring_decode_attention_step(cfg, lp["temporal"]["kind_attn"], cl["attn"], y, lengths)
            new_layers.append({"attn": kv})
        else:
            t, st = recurrent_block(cfg, lp["temporal"]["kind_rec"], y, cl)
            new_layers.append(st)
        x = x + t
        y = apply_norm(cfg, lp["ln2"], x)
        x = x + mlp_block(cfg, lp["ffn"], y)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(x, params["embed"], valid=cfg.vocab_size)
    return logits, {"layers": new_layers}
