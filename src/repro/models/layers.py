"""Shared model layers + the logical-axis sharding system.

Sharding design (GSPMD / MaxText style): every parameter and key
activation is annotated with *logical* axis names; a rules table maps
logical names to candidate mesh axes, and ``resolve_pspec`` picks the
first candidate whose size divides the dimension (otherwise the dim is
replicated — e.g. gemma-2b's 8 attention heads on a 16-way model axis).
The mapping is mesh-aware, so the same model code runs on the single-pod
(16,16) mesh, the multi-pod (2,16,16) mesh, and a 1-device CPU test.

Parameters are declared as ``ParamDef`` trees: one declaration yields
the init fn, the PartitionSpec, and the ShapeDtypeStruct used by the
dry run, guaranteeing they never drift apart.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..kernels import flash_attention, rmsnorm

# ---------------------------------------------------------------------------
# Logical axis rules + mesh context
# ---------------------------------------------------------------------------

# logical name -> ordered candidate mesh-axis groups; the first group whose
# total size divides the dim (and whose axes are all present in the mesh)
# is used. Entries are tuples-of-axes (one dim may span several mesh axes).
def axis_rules(cfg: ModelConfig) -> Dict[str, Sequence[Tuple[str, ...]]]:
    fsdp = cfg.sharding == "fsdp_tp"
    # tp2d (decode-oriented): big weight matrices (ff/vocab dims) shard over
    # BOTH mesh axes so they stay device-resident — no per-token FSDP
    # re-gathers; the (tiny, batch-sized) activations psum instead.
    tp2d = cfg.sharding == "tp2d"
    ff_rule = [("data", "model"), ("model",)] if tp2d else [("model",)]
    vocab_rule = [("data", "model"), ("model",)] if tp2d else [("model",)]
    sp = cfg.seq_shard_norm
    return {
        "batch": [("pod", "data"), ("data",)],
        "seq": [],                           # attention-visible seq: unsharded
        "seq_sp": [("model",)] if sp else [],   # SP: inter-block activations
        "seq_cp": [("model",)],              # context parallelism (see below)
        "embed": [],                         # activation d_model: replicated
        "heads": [("model",)],
        "heads_flat": [("model",)],          # fused (H*hd) projections (rwkv)
        "kv_heads": [("model",)],
        # tp2d: attention weights also go resident by sharding head_dim
        # over the data axis; the (tiny) q/k/v activations re-gather.
        "head_dim": [("data",)] if tp2d else [],
        "cache_kv_heads": [("model",)],      # kv cache: prefer kv-head sharding,
        "cache_seq": [("model",)],           # else shard cache length (flash-decode),
        "cache_head_dim": [("model",)],      # head_dim only for cross-attn KV
        "ff": ff_rule,
        "experts": [("model",)],
        "expert_cap": [],
        "vocab": vocab_rule,
        "embed_w": [("data",)] if fsdp else [],   # FSDP: weights' d_model dim
        "ff_w": [("model",)],
        "layers": [],
        "state": [("model",)],               # recurrent state channels
        "state2": [],                        # 2nd dim of square state matrices
        None: [],
    }


@dataclass
class MeshContext:
    mesh: Mesh
    cfg: ModelConfig
    rules: Dict[str, Sequence[Tuple[str, ...]]]


_TLS = threading.local()


def set_mesh(mesh: Optional[Mesh], cfg: ModelConfig) -> None:
    _TLS.ctx = MeshContext(mesh, cfg, axis_rules(cfg)) if mesh is not None else None


def clear_mesh() -> None:
    _TLS.ctx = None


def current_ctx() -> Optional[MeshContext]:
    return getattr(_TLS, "ctx", None)


class mesh_context:
    """``with mesh_context(mesh, cfg): ...`` — scoped sharding annotations."""

    def __init__(self, mesh: Optional[Mesh], cfg: ModelConfig) -> None:
        self.mesh, self.cfg = mesh, cfg

    def __enter__(self):
        self._prev = current_ctx()
        set_mesh(self.mesh, self.cfg)
        return self

    def __exit__(self, *exc):
        _TLS.ctx = self._prev
        return False


def resolve_pspec(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Dict[str, Sequence[Tuple[str, ...]]],
) -> P:
    """Map logical dim names to mesh axes with divisibility checking.

    Each mesh axis is used at most once per spec (GSPMD requirement)."""
    used: set = set()
    out = []
    for name, dim in zip(logical, shape):
        chosen = None
        for axes in rules.get(name, []):
            if any(a not in mesh.axis_names or a in used for a in axes):
                continue
            total = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % total == 0 and dim >= total:
                chosen = axes
                used.update(axes)
                break
        if chosen is None:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
        else:
            out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jnp.ndarray, *logical: Optional[str]) -> jnp.ndarray:
    """Apply a with_sharding_constraint from logical names (no-op w/o mesh)."""
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return x
    spec = resolve_pspec(logical, x.shape, ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# ParamDef system
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | small_normal
    scale: float = 0.02
    dtype: Optional[str] = None  # None -> config dtype

    def initialize(self, key: jax.Array, cfg: ModelConfig) -> jnp.ndarray:
        dtype = jnp.dtype(self.dtype or cfg.dtype)
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        scale = self.scale if self.init == "normal" else self.scale * 0.1
        return (jax.random.normal(key, self.shape, jnp.float32) * scale).astype(dtype)


def _traverse(tree: Any, fn: Callable[[ParamDef, Tuple], Any], path: Tuple = ()) -> Any:
    if isinstance(tree, ParamDef):
        return fn(tree, path)
    if isinstance(tree, dict):
        return {k: _traverse(v, fn, path + (k,)) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_traverse(v, fn, path + (i,)) for i, v in enumerate(tree))
    raise TypeError(f"unexpected node {type(tree)} at {path}")


def init_params(defs: Any, rng: jax.Array, cfg: ModelConfig) -> Any:
    """Materialize a ParamDef tree into arrays.

    Seeding uses crc32 of the parameter path — NOT Python ``hash()``,
    which is randomized per process and would make initialization
    irreproducible across restarts/hosts."""
    import zlib

    def one(d: ParamDef, path: Tuple) -> jnp.ndarray:
        seed = zlib.crc32("/".join(map(str, path)).encode()) % (2 ** 31 - 1)
        key = jax.random.fold_in(rng, seed)
        return d.initialize(key, cfg)

    return _traverse(defs, one)


def param_pspecs(defs: Any, mesh: Mesh, cfg: ModelConfig) -> Any:
    rules = axis_rules(cfg)

    def one(d: ParamDef, path: Tuple) -> P:
        return resolve_pspec(d.logical, d.shape, mesh, rules)

    return _traverse(defs, one)


def param_shapes(defs: Any, cfg: ModelConfig) -> Any:
    def one(d: ParamDef, path: Tuple) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or cfg.dtype))

    return _traverse(defs, one)


def param_count(defs: Any) -> int:
    total = 0

    def one(d: ParamDef, path: Tuple) -> int:
        nonlocal total
        total += int(np.prod(d.shape))
        return 0

    _traverse(defs, one)
    return total


def stack_defs(layer_defs: Any, n_layers: int) -> Any:
    """Prepend a (scan) layer axis to every ParamDef in a layer tree."""

    def one(d: ParamDef, path: Tuple) -> ParamDef:
        return ParamDef(
            shape=(n_layers,) + d.shape,
            logical=("layers",) + d.logical,
            init=d.init,
            scale=d.scale,
            dtype=d.dtype,
        )

    return _traverse(layer_defs, one)


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6, offset: float = 0.0) -> jnp.ndarray:
    return rmsnorm(x, w, eps=eps, scale_offset=offset)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mean = xf.mean(-1, keepdims=True)
    var = ((xf - mean) ** 2).mean(-1, keepdims=True)
    return ((xf - mean) * jax.lax.rsqrt(var + eps) * w + b).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (B, S, H, D), positions: (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs        # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def embed_tokens(table: jnp.ndarray, tokens: jnp.ndarray, scale_by_dim: bool = False) -> jnp.ndarray:
    x = jnp.take(table, tokens, axis=0)
    if scale_by_dim:
        x = x * jnp.asarray(table.shape[-1] ** 0.5, x.dtype)
    return shard(x, "batch", "seq_sp", "embed")


def unembed(x: jnp.ndarray, table: jnp.ndarray, valid: Optional[int] = None) -> jnp.ndarray:
    """x: (B, S, D), table: (Vpad, D) -> logits (B, S, Vpad); rows beyond
    ``valid`` (vocab padding) are masked to -1e9 so softmax/argmax/CE
    ignore them."""
    ctx = current_ctx()
    if ctx is not None and ctx.cfg.sharding == "tp2d":
        x = shard(x, None, "seq", "embed")       # replicate tiny decode batch
        logits = jnp.einsum("bsd,vd->bsv", x, table)
        return shard(logits, None, "seq", "vocab")   # vocab -> (data, model)
    def mask_pad(logits):
        if valid is not None and valid < table.shape[0]:
            pad_mask = jnp.arange(table.shape[0]) >= valid
            logits = jnp.where(pad_mask[None, None], -1e9, logits)
        return logits

    ctx2 = current_ctx()
    if ctx2 is not None and ctx2.cfg.seq_shard_norm:
        # SP: tokens stay sequence-sharded; softmax/CE run fully local
        # (no vocab-axis collectives, logits 1/16th per device)
        x = shard(x, "batch", "seq_sp", "embed")
        logits = mask_pad(jnp.einsum("bsd,vd->bsv", x, table))
        return shard(logits, "batch", "seq_sp", None)
    logits = mask_pad(jnp.einsum("bsd,vd->bsv", x, table))
    return shard(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Attention (GQA) + MLP blocks, shared by dense/MoE/whisper/vlm families
# ---------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig, d_model: Optional[int] = None, kv: Optional[int] = None) -> Dict[str, ParamDef]:
    d = d_model or cfg.d_model
    kvh = kv if kv is not None else cfg.n_kv_heads
    hd = cfg.head_dim
    return {
        "wq": ParamDef((d, cfg.n_heads, hd), ("embed_w", "heads", "head_dim")),
        "wk": ParamDef((d, kvh, hd), ("embed_w", "kv_heads", "head_dim")),
        "wv": ParamDef((d, kvh, hd), ("embed_w", "kv_heads", "head_dim")),
        "wo": ParamDef((cfg.n_heads, hd, d), ("heads", "head_dim", "embed_w")),
    }


def mlp_defs(cfg: ModelConfig, d_model: Optional[int] = None, d_ff: Optional[int] = None) -> Dict[str, ParamDef]:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": ParamDef((d, f), ("embed_w", "ff")),
            "w_up": ParamDef((d, f), ("embed_w", "ff")),
            "w_down": ParamDef((f, d), ("ff", "embed_w")),
        }
    return {
        "w_up": ParamDef((d, f), ("embed_w", "ff")),
        "w_down": ParamDef((f, d), ("ff", "embed_w")),
    }


def apply_qkv(p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def context_parallel_attention(cfg: ModelConfig) -> bool:
    """True when attention heads cannot shard over the model axis (e.g.
    whisper's 20 or gemma's 8 heads on a 16-way axis): fall back to
    CONTEXT PARALLELISM — shard the query sequence dim instead, so each
    device attends 1/model_axis of the queries against (small, gathered)
    keys/values rather than replicating the whole attention."""
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return False
    msize = dict(ctx.mesh.shape).get("model", 1)
    return msize > 1 and cfg.n_heads % msize != 0


def attention_block(
    cfg: ModelConfig,
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                     # (B, S, D)
    positions: jnp.ndarray,             # (B, S)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cross-attn
    attn_kwargs: Optional[dict] = None,
) -> jnp.ndarray:
    q, k, v = apply_qkv(p, x)
    if kv_override is not None:
        k, v = kv_override
    else:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    cp = context_parallel_attention(cfg)
    if cp:
        q = shard(q, "batch", "seq_cp", None, None)
    # kernels expect (B, H, S, D)
    out = flash_attention(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=causal, window=window, **(attn_kwargs or {}),
    ).swapaxes(1, 2)                     # (B, S, H, hd)
    if cp:
        out = shard(out, "batch", "seq_cp", None, None)
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return shard(out, "batch", "seq_cp", "embed")
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard(out, "batch", "seq_sp", "embed")


def cross_attention_block(
    cfg: ModelConfig,
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                      # decoder states (B, S, D)
    enc_kv: Tuple[jnp.ndarray, jnp.ndarray],   # precomputed (B, Se, KV, hd) pairs
) -> jnp.ndarray:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = enc_kv
    if context_parallel_attention(cfg):
        q = shard(q, "batch", "seq_cp", None, None)
    out = flash_attention(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2), causal=False,
    ).swapaxes(1, 2)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if context_parallel_attention(cfg):
        return shard(out, "batch", "seq_cp", "embed")
    return shard(out, "batch", "seq_sp", "embed")


def mlp_block(cfg: ModelConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    if cfg.sharding == "tp2d":
        # decode-oriented 2D TP: weights stay resident (ff sharded over
        # data x model); the batch-replicated activations flow through and
        # the down-projection partial-sums. Worth it when B*S is tiny
        # (decode) and weights are huge — see EXPERIMENTS.md §Perf.
        x = shard(x, None, "seq", "embed")          # replicate batch
        if cfg.activation in ("swiglu", "geglu"):
            act = jax.nn.silu if cfg.activation == "swiglu" else (
                lambda t: jax.nn.gelu(t, approximate=True))
            h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
            h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
        elif cfg.activation == "relu_sq":
            h = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", x, p["w_up"])))
        else:
            h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]), approximate=True)
        h = shard(h, None, "seq", "ff")              # ff -> (data, model)
        out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
        return shard(out, "batch", "seq", "embed")
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]), approximate=True)
        h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]), approximate=True)
    elif cfg.activation == "relu_sq":
        h = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", x, p["w_up"])))
    else:
        raise ValueError(cfg.activation)
    h = shard(h, "batch", "seq", "ff")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return shard(out, "batch", "seq_sp", "embed")


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(
    logits: jnp.ndarray,          # (B, S, V)
    labels: jnp.ndarray,          # (B, S) int32
    mask: Optional[jnp.ndarray] = None,   # (B, S) 1=count
    z_loss: float = 0.0,
) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    one_hot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.einsum("bsv,bsv->bs", logits, one_hot)
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
