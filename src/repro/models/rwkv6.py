"""RWKV-6 "Finch": attention-free LM with data-dependent decay.

Per layer: a *time-mixing* block (token shift -> r/k/v/gate/decay
projections -> multi-head WKV linear-attention recurrence with per-step
data-dependent decay -> group norm -> output proj) and a *channel-mixing*
block (token shift -> squared-ReLU MLP). The WKV recurrence runs through
the chunked kernel (``repro.kernels.wkv6``); decode keeps an O(1) state
(per-head KxV matrix + last-token shift states), which is what makes the
long_500k shape tractable for this family.

Faithful-with-noted-simplifications: the five per-projection static
token-shift mixes are kept; the data-dependent LoRA modulation is applied
to the decay (the component that matters for the recurrence) rather than
to all five mixes.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import wkv6
from .layers import ParamDef, cross_entropy, embed_tokens, rms_norm, shard, stack_defs, unembed

LORA_RANK = 32


def _heads(cfg: ModelConfig) -> Tuple[int, int]:
    hd = cfg.rwkv_head_dim
    return cfg.d_model // hd, hd


def layer_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    H, K = _heads(cfg)
    return {
        "ln1": {"w": ParamDef((d,), (None,), init="ones")},
        "tmix": {
            "mu_r": ParamDef((d,), (None,), init="zeros"),
            "mu_k": ParamDef((d,), (None,), init="zeros"),
            "mu_v": ParamDef((d,), (None,), init="zeros"),
            "mu_w": ParamDef((d,), (None,), init="zeros"),
            "mu_g": ParamDef((d,), (None,), init="zeros"),
            "wr": ParamDef((d, d), ("embed_w", "heads_flat")),
            "wk": ParamDef((d, d), ("embed_w", "heads_flat")),
            "wv": ParamDef((d, d), ("embed_w", "heads_flat")),
            "wg": ParamDef((d, d), ("embed_w", "heads_flat")),
            "w0": ParamDef((d,), (None,), init="zeros"),
            "w_lora_a": ParamDef((d, LORA_RANK), ("embed_w", None)),
            "w_lora_b": ParamDef((LORA_RANK, d), (None, None)),
            "u": ParamDef((H, K), (None, None), init="zeros"),
            "ln_x": ParamDef((d,), (None,), init="ones"),
            "wo": ParamDef((d, d), ("heads_flat", "embed_w")),
        },
        "ln2": {"w": ParamDef((d,), (None,), init="ones")},
        "cmix": {
            "mu_k": ParamDef((d,), (None,), init="zeros"),
            "mu_r": ParamDef((d,), (None,), init="zeros"),
            "wk": ParamDef((d, f), ("embed_w", "ff")),
            "wv": ParamDef((f, d), ("ff", "embed_w")),
            "wr": ParamDef((d, d), ("embed_w", None)),
        },
    }


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    defs = {
        "embed": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed_w")),
        "final_norm": {"w": ParamDef((cfg.d_model,), (None,), init="ones")},
        "unembed": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed_w")),
    }
    if cfg.scan_layers:
        defs["layers"] = stack_defs(layer_defs(cfg), cfg.n_layers)
    else:
        defs["layers"] = [layer_defs(cfg) for _ in range(cfg.n_layers)]
    return defs


def _shift(x: jnp.ndarray, last: jnp.ndarray) -> jnp.ndarray:
    """Token shift: x_{t-1} with ``last`` filling position 0. x: (B,S,D)."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _mix(x, xx, mu):
    return x + (xx - x) * mu


def _tmix_inputs(cfg, p, x, last_x):
    """Compute r,k,v,g,log-decay for a sequence (B,S,D)."""
    H, K = _heads(cfg)
    B, S, d = x.shape
    xx = _shift(x, last_x)
    r = jnp.einsum("bsd,de->bse", _mix(x, xx, p["mu_r"]), p["wr"])
    k = jnp.einsum("bsd,de->bse", _mix(x, xx, p["mu_k"]), p["wk"])
    v = jnp.einsum("bsd,de->bse", _mix(x, xx, p["mu_v"]), p["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", _mix(x, xx, p["mu_g"]), p["wg"]))
    xw = _mix(x, xx, p["mu_w"])
    lora = jnp.einsum("bsr,rd->bsd", jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["w_lora_a"])), p["w_lora_b"])
    lw = -jnp.exp(jnp.clip(p["w0"] + lora, -8.0, 6.0).astype(jnp.float32))  # log decay <= 0

    def to_heads(t, dim=K):
        return t.reshape(B, S, H, dim).swapaxes(1, 2)   # (B,H,S,K)

    return to_heads(r), to_heads(k), to_heads(v), to_heads(lw.astype(x.dtype)), g


def _group_norm(x: jnp.ndarray, w: jnp.ndarray, H: int, eps: float = 64e-5) -> jnp.ndarray:
    """Per-head group norm over the flattened head outputs. x: (B,S,D)."""
    B, S, d = x.shape
    xg = x.reshape(B, S, H, d // H).astype(jnp.float32)
    mean = xg.mean(-1, keepdims=True)
    var = ((xg - mean) ** 2).mean(-1, keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(B, S, d) * w).astype(x.dtype)


def tmix_block(cfg, p, x, last_x, state0):
    """x: (B,S,D) normed. Returns (out, new_last_x, new_state)."""
    H, K = _heads(cfg)
    r, k, v, lw, g = _tmix_inputs(cfg, p, x, last_x)
    lwf = lw.astype(jnp.float32)
    out, state = wkv6(r, k, v, lwf, p["u"].astype(jnp.float32), state0)
    B, _, S, _ = out.shape  # (B,H,S,K)
    out = out.swapaxes(1, 2).reshape(B, S, cfg.d_model)
    out = _group_norm(out, p["ln_x"], H) * g
    out = jnp.einsum("bsd,de->bse", out, p["wo"])
    return shard(out, "batch", "seq", "embed"), x[:, -1], state


def cmix_block(cfg, p, x, last_x):
    xx = _shift(x, last_x)
    k = jnp.einsum("bsd,df->bsf", _mix(x, xx, p["mu_k"]), p["wk"])
    k = jnp.square(jax.nn.relu(k))
    k = shard(k, "batch", "seq", "ff")
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", _mix(x, xx, p["mu_r"]), p["wr"]))
    return shard(r * kv, "batch", "seq", "embed"), x[:, -1]


def _layer(cfg, p, x, st):
    """st: dict(tmix_x (B,D), cmix_x (B,D), wkv (B,H,K,K))."""
    y, tlast, wkv_state = tmix_block(cfg, p["tmix"], rms_norm(x, p["ln1"]["w"], eps=cfg.norm_eps), st["tmix_x"], st["wkv"])
    x = x + y
    y, clast = cmix_block(cfg, p["cmix"], rms_norm(x, p["ln2"]["w"], eps=cfg.norm_eps), st["cmix_x"])
    x = x + y
    return x, {"tmix_x": tlast, "cmix_x": clast, "wkv": wkv_state}


def state_defs(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    H, K = _heads(cfg)
    per = {
        "tmix_x": ParamDef((batch, cfg.d_model), ("batch", "state"), init="zeros"),
        "cmix_x": ParamDef((batch, cfg.d_model), ("batch", "state"), init="zeros"),
        "wkv": ParamDef((batch, H, K, K), ("batch", "heads", None, None), init="zeros", dtype="float32"),
    }
    if cfg.scan_layers:
        return {"layers": stack_defs(per, cfg.n_layers)}
    return {"layers": [per for _ in range(cfg.n_layers)]}


def _zero_state(cfg, batch_size, dtype):
    H, K = _heads(cfg)
    per = {
        "tmix_x": jnp.zeros((batch_size, cfg.d_model), dtype),
        "cmix_x": jnp.zeros((batch_size, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch_size, H, K, K), jnp.float32),
    }
    return per


def forward(cfg: ModelConfig, params, batch, *, last_only: bool = False):
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens)
    B = x.shape[0]
    zero = _zero_state(cfg, B, x.dtype)

    if cfg.scan_layers:
        def body(x, lp):
            x, _ = _layer(cfg, lp, x, zero)
            return x, None

        if cfg.remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["layers"])
    else:
        for lp in params["layers"]:
            blk = (lambda p_, x_: _layer(cfg, p_, x_, zero))
            if cfg.remat != "none":
                blk = jax.checkpoint(blk, prevent_cse=False)
            x, _ = blk(lp, x)
    x = rms_norm(x, params["final_norm"]["w"], eps=cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    logits = unembed(x, params["unembed"], valid=cfg.vocab_size)
    return logits, {}


def loss_fn(cfg: ModelConfig, params, batch):
    logits, _ = forward(cfg, params, batch)
    loss = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"loss": loss, "ce_loss": loss}


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    return state_defs(cfg, batch)


def decode_step(cfg: ModelConfig, params, cache, tokens, lengths):
    """Single-token step: runs the same layer code with S=1."""
    x = embed_tokens(params["embed"], tokens)       # (B, 1, D)

    if cfg.scan_layers:
        def body(x, scanned):
            lp, st = scanned
            x, st = _layer(cfg, lp, x, st)
            return x, st

        x, new_states = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = {"layers": new_states}
    else:
        new_states = []
        for lp, st in zip(params["layers"], cache["layers"]):
            x, st = _layer(cfg, lp, x, st)
            new_states.append(st)
        cache = {"layers": new_states}
    x = rms_norm(x, params["final_norm"]["w"], eps=cfg.norm_eps)
    logits = unembed(x, params["unembed"], valid=cfg.vocab_size)
    return logits, cache
