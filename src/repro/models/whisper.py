"""Whisper-large-v3 backbone: encoder-decoder with cross-attention.

Per the assignment the conv/mel frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings (B, encoder_seq, d_model). The
encoder is a bidirectional transformer with fixed sinusoidal positions;
the decoder is a causal transformer with self- + cross-attention.

Hardware adaptation note (DESIGN.md): the decoder uses RoPE instead of
Whisper's 448-slot learned positions so the assigned 4k-train / 32k-decode
backbone shapes are well-defined; pre-LN layernorm (with bias) is kept.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import (
    ParamDef,
    attention_block,
    attn_defs,
    cross_attention_block,
    cross_entropy,
    embed_tokens,
    mlp_block,
    mlp_defs,
    shard,
    stack_defs,
    unembed,
)
from .kvcache import attn_cache_defs, decode_attention_step
from .transformer import norm_def, apply_norm


def _sinusoid(seq: int, d: int, dtype) -> jnp.ndarray:
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-dim * (jnp.log(10000.0) / (d // 2 - 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def enc_layer_defs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": norm_def(cfg),
        "attn": attn_defs(cfg),
        "ln2": norm_def(cfg),
        "ffn": mlp_defs(cfg),
    }


def dec_layer_defs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": norm_def(cfg),
        "self_attn": attn_defs(cfg),
        "ln_x": norm_def(cfg),
        "cross_attn": attn_defs(cfg),
        "ln2": norm_def(cfg),
        "ffn": mlp_defs(cfg),
    }


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.scan_layers:
        enc = stack_defs(enc_layer_defs(cfg), cfg.encoder_layers)
        dec = stack_defs(dec_layer_defs(cfg), cfg.n_layers)
    else:
        enc = [enc_layer_defs(cfg) for _ in range(cfg.encoder_layers)]
        dec = [dec_layer_defs(cfg) for _ in range(cfg.n_layers)]
    return {
        "embed": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed_w")),
        "enc_layers": enc,
        "enc_norm": norm_def(cfg),
        "dec_layers": dec,
        "final_norm": norm_def(cfg),
    }


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    return jax.checkpoint(fn, prevent_cse=False)


def encode(cfg: ModelConfig, params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, Se, D) stub embeddings -> encoder states (B, Se, D)."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)[None]
    x = shard(x, "batch", "seq", "embed")
    def enc_block(x, lp):
        # bidirectional self-attention, no rope (sinusoidal positions above)
        y = apply_norm(cfg, lp["ln1"], x)
        from .layers import apply_qkv, context_parallel_attention, shard as _shard
        from ..kernels import flash_attention
        q, k, v = apply_qkv(lp["attn"], y)
        if context_parallel_attention(cfg):
            q = _shard(q, "batch", "seq_cp", None, None)
        att = flash_attention(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
                              causal=False).swapaxes(1, 2)
        att = jnp.einsum("bshk,hkd->bsd", att, lp["attn"]["wo"])
        x = x + shard(att, "batch", "seq", "embed")
        y = apply_norm(cfg, lp["ln2"], x)
        return x + mlp_block(cfg, lp["ffn"], y), None

    enc_block = _remat(cfg, enc_block)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(enc_block, x, params["enc_layers"])
    else:
        for lp in params["enc_layers"]:
            x, _ = enc_block(x, lp)
    return apply_norm(cfg, params["enc_norm"], x)


def _cross_kv(cfg, p, enc: jnp.ndarray):
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"])
    return k, v


def decode_stack(cfg: ModelConfig, params, x: jnp.ndarray, enc: jnp.ndarray) -> jnp.ndarray:
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])

    def dec_block(x, lp):
        y = apply_norm(cfg, lp["ln1"], x)
        x = x + attention_block(cfg, lp["self_attn"], y, positions, causal=True)
        y = apply_norm(cfg, lp["ln_x"], x)
        x = x + cross_attention_block(cfg, lp["cross_attn"], y, _cross_kv(cfg, lp["cross_attn"], enc))
        y = apply_norm(cfg, lp["ln2"], x)
        return x + mlp_block(cfg, lp["ffn"], y), None

    dec_block = _remat(cfg, dec_block)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(dec_block, x, params["dec_layers"])
    else:
        for lp in params["dec_layers"]:
            x, _ = dec_block(x, lp)
    return apply_norm(cfg, params["final_norm"], x)


def forward(cfg: ModelConfig, params, batch, *, last_only: bool = False):
    enc = encode(cfg, params, batch["frames"])
    x = embed_tokens(params["embed"], batch["tokens"])
    x = decode_stack(cfg, params, x, enc)
    if last_only:
        x = x[:, -1:]
    logits = unembed(x, params["embed"], valid=cfg.vocab_size)   # whisper ties embeddings
    return logits, {}


def loss_fn(cfg: ModelConfig, params, batch):
    logits, _ = forward(cfg, params, batch)
    loss = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"loss": loss, "ce_loss": loss}


# ---------------------------------------------------------------------------
# Decode: self-attn KV cache + precomputed cross-attn KV
# ---------------------------------------------------------------------------


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    per = {
        "self": attn_cache_defs(cfg, batch, max_len),
        "cross_k": ParamDef((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim),
                            ("batch", None, "cache_kv_heads", "cache_head_dim"), init="zeros"),
        "cross_v": ParamDef((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim),
                            ("batch", None, "cache_kv_heads", "cache_head_dim"), init="zeros"),
    }
    if cfg.scan_layers:
        return {"layers": stack_defs(per, cfg.n_layers)}
    return {"layers": [per for _ in range(cfg.n_layers)]}


def prefill_cross(cfg: ModelConfig, params, cache, frames: jnp.ndarray):
    """Run the encoder on stub frames and fill the per-layer cross-attn KV."""
    enc = encode(cfg, params, frames)

    def per_layer(lp):
        k = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wv"])
        return k, v

    ks, vs = jax.vmap(per_layer)(params["dec_layers"])
    return {"layers": {**cache["layers"], "cross_k": ks, "cross_v": vs}}


def decode_step(cfg: ModelConfig, params, cache, tokens, lengths):
    x = embed_tokens(params["embed"], tokens)

    def body(x, scanned):
        lp, cl = scanned
        y = apply_norm(cfg, lp["ln1"], x)
        att, new_self = decode_attention_step(cfg, lp["self_attn"], cl["self"], y, lengths)
        x = x + att
        y = apply_norm(cfg, lp["ln_x"], x)
        x = x + cross_attention_block(cfg, lp["cross_attn"], y, (cl["cross_k"], cl["cross_v"]))
        y = apply_norm(cfg, lp["ln2"], x)
        x = x + mlp_block(cfg, lp["ffn"], y)
        return x, {"self": new_self, "cross_k": cl["cross_k"], "cross_v": cl["cross_v"]}

    if cfg.scan_layers:
        x, new_layers = jax.lax.scan(body, x, (params["dec_layers"], cache["layers"]))
    else:
        new_layers = []
        for lp, cl in zip(params["dec_layers"], cache["layers"]):
            x, cl = body(x, (lp, cl))
            new_layers.append(cl)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(x, params["embed"], valid=cfg.vocab_size)
    return logits, {"layers": new_layers}
