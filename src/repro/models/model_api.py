"""Uniform Model facade over the architecture families.

``build_model(cfg)`` returns a ``Model`` exposing:
  * ``defs`` / ``init`` / ``pspecs`` / ``shapes`` — parameter tree
    declaration, materialization, PartitionSpecs, ShapeDtypeStructs;
  * ``forward`` / ``loss`` — full-sequence compute;
  * ``cache_defs`` / ``init_cache`` / ``cache_pspecs`` — decode state;
  * ``decode_step`` — single-token decode;
  * ``input_specs(shape)`` — ShapeDtypeStruct stand-ins for every input
    of the train/prefill/decode step (the dry-run contract).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..configs.base import ModelConfig, ShapeConfig
from . import griffin, rwkv6, transformer, whisper
from .layers import (
    axis_rules,
    init_params,
    param_count,
    param_pspecs,
    param_shapes,
    resolve_pspec,
)

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "rwkv6": rwkv6,
    "griffin": griffin,
    "whisper": whisper,
}


@dataclass
class Model:
    cfg: ModelConfig
    mod: Any

    # ------------------------------------------------------------- params
    @property
    def defs(self):
        return self.mod.model_defs(self.cfg)

    def init(self, rng: jax.Array):
        return init_params(self.defs, rng, self.cfg)

    def pspecs(self, mesh: Mesh):
        return param_pspecs(self.defs, mesh, self.cfg)

    def shapes(self):
        return param_shapes(self.defs, self.cfg)

    def n_params(self) -> int:
        return param_count(self.defs)

    # ------------------------------------------------------------ compute
    def forward(self, params, batch, *, last_only: bool = False):
        return self.mod.forward(self.cfg, params, batch, last_only=last_only)

    def loss(self, params, batch):
        return self.mod.loss_fn(self.cfg, params, batch)

    # ------------------------------------------------------------- decode
    def cache_defs(self, batch: int, max_len: int):
        return self.mod.cache_defs(self.cfg, batch, max_len)

    def init_cache(self, batch: int, max_len: int):
        zeros = jax.random.PRNGKey(0)
        return init_params(self.cache_defs(batch, max_len), zeros, self.cfg)

    def cache_pspecs(self, mesh: Mesh, batch: int, max_len: int):
        return param_pspecs(self.cache_defs(batch, max_len), mesh, self.cfg)

    def cache_shapes(self, batch: int, max_len: int):
        return param_shapes(self.cache_defs(batch, max_len), self.cfg)

    def decode_step(self, params, cache, tokens, lengths):
        return self.mod.decode_step(self.cfg, params, cache, tokens, lengths)

    # --------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeConfig, mesh: Optional[Mesh] = None) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for one step's inputs (no allocation).

        train/prefill: {tokens, labels[, frames|patches]};
        decode: {tokens (B,1), lengths (B,)} (cache specs come separately).
        """
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        rules = axis_rules(cfg)

        def spec(shp, dtype, logical):
            if mesh is None:
                return jax.ShapeDtypeStruct(shp, dtype)
            from jax.sharding import NamedSharding
            ps = resolve_pspec(logical, shp, mesh, rules)
            return jax.ShapeDtypeStruct(shp, dtype, sharding=NamedSharding(mesh, ps))

        dt = jnp.dtype(cfg.dtype)
        if shape.kind in ("train", "prefill"):
            out = {
                "tokens": spec((B, S), jnp.int32, ("batch", "seq")),
            }
            if shape.kind == "train":
                out["labels"] = spec((B, S), jnp.int32, ("batch", "seq"))
            if cfg.family == "whisper":
                out["frames"] = spec((B, cfg.encoder_seq, cfg.d_model), dt, ("batch", "seq", "embed"))
            if cfg.family == "vlm":
                out["patches"] = spec((B, cfg.vision_patches, cfg.d_model), dt, ("batch", "seq", "embed"))
            return out
        # decode: one new token against a cache of S
        return {
            "tokens": spec((B, 1), jnp.int32, ("batch", "seq")),
            "lengths": spec((B,), jnp.int32, ("batch",)),
        }


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg, mod=_FAMILY[cfg.family])
