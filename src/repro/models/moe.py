"""Mixture-of-Experts FFN with expert parallelism.

Two dispatch implementations (selectable via ``cfg.moe_dispatch``):

  * ``scatter`` (default, memory-light): assignments are sorted by
    expert id; ranks within each expert come from a searchsorted over
    the sorted ids (no (T, E, C) one-hot); tokens scatter into a
    (E, C, d) buffer sharded over the model axis (expert parallelism),
    run through their expert MLP as grouped einsums, and gather back.
    Peak temp memory is O(E*C*d) instead of O(T*E*C).
  * ``onehot`` (reference): the classic Switch-Transformer einsum
    dispatch with an explicit (T, E, C) dispatch mask — used as the
    correctness oracle in tests and for tiny decode batches.

Load-balancing aux loss and router z-loss follow the standard
formulation; capacity = ceil(T * k / E) * capacity_factor.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import ParamDef, shard


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamDef((d, E), ("embed_w", None)),
        "w_gate": ParamDef((E, d, f), ("experts", "embed_w", None)),
        "w_up": ParamDef((E, d, f), ("experts", "embed_w", None)),
        "w_down": ParamDef((E, f, d), ("experts", None, "embed_w")),
    }


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = math.ceil(n_tokens * cfg.experts_per_token / cfg.n_experts * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)   # round up to a multiple of 4


def _expert_mlp(cfg: ModelConfig, p: Dict[str, jnp.ndarray], xe: jnp.ndarray) -> jnp.ndarray:
    """xe: (E, C, d) -> (E, C, d), grouped per-expert MLP."""
    xe = shard(xe, "experts", None, "embed")
    gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    h = jax.nn.silu(gate) * up
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    return shard(out, "experts", None, "embed")


def _router(cfg: ModelConfig, p: Dict[str, jnp.ndarray], x_flat: jnp.ndarray):
    logits = jnp.einsum("td,de->te", x_flat, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux losses
    T, E = logits.shape
    frac_tokens = jnp.zeros((E,), jnp.float32).at[top_idx.reshape(-1)].add(1.0) / (
        T * cfg.experts_per_token
    )
    frac_probs = probs.mean(0)
    load_loss = E * jnp.sum(frac_tokens * frac_probs)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return top_p, top_idx, {"moe_load_loss": load_loss, "moe_z_loss": z_loss}


def _n_groups(T: int) -> int:
    """Hierarchical (GShard-style) dispatch groups = number of data shards.

    Sorting/scattering the GLOBAL token axis under SPMD forces the
    partitioner to gather tokens across devices (measured: 258 s of
    collectives on qwen3 prefill_32k). Folding the data axis into a
    leading vmapped group dim makes every argsort/scatter LOCAL: the
    (G, E, C, d) expert buffers are 2D-sharded (G over data, E over
    model) and align with the expert-sharded weights, so the expert
    matmuls need no extra communication at all."""
    from .layers import current_ctx

    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return 1
    shape = dict(ctx.mesh.shape)
    g = shape.get("pod", 1) * shape.get("data", 1)
    return g if (g > 1 and T % g == 0) else 1


def _dispatch_scatter(cfg: ModelConfig, p, x_flat, top_p, top_idx, C_unused):
    T, d = x_flat.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    G = _n_groups(T)
    Tl = T // G
    C = _capacity(cfg, Tl)

    def group_dispatch(x_g, top_p_g, top_idx_g):
        """Everything token-local within one data shard."""
        flat_expert = top_idx_g.reshape(-1)                   # (Tl*K,)
        order = jnp.argsort(flat_expert)
        sorted_expert = flat_expert[order]
        sorted_token = (jnp.arange(Tl * K) // K)[order]
        sorted_prob = top_p_g.reshape(-1)[order]
        starts = jnp.searchsorted(sorted_expert, jnp.arange(E), side="left")
        rank = jnp.arange(Tl * K) - starts[sorted_expert]
        keep = rank < C
        dst = jnp.where(keep, sorted_expert * C + rank, E * C)   # overflow row
        buf = jnp.zeros((E * C + 1, d), x_g.dtype)
        buf = buf.at[dst].set(x_g[sorted_token])
        return buf[: E * C].reshape(E, C, d), (dst, sorted_token, sorted_prob, keep)

    xg = x_flat.reshape(G, Tl, d)
    xe, residue = jax.vmap(group_dispatch)(
        xg, top_p.reshape(G, Tl, K), top_idx.reshape(G, Tl, K)
    )                                                          # (G, E, C, d)
    xe = shard(xe, "batch", "experts", None, "embed")

    gate = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    ye = shard(ye, "batch", "experts", None, "embed")

    def group_combine(ye_g, res):
        dst, sorted_token, sorted_prob, keep = res
        flat = jnp.concatenate([ye_g.reshape(E * C, d), jnp.zeros((1, d), ye_g.dtype)], 0)
        contrib = flat[dst] * (sorted_prob * keep)[:, None].astype(ye_g.dtype)
        return jnp.zeros((Tl, d), ye_g.dtype).at[sorted_token].add(contrib)

    y = jax.vmap(group_combine)(ye, residue)
    return y.reshape(T, d)


def _dispatch_onehot(cfg: ModelConfig, p, x_flat, top_p, top_idx, C):
    T, d = x_flat.shape
    E, K = cfg.n_experts, cfg.experts_per_token

    # (T, K, E) expert one-hot; position within expert via cumsum over tokens
    oh = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)            # (T, K, E)
    flat_oh = oh.reshape(T * K, E)
    pos = (jnp.cumsum(flat_oh, axis=0) - flat_oh) * flat_oh       # rank per assignment
    pos = pos.sum(-1).reshape(T, K).astype(jnp.int32)             # (T, K)
    keep = pos < C
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
    dispatch = jnp.einsum("tke,tkc->tec", oh, pos_oh)             # (T, E, C)
    combine = jnp.einsum("tk,tke,tkc->tec", top_p, oh, pos_oh)

    xe = jnp.einsum("tec,td->ecd", dispatch.astype(x_flat.dtype), x_flat)
    ye = _expert_mlp(cfg, p, xe)
    y = jnp.einsum("tec,ecd->td", combine.astype(ye.dtype), ye)
    return y


def moe_block(cfg: ModelConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, S, d) -> (y, aux_losses)."""
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    top_p, top_idx, aux = _router(cfg, p, x_flat)
    C = _capacity(cfg, B * S)
    if cfg.moe_dispatch == "onehot":
        y = _dispatch_onehot(cfg, p, x_flat, top_p, top_idx, C)
    else:
        y = _dispatch_scatter(cfg, p, x_flat, top_p, top_idx, C)
    y = y.reshape(B, S, d)
    return shard(y, "batch", "seq", "embed"), aux
