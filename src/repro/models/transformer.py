"""Dense decoder-only transformer LM (gemma / llama / yi / phi4 / VLM-LM).

Pre-norm blocks, GQA attention with RoPE, SwiGLU/GeGLU MLPs, optional
tied embeddings. Layers are scanned (``cfg.scan_layers``) with a
configurable remat policy; all activations carry logical-axis sharding
annotations so the same code lowers on 1 CPU device and on the 512-chip
production mesh. MoE models reuse this file with the FFN swapped for
``moe.moe_block`` (see moe.py).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import (
    ParamDef,
    attention_block,
    attn_defs,
    cross_entropy,
    embed_tokens,
    mlp_block,
    mlp_defs,
    rms_norm,
    layer_norm,
    shard,
    stack_defs,
    unembed,
)
from . import moe as moe_mod
from .kvcache import (
    attn_cache_defs,
    decode_attention_step,
    update_cache,
)


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------


def norm_def(cfg: ModelConfig, d: Optional[int] = None) -> Dict[str, ParamDef]:
    d = d or cfg.d_model
    if cfg.norm_type == "layernorm":
        return {
            "w": ParamDef((d,), (None,), init="ones"),
            "b": ParamDef((d,), (None,), init="zeros"),
        }
    init = "zeros" if cfg.norm_offset else "ones"
    return {"w": ParamDef((d,), (None,), init=init)}


def apply_norm(cfg: ModelConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p["w"], p["b"], eps=cfg.norm_eps)
    return rms_norm(x, p["w"], eps=cfg.norm_eps, offset=cfg.norm_offset)


def layer_defs(cfg: ModelConfig) -> Dict[str, Any]:
    ffn = (
        moe_mod.moe_defs(cfg) if cfg.family == "moe" else mlp_defs(cfg)
    )
    return {
        "ln1": norm_def(cfg),
        "attn": attn_defs(cfg),
        "ln2": norm_def(cfg),
        "ffn": ffn,
    }


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    defs: Dict[str, Any] = {
        "embed": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed_w")),
        "final_norm": norm_def(cfg),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed_w"))
    if cfg.scan_layers:
        defs["layers"] = stack_defs(layer_defs(cfg), cfg.n_layers)
    else:
        defs["layers"] = [layer_defs(cfg) for _ in range(cfg.n_layers)]
    if cfg.family == "vlm":
        # stub vision frontend: a single projection of precomputed patch embeds
        defs["vision_proj"] = ParamDef((cfg.d_model, cfg.d_model), ("embed_w", None))
    return defs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _block(cfg: ModelConfig, p: Dict[str, Any], x: jnp.ndarray, positions: jnp.ndarray,
           aux: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    # under SP (cfg.seq_shard_norm) the residual stream stays sequence-
    # sharded between blocks: norms/mlp/projections run on 1/model_axis
    # of the tokens; only attention gathers the full sequence.
    x = shard(x, "batch", "seq_sp", "embed")
    h = attention_block(cfg, p["attn"], apply_norm(cfg, p["ln1"], x), positions)
    x = x + h
    y = apply_norm(cfg, p["ln2"], x)
    if cfg.family == "moe":
        f, moe_aux = moe_mod.moe_block(cfg, p["ffn"], y)
        aux = {k: aux.get(k, 0.0) + v for k, v in moe_aux.items()}
    else:
        f = mlp_block(cfg, p["ffn"], y)
    return x + f, aux


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    return jax.checkpoint(
        fn, prevent_cse=False,
        policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    )


def backbone(cfg: ModelConfig, params: Dict[str, Any], x: jnp.ndarray,
             positions: jnp.ndarray) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Run the decoder stack on embedded inputs x (B, S, D)."""
    aux0 = {"moe_load_loss": jnp.zeros((), jnp.float32),
            "moe_z_loss": jnp.zeros((), jnp.float32)} if cfg.family == "moe" else {}

    if cfg.scan_layers:
        def body(carry, layer_params):
            x, aux = carry
            x, aux = _block(cfg, layer_params, x, positions, aux)
            return (x, aux), None

        body = _remat(cfg, body)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"])
    else:
        aux = aux0
        blk = _remat(cfg, functools.partial(_block, cfg))
        for lp in params["layers"]:
            x, aux = blk(lp, x, positions, aux)
    x = apply_norm(cfg, params["final_norm"], x)
    return x, aux


def embed_inputs(cfg: ModelConfig, params: Dict[str, Any], batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token (+ stub-modality) embedding; returns (x, positions)."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, scale_by_dim=cfg.embed_scale)
    if cfg.family == "vlm" and "patches" in batch:
        patches = jnp.einsum("bpd,de->bpe", batch["patches"].astype(x.dtype), params["vision_proj"])
        x = jnp.concatenate([patches, x], axis=1)
        x = shard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
    return x, positions


def forward(cfg: ModelConfig, params: Dict[str, Any], batch: Dict[str, jnp.ndarray],
            *, last_only: bool = False) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence forward. Returns (logits, aux). ``last_only`` computes
    logits for the final position only (prefill memory optimization)."""
    x, positions = embed_inputs(cfg, params, batch)
    x, aux = backbone(cfg, params, x, positions)
    if cfg.family == "vlm" and "patches" in batch:
        x = x[:, batch["patches"].shape[1]:]          # loss on text positions only
    if last_only:
        x = x[:, -1:]
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(x, table, valid=cfg.vocab_size)
    return logits, aux


def loss_fn(cfg: ModelConfig, params: Dict[str, Any], batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(cfg, params, batch)
    loss = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    metrics = {"ce_loss": loss}
    if cfg.family == "moe":
        lb = aux["moe_load_loss"] / cfg.n_layers
        zl = aux["moe_z_loss"] / cfg.n_layers
        loss = loss + cfg.router_aux_coef * lb + 1e-3 * zl
        metrics.update(moe_load_loss=lb, moe_z_loss=zl)
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode (KV cache)
# ---------------------------------------------------------------------------


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    per_layer = attn_cache_defs(cfg, batch, max_len)
    if cfg.scan_layers:
        return {"layers": stack_defs(per_layer, cfg.n_layers)}
    return {"layers": [per_layer for _ in range(cfg.n_layers)]}


def _decode_block(cfg: ModelConfig, p: Dict[str, Any], cache_l: Dict[str, jnp.ndarray],
                  x: jnp.ndarray, lengths: jnp.ndarray) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One layer of single-token decode. x: (B, 1, D)."""
    y = apply_norm(cfg, p["ln1"], x)
    attn_out, cache_l = decode_attention_step(cfg, p["attn"], cache_l, y, lengths)
    x = x + attn_out
    y = apply_norm(cfg, p["ln2"], x)
    if cfg.family == "moe":
        f, _ = moe_mod.moe_block(cfg, p["ffn"], y)
    else:
        f = mlp_block(cfg, p["ffn"], y)
    return x + f, cache_l


def prefill(cfg: ModelConfig, params: Dict[str, Any], cache: Dict[str, Any],
            batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict[str, Any], jnp.ndarray]:
    """Run the prompt through the stack while filling the KV cache.

    Returns (last-position logits (B,1,V), cache, lengths (B,)). The cache
    must be fresh (slots [0, P) are written); RoPE positions start at 0.
    For VLM, stub patch embeddings are part of the prompt.
    """
    from .layers import apply_qkv, rope as rope_fn
    from ..kernels import flash_attention

    x, positions = embed_inputs(cfg, params, batch)
    P = x.shape[1]

    def blk(x, lp, cl):
        y = apply_norm(cfg, lp["ln1"], x)
        q, k, v = apply_qkv(lp["attn"], y)
        q = rope_fn(q, positions, cfg.rope_theta)
        k = rope_fn(k, positions, cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice(cl["k"], k.swapaxes(1, 2), (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cl["v"], v.swapaxes(1, 2), (0, 0, 0, 0))
        att = flash_attention(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
                              causal=True).swapaxes(1, 2)
        att = jnp.einsum("bshk,hkd->bsd", att, lp["attn"]["wo"])
        x = x + shard(att, "batch", "seq", "embed")
        y = apply_norm(cfg, lp["ln2"], x)
        if cfg.family == "moe":
            f, _ = moe_mod.moe_block(cfg, lp["ffn"], y)
        else:
            f = mlp_block(cfg, lp["ffn"], y)
        return x + f, {"k": ck, "v": cv}

    if cfg.scan_layers:
        def body(x, scanned):
            lp, cl = scanned
            return blk(x, lp, cl)

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = {"layers": new_layers}
    else:
        new_layers = []
        for lp, cl in zip(params["layers"], cache["layers"]):
            x, cl = blk(x, lp, cl)
            new_layers.append(cl)
        cache = {"layers": new_layers}
    x = apply_norm(cfg, params["final_norm"], x[:, -1:])
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(x, table, valid=cfg.vocab_size)
    lengths = jnp.full((x.shape[0],), P, jnp.int32)
    return logits, cache, lengths


def decode_step(cfg: ModelConfig, params: Dict[str, Any], cache: Dict[str, Any],
                tokens: jnp.ndarray, lengths: jnp.ndarray) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """tokens: (B, 1) int32; lengths: (B,) current cache fill. Returns
    (logits (B, 1, V), updated cache)."""
    x = embed_tokens(params["embed"], tokens, scale_by_dim=cfg.embed_scale)

    if cfg.scan_layers:
        def body(x, scanned):
            lp, cl = scanned
            x, cl = _decode_block(cfg, lp, cl, x, lengths)
            return x, cl

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = {"layers": new_layers}
    else:
        new_layers = []
        for lp, cl in zip(params["layers"], cache["layers"]):
            x, cl = _decode_block(cfg, lp, cl, x, lengths)
            new_layers.append(cl)
        cache = {"layers": new_layers}
    x = apply_norm(cfg, params["final_norm"], x)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(x, table, valid=cfg.vocab_size)
    return logits, cache
