"""Model substrate: the 10 assigned architectures in pure JAX."""

from .model_api import Model, build_model
from .layers import mesh_context, set_mesh, clear_mesh, shard, resolve_pspec, axis_rules

__all__ = ["Model", "build_model", "mesh_context", "set_mesh", "clear_mesh",
           "shard", "resolve_pspec", "axis_rules"]
