"""The Task Server: stewards execution of tasks requested by the Thinker.

Reproduces Colmena's Task Server abstraction — it pulls task requests
from the queues, routes them to an execution backend, and pushes
completed ``Result`` objects back — and layers on the reliability
machinery a 1000+-node deployment needs:

  * **pluggable executors**: named ``WorkerPool``s (the paper's
    multi-resource deployments — e.g. a "sim" pool for simulation tasks
    and an "ml" pool on accelerator nodes — selected per-task through
    ``ResourceRequest.pool``);
  * **retries with backoff** for tasks lost to node failures;
  * **heartbeat monitoring** that detects dead/silent workers, fails over
    their in-flight tasks, and replaces the 'node' (elastic recovery);
  * **straggler mitigation**: speculative re-execution of tasks running
    far beyond the historical duration for their method — first finisher
    wins, the copy is dropped;
  * **batched dispatch** (``BatchPolicy``): small same-method tasks are
    coalesced inside a linger window into a single worker round-trip,
    with results split back into individual ``Result``s carrying correct
    per-task timing;
  * **timeouts** per task.

The server runs as a thread by default (1 process on this container) but
the same class runs under ``multiprocessing`` with ``PipeColmenaQueues``
— the deployment shape in the paper.
"""

from __future__ import annotations

import heapq
import itertools
import logging
import statistics
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from .executors import FailureInjector, PoolSpec, WorkerPool
from .queues import ColmenaQueues, ControlAck, ControlRequest, KillSignal
from .result import FailureKind, ResourceRequest, Result

logger = logging.getLogger("repro.task_server")


@dataclass
class RetryPolicy:
    max_retries: int = 2
    backoff_s: float = 0.0          # base backoff (doubles per retry)
    retry_on: tuple = (FailureKind.WORKER_DIED, FailureKind.TIMEOUT)


@dataclass
class StragglerPolicy:
    enabled: bool = True
    # speculate when runtime > factor * median(method history)
    factor: float = 3.0
    min_history: int = 5
    check_interval_s: float = 0.25


@dataclass
class BatchPolicy:
    """Batched dispatch: coalesce small same-method tasks into a single
    worker round-trip (the data-fabric optimization for dispatch-bound
    workloads). ``linger_s`` bounds how long a partial batch waits for
    company; ``methods=None`` batches every method."""

    max_batch: int = 8
    linger_s: float = 0.002
    methods: Optional[tuple] = None

    def eligible(self, method: str) -> bool:
        return self.methods is None or method in self.methods


@dataclass
class ServerMetrics:
    tasks_received: int = 0
    tasks_completed: int = 0
    tasks_failed: int = 0
    tasks_retried: int = 0
    speculative_launched: int = 0
    speculative_wins: int = 0
    workers_replaced: int = 0


@dataclass
class _InFlight:
    result: Result
    started: float
    pool: str
    speculated: bool = False
    done: bool = False


class TaskServer:
    """Dispatch loop + reliability machinery over one or more WorkerPools."""

    def __init__(
        self,
        queues: ColmenaQueues,
        methods: Dict[str, Callable],
        pools: Optional[Dict[str, WorkerPool]] = None,
        pool_specs: Optional[Dict[str, PoolSpec]] = None,
        n_workers: int = 4,
        retry: Optional[RetryPolicy] = None,
        straggler: Optional[StragglerPolicy] = None,
        batching: Optional[BatchPolicy] = None,
        injector: Optional[FailureInjector] = None,
        heartbeat_timeout_s: float = 10.0,
        replace_dead_workers: bool = True,
        event_log: Optional[object] = None,  # repro.observe.EventLog (duck-typed)
        method_resources: Optional[Dict[str, "ResourceRequest"]] = None,
    ) -> None:
        self.queues = queues
        self.methods = dict(methods)
        # Per-method resource defaults (the repro.app task registry):
        # requests that left pool/timeout unset inherit the method's.
        self.method_resources = dict(method_resources or {})
        # ``pool_specs`` is the declarative form: picklable, so a server
        # spawned in its own process rebuilds the full named-pool dict on
        # its side of the boundary (live WorkerPool objects cannot cross).
        # Live ``pools`` win when both are given.
        if pools is None and pool_specs:
            pools = {name: spec.build(injector=injector) for name, spec in pool_specs.items()}
        self.pools = pools or {"default": WorkerPool("default", n_workers, injector=injector)}
        # Kept for clamping remote resize requests to the spec's band.
        self.pool_specs = dict(pool_specs or {})
        # Telemetry: default to the queues' log so one wiring point covers
        # the whole lifecycle; pools without their own log inherit it.
        self.event_log = event_log if event_log is not None else getattr(queues, "event_log", None)
        for pool in self.pools.values():
            if getattr(pool, "event_log", None) is None:
                pool.event_log = self.event_log
        self.retry = retry or RetryPolicy()
        self.straggler = straggler or StragglerPolicy()
        self.batching = batching
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.replace_dead_workers = replace_dead_workers
        self.metrics = ServerMetrics()

        self._inflight: Dict[str, _InFlight] = {}
        self._inflight_lock = threading.Lock()
        self._history: Dict[str, List[float]] = {}
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        # Deferred retries: a deadline heap drained by a dedicated timer
        # thread. The completion path (_complete runs on worker and
        # monitor threads) must never sleep out a backoff — one retrying
        # task would stall every other completion and the heartbeat
        # failover sweep for the backoff duration.
        self._retry_heap: List[Tuple[float, int, Result]] = []
        self._retry_cond = threading.Condition()
        self._retry_seq = itertools.count()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "TaskServer":
        # The control channel: resize/ping requests arriving over the
        # request queue are serviced by this server (see handle_control).
        # Installed here — in the server's own process for spawned sites —
        # because bound methods don't survive the queue pickle boundary.
        self.queues.control_handler = self.handle_control
        main = threading.Thread(target=self._dispatch_loop, daemon=True, name="task-server")
        main.start()
        self._threads.append(main)
        mon = threading.Thread(target=self._monitor_loop, daemon=True, name="task-server-monitor")
        mon.start()
        self._threads.append(mon)
        retry = threading.Thread(target=self._retry_loop, daemon=True, name="task-server-retry")
        retry.start()
        self._threads.append(retry)
        return self

    def run(self) -> None:
        """Blocking variant (for running inside a dedicated process)."""
        self.start()
        self.join()

    def join(self, timeout: Optional[float] = None) -> None:
        self._threads[0].join(timeout=timeout)

    def stop(self) -> None:
        self._stop.set()
        with self._retry_cond:
            self._retry_cond.notify_all()
        for p in self.pools.values():
            p.shutdown()

    # ------------------------------------------------------- control channel
    def handle_control(self, req: ControlRequest) -> None:
        """Service an out-of-band ``ControlRequest`` (cross-process
        elasticity): ``resize`` retargets a pool within its spec band and
        emits ``pool_resize`` into *this* process's event log; ``ping``
        reports fleet state. Every request is acked on the control topic
        so the parent side can block on the round-trip."""
        ok, detail = True, {}
        try:
            if req.kind == "resize":
                pool = self.pools.get(req.pool)
                if pool is None:
                    raise KeyError(f"unknown pool {req.pool!r}")
                target = int(req.params["target"])
                spec = self.pool_specs.get(req.pool)
                if spec is not None:
                    target = spec.clamp(target)
                old, new = pool.resize(target)
                detail = {"old": old, "new": new}
                if self.event_log is not None and new != old:
                    self.event_log.pool_resize(
                        req.pool, old, new,
                        reason=req.params.get("reason", "control"),
                    )
                    self.event_log.gauge("workers", new, pool=req.pool)
            elif req.kind == "ping":
                detail = {
                    "pools": {n: p.n_workers for n, p in self.pools.items()},
                    "queued": {n: p.queued() for n, p in self.pools.items()},
                }
            else:
                raise ValueError(f"unknown control kind {req.kind!r}")
        except Exception as exc:  # noqa: BLE001 - failure travels in the ack
            ok, detail = False, {"error": f"{type(exc).__name__}: {exc}"}
        self.queues.send_control_ack(ControlAck(
            request_id=req.request_id, kind=req.kind, pool=req.pool,
            ok=ok, detail=detail,
        ))

    # -------------------------------------------------------------- dispatch
    def _dispatch_loop(self) -> None:
        bp = self.batching
        while not self._stop.is_set():
            try:
                if bp is None:
                    task = self.queues.get_task(timeout=0.05)
                    tasks = [task] if task is not None else []
                else:
                    tasks = self.queues.get_task_batch(
                        bp.max_batch, timeout=0.05, linger_s=bp.linger_s
                    )
            except KillSignal:
                logger.info("kill signal received; stopping task server")
                self.stop()
                return
            if not tasks:
                continue
            self.metrics.tasks_received += len(tasks)
            for task in tasks:
                self._apply_method_resources(task)
            if bp is None:
                self._dispatch(tasks[0])
                continue
            # Coalesce same-(method, pool) runs; ineligible methods fall
            # through to the plain path. Singleton groups still go through
            # _dispatch_batch so occupancy gauges cover every dispatch.
            groups: Dict[tuple, List[Result]] = {}
            for task in tasks:
                if bp.eligible(task.method):
                    groups.setdefault((task.method, task.resources.pool), []).append(task)
                else:
                    self._dispatch(task)
            for group in groups.values():
                self._dispatch_batch(group)

    def _apply_method_resources(self, task: Result) -> None:
        """Fill unset resource fields from the method's registered default
        (``repro.app``'s ``@task(pool=..., timeout_s=...)``). A request
        naming any non-default pool (or any timeout) wins; ``pool=
        "default"`` is indistinguishable from unset and inherits the
        registry's pool — register a method under ``pool="default"`` if
        its tasks must be routable there."""
        default = self.method_resources.get(task.method)
        if default is None:
            return
        r = task.resources
        if r.pool == "default" and default.pool != "default":
            r.pool = default.pool
        if r.timeout_s is None and default.timeout_s is not None:
            r.timeout_s = default.timeout_s

    def _dispatch_batch(self, batch: List[Result]) -> None:
        """One worker round-trip for several same-method tasks."""
        fn = self.methods.get(batch[0].method)
        if fn is None:
            for task in batch:
                self._dispatch(task)  # fails each cleanly
            return
        pool_name = batch[0].resources.pool if batch[0].resources.pool in self.pools else "default"
        pool = self.pools[pool_name]
        with self._inflight_lock:
            now = time.monotonic()
            for task in batch:
                if task.task_id not in self._inflight:
                    self._inflight[task.task_id] = _InFlight(result=task, started=now, pool=pool_name)
        if self.event_log is not None:
            self.event_log.gauge(
                "batch_occupancy", len(batch), pool=pool_name, method=batch[0].method
            )
        pool.submit_batch(batch, fn, self._on_done)

    def _dispatch(self, task: Result) -> None:
        fn = self.methods.get(task.method)
        if fn is None:
            task.set_failure(FailureKind.EXCEPTION, f"unknown method {task.method!r}")
            if self.event_log is not None:
                self.event_log.task_event("failed", task, kind="unknown_method")
            self.queues.send_result(task)
            self.metrics.tasks_failed += 1
            return
        pool_name = task.resources.pool if task.resources.pool in self.pools else "default"
        pool = self.pools[pool_name]
        with self._inflight_lock:
            # Speculative copies share a task_id with the original.
            if task.task_id not in self._inflight:
                self._inflight[task.task_id] = _InFlight(result=task, started=time.monotonic(), pool=pool_name)
        pool.submit(task, fn, self._on_done)

    # ------------------------------------------------------------ completion
    def _on_done(self, result: Result) -> None:
        with self._inflight_lock:
            entry = self._inflight.get(result.task_id)
            if entry is None:
                # Every live task has an in-flight entry (registered at
                # dispatch). No entry means this copy lost a race: a
                # speculative loser, or a zombie worker's late result
                # after the monitor failed the task over. Exactly one
                # copy per task reaches the client — drop the rest.
                logger.info("dropping late copy of %s", result.task_id)
                return
            entry.done = True
            del self._inflight[result.task_id]
            if result.speculative:
                self.metrics.speculative_wins += 1
        self._complete(result)

    def _complete(self, result: Result) -> None:
        """Route a finished task: record success, or retry/fail it."""
        if result.success:
            dur = (result.time.compute_ended or 0) - (result.time.compute_started or 0)
            self._history.setdefault(result.method, []).append(dur)
            self.metrics.tasks_completed += 1
            self.queues.send_result(result)
            return

        # Failure path: maybe retry.
        if (
            result.failure in self.retry.retry_on
            and result.retries < self.retry.max_retries
        ):
            self.metrics.tasks_retried += 1
            backoff = self.retry.backoff_s * (2 ** result.retries)
            retry = result.clone_for_retry()
            retry.mark("created")
            if self.event_log is not None:
                self.event_log.task_event(
                    "retried", retry, origin=result.task_id, attempt=retry.retries,
                    after=result.failure.value,
                )
            logger.info("retrying %s (attempt %d) after %s", result.task_id, retry.retries, result.failure)
            if backoff:
                self._schedule_retry(retry, time.monotonic() + backoff)
            else:
                self._dispatch(retry)
            return

        self.metrics.tasks_failed += 1
        self.queues.send_result(result)

    # --------------------------------------------------------------- retries
    def _schedule_retry(self, retry: Result, due: float) -> None:
        with self._retry_cond:
            heapq.heappush(self._retry_heap, (due, next(self._retry_seq), retry))
            self._retry_cond.notify()

    def pending_retries(self) -> int:
        with self._retry_cond:
            return len(self._retry_heap)

    def _retry_loop(self) -> None:
        """Dispatch deferred retries as their backoff deadlines pass. N
        concurrently-failing tasks back off in parallel: the heap holds
        them all and each dispatches at its own deadline."""
        while not self._stop.is_set():
            with self._retry_cond:
                # Re-check under the lock: stop() sets _stop before taking
                # the condition, so seeing it unset here guarantees the
                # coming notify_all cannot be missed by this wait.
                if self._stop.is_set():
                    return
                if not self._retry_heap:
                    self._retry_cond.wait()
                    continue
                due = self._retry_heap[0][0]
                now = time.monotonic()
                if due > now:
                    self._retry_cond.wait(due - now)
                    continue
                _, _, retry = heapq.heappop(self._retry_heap)
            self._dispatch(retry)

    # -------------------------------------------------------------- monitors
    def _monitor_loop(self) -> None:
        # _stop.wait, not time.sleep: stop() must return promptly, not
        # lag a full check interval behind the shutdown request.
        while not self._stop.wait(self.straggler.check_interval_s):
            self._check_heartbeats()
            self._check_timeouts()
            if self.straggler.enabled:
                self._check_stragglers()

    def _check_timeouts(self) -> None:
        """Enforce ``ResourceRequest.timeout_s``: a task running past its
        wall-time limit is failed with TIMEOUT (and retried per policy)
        even though its worker thread is still alive — the recovery path
        for hung task functions."""
        now = time.monotonic()
        with self._inflight_lock:
            expired = [
                tid for tid, e in self._inflight.items()
                if e.result.resources.timeout_s is not None
                and not e.done
                and e.result.time.compute_started is not None
                and now - e.result.time.compute_started > e.result.resources.timeout_s
            ]
        for tid in expired:
            with self._inflight_lock:
                entry = self._inflight.pop(tid, None)
            if entry is None or entry.done:
                continue
            failed = entry.result
            failed.set_failure(
                FailureKind.TIMEOUT,
                f"exceeded wall-time limit {failed.resources.timeout_s}s",
            )
            failed.mark("compute_ended")
            if self.event_log is not None:
                self.event_log.task_event(
                    "failed", failed, pool=entry.pool, kind="timeout",
                )
            logger.info("task %s timed out after %.2fs", tid, now - entry.started)
            self._complete(failed)

    def _check_heartbeats(self) -> None:
        for name, pool in self.pools.items():
            for w in pool.dead_workers(self.heartbeat_timeout_s):
                # Fail over everything the worker was holding: the task it
                # was executing plus the not-yet-started rest of its batch.
                pending = list(w.current_batch)
                if w.current_task and w.current_task not in pending:
                    pending.append(w.current_task)
                for tid in pending:
                    # Popping the entry claims the task: the zombie worker
                    # thread may still finish it, but its late copy finds
                    # no entry in _on_done and is dropped, not re-sent.
                    with self._inflight_lock:
                        entry = self._inflight.pop(tid, None)
                    if entry is not None and not entry.done:
                        failed = entry.result
                        failed.set_failure(
                            FailureKind.WORKER_DIED,
                            f"worker {w.worker_id} heartbeat lost",
                        )
                        failed.mark("compute_ended")
                        if self.event_log is not None:
                            self.event_log.task_event(
                                "failed", failed, pool=entry.pool,
                                worker_id=w.worker_id, kind="heartbeat_lost",
                            )
                        self._complete(failed)
                w.current_task = None
                w.current_batch = []
                if self.replace_dead_workers and not w.alive:
                    with pool._lock:
                        still_there = w.worker_id in pool._workers
                        if still_there:
                            del pool._workers[w.worker_id]
                    if still_there:
                        pool.add_workers(1)
                        self.metrics.workers_replaced += 1
                        logger.info("replaced dead worker %d in pool %s", w.worker_id, name)

    def _check_stragglers(self) -> None:
        now = time.monotonic()
        with self._inflight_lock:
            entries = list(self._inflight.values())
        for entry in entries:
            if entry.done or entry.speculated or not entry.result.resources.speculative_ok:
                continue
            hist = self._history.get(entry.result.method, [])
            if len(hist) < self.straggler.min_history:
                continue
            median = statistics.median(hist[-50:])
            if median <= 0:
                continue
            if now - entry.started > self.straggler.factor * median:
                pool = self.pools[entry.pool]
                if pool.queued() > 0:
                    continue  # no spare capacity; don't pile on
                entry.speculated = True
                copy = entry.result.clone_for_speculation()
                copy.mark("created")
                if self.event_log is not None:
                    self.event_log.task_event("speculated", copy, pool=entry.pool)
                self.metrics.speculative_launched += 1
                logger.info(
                    "straggler: %s running %.2fs > %.1fx median %.2fs; speculating",
                    entry.result.task_id, now - entry.started, self.straggler.factor, median,
                )
                fn = self.methods[copy.method]
                pool.submit(copy, fn, self._on_done)


def serve_forever(
    queues: ColmenaQueues,
    methods: Dict[str, Callable],
    jsonl_path: Optional[str] = None,
    log_capacity: int = 1 << 16,
    **kwargs,
) -> None:
    """Entry point for running a TaskServer in a separate process.

    ``ColmenaQueues`` drop their event log when pickled (it is
    per-process), so without ``jsonl_path`` a spawned server is blind:
    ``picked_up``/``dispatched``/``running``/``completed`` never get
    recorded anywhere. With it, the child opens its own JSONL
    ``EventLog`` and attaches it to the queues/server/pools; since
    ``time.monotonic`` is CLOCK_MONOTONIC (system-wide on Linux), the
    child log merges with the parent's by timestamp into one causal
    trace (``repro.observe.trace.merge_jsonl``).
    """
    event_log = None
    if jsonl_path is not None:
        from repro.observe import EventLog  # deferred: core never imports observe at module scope

        event_log = EventLog(capacity=log_capacity, jsonl_path=jsonl_path)
        queues.event_log = event_log
    try:
        TaskServer(queues, methods, event_log=event_log, **kwargs).run()
    finally:
        if event_log is not None:
            event_log.close()
