"""Colmena-on-JAX core: AI-steered workflow orchestration.

The paper's primary contribution, adapted to a TPU/JAX runtime (see
DESIGN.md): Thinker agents steer campaigns of jitted computations through
Task Queues and a Task Server, with a ProxyStore-style data fabric
keeping bulk tensors off the control path.

These are the low-level constructors; applications normally compose the
stack declaratively through ``repro.app`` (``AppSpec``/``ColmenaApp`` in
``repro.core.app``), which wires queues + server + fabric + observe +
steering + campaign from one spec and owns the lifecycle.
"""

from .executors import (
    FailureInjector,
    PoolSpec,
    WarmCache,
    WarmCacheStats,
    WorkerDied,
    WorkerPool,
    normalize_pools,
    resolve_warm,
    stateful_task,
)
from .proxystore import (
    Connector,
    FileConnector,
    InMemoryConnector,
    Proxy,
    SharedMemoryConnector,
    Store,
    apply_threshold,
    get_store,
    iter_proxies,
    prefetch_all,
    resolve_all,
)
from .queues import (
    ColmenaQueues,
    CompletionNotice,
    KillSignal,
    LocalColmenaQueues,
    PipeColmenaQueues,
)
from .result import FailureKind, ResourceRequest, Result, TimingInfo, Timestamps, TraceContext
from .task_server import (
    BatchPolicy,
    RetryPolicy,
    ServerMetrics,
    StragglerPolicy,
    TaskServer,
    serve_forever,
)
from .thinker import (
    BaseThinker,
    ResourceCounter,
    WakeEvent,
    agent,
    event_responder,
    result_processor,
    task_submitter,
    wait_event,
)
from .steering import BatchRetrainThinker, ConstantInflightThinker, PriorityQueueThinker
from .campaign import Campaign, CampaignReport

__all__ = [
    "agent",
    "apply_threshold",
    "BaseThinker",
    "BatchPolicy",
    "BatchRetrainThinker",
    "Campaign",
    "CampaignReport",
    "ColmenaQueues",
    "CompletionNotice",
    "Connector",
    "ConstantInflightThinker",
    "event_responder",
    "FailureInjector",
    "FailureKind",
    "FileConnector",
    "get_store",
    "InMemoryConnector",
    "iter_proxies",
    "KillSignal",
    "LocalColmenaQueues",
    "normalize_pools",
    "PipeColmenaQueues",
    "PoolSpec",
    "prefetch_all",
    "PriorityQueueThinker",
    "Proxy",
    "resolve_all",
    "resolve_warm",
    "ResourceCounter",
    "ResourceRequest",
    "Result",
    "result_processor",
    "RetryPolicy",
    "serve_forever",
    "ServerMetrics",
    "SharedMemoryConnector",
    "stateful_task",
    "Store",
    "StragglerPolicy",
    "WarmCache",
    "WarmCacheStats",
    "task_submitter",
    "TaskServer",
    "WakeEvent",
    "wait_event",
    "TimingInfo",
    "Timestamps",
    "TraceContext",
    "WorkerDied",
    "WorkerPool",
]
