"""Colmena-on-JAX core: AI-steered workflow orchestration.

The paper's primary contribution, adapted to a TPU/JAX runtime (see
DESIGN.md): Thinker agents steer campaigns of jitted computations through
Task Queues and a Task Server, with a ProxyStore-style data fabric
keeping bulk tensors off the control path.
"""

from .executors import FailureInjector, WorkerDied, WorkerPool, stateful_task
from .proxystore import (
    Connector,
    FileConnector,
    InMemoryConnector,
    Proxy,
    Store,
    apply_threshold,
    get_store,
    prefetch_all,
    resolve_all,
)
from .queues import (
    ColmenaQueues,
    CompletionNotice,
    KillSignal,
    LocalColmenaQueues,
    PipeColmenaQueues,
)
from .result import FailureKind, ResourceRequest, Result, TimingInfo, Timestamps
from .task_server import RetryPolicy, ServerMetrics, StragglerPolicy, TaskServer, serve_forever
from .thinker import (
    BaseThinker,
    ResourceCounter,
    agent,
    event_responder,
    result_processor,
    task_submitter,
)
from .steering import BatchRetrainThinker, ConstantInflightThinker, PriorityQueueThinker
from .campaign import Campaign, CampaignReport

__all__ = [
    "agent",
    "apply_threshold",
    "BaseThinker",
    "BatchRetrainThinker",
    "Campaign",
    "CampaignReport",
    "ColmenaQueues",
    "CompletionNotice",
    "Connector",
    "ConstantInflightThinker",
    "event_responder",
    "FailureInjector",
    "FailureKind",
    "FileConnector",
    "get_store",
    "InMemoryConnector",
    "KillSignal",
    "LocalColmenaQueues",
    "PipeColmenaQueues",
    "prefetch_all",
    "PriorityQueueThinker",
    "Proxy",
    "resolve_all",
    "ResourceCounter",
    "ResourceRequest",
    "Result",
    "result_processor",
    "RetryPolicy",
    "serve_forever",
    "ServerMetrics",
    "stateful_task",
    "Store",
    "StragglerPolicy",
    "task_submitter",
    "TaskServer",
    "TimingInfo",
    "Timestamps",
    "WorkerDied",
    "WorkerPool",
]
