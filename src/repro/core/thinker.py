"""The Thinker: Colmena's agent-based steering programming model.

A Thinker is a Python object whose decorated methods run as cooperating
threads ("agents") once ``run()`` is called. The four agent types from
the paper:

  1. ``@agent`` — starts at application start; runs until it returns
     (``startup=True`` marks short-lived initializers). When a *critical*
     agent returns, the whole Thinker begins shutdown (``done`` is set).
  2. ``@result_processor(topic=...)`` — invoked once per completed task on
     a topic, receiving the ``Result``. ``on="completion"`` subscribes to
     the act-on-completion notices instead (react before data arrives).
  3. ``@event_responder(event_name=...)`` — invoked when a named
     ``threading.Event`` on the Thinker is set; can optionally reallocate
     resources between task pools for the duration of the response.
  4. ``@task_submitter(task_type=..., n_slots=...)`` — invoked whenever
     the ``ResourceCounter`` has ``n_slots`` free in the given pool; the
     body is expected to submit work that occupies those slots.

Coordination uses only the standard ``threading`` library (Events,
Conditions), exactly as the paper prescribes — steering logic is meant to
be ms-scale, so the GIL is not a limiter.
"""

from __future__ import annotations

import logging
import threading
import time
from functools import update_wrapper
from typing import Any, Callable, Dict, List, Optional

from .queues import ColmenaQueues
from .result import Result

logger = logging.getLogger("repro.thinker")

# Fallback poll granularity, used only when a waiter is given a plain
# ``threading.Event`` it cannot subscribe to, or when the queues lack the
# wake-sentinel API. Thinker-internal waits use ``WakeEvent`` condition
# wakeups and burn no CPU while idle; result processors block inside
# ``queue.get`` and are woken by per-topic sentinels on ``done.set()``.
_POLL_S = 0.02
_FALLBACK_GETTER_TIMEOUT_S = 0.2


# --------------------------------------------------------------------------
# Wakeups
# --------------------------------------------------------------------------


class WakeEvent(threading.Event):
    """A ``threading.Event`` other waits can subscribe to.

    ``set()`` additionally notifies every watched ``Condition``, so a
    thread blocked on a *different* primitive (e.g. ``ResourceCounter``'s
    condition, a work heap) wakes the moment the event fires instead of
    polling for it. This is what lets idle agents park without a
    poll-granularity timeout.
    """

    def __init__(self) -> None:
        super().__init__()
        self._watch_lock = threading.Lock()
        self._watched: List[threading.Condition] = []
        self._on_set: List[Callable[[], None]] = []

    def watch(self, cond: threading.Condition) -> None:
        """Have ``set()`` notify ``cond``. Call before checking
        ``is_set`` so a concurrent ``set()`` is never missed."""
        with self._watch_lock:
            self._watched.append(cond)

    def unwatch(self, cond: threading.Condition) -> None:
        with self._watch_lock:
            try:
                self._watched.remove(cond)
            except ValueError:
                pass

    def on_set(self, fn: Callable[[], None]) -> None:
        """Run ``fn`` once, on the first ``set()`` (immediately if the
        event is already set). Used to push queue wake sentinels the
        moment a Thinker begins shutdown."""
        with self._watch_lock:
            if not self.is_set():
                self._on_set.append(fn)
                return
        fn()

    def set(self) -> None:  # noqa: A003 - mirrors threading.Event API
        with self._watch_lock:
            first = not self.is_set()
            super().set()
            watched = list(self._watched)
            callbacks = self._on_set if first else []
            if first:
                self._on_set = []
        for cond in watched:
            with cond:
                cond.notify_all()
        for fn in callbacks:
            fn()


def wait_event(ev: threading.Event, done: threading.Event) -> bool:
    """Block until ``ev`` or ``done`` is set; returns ``ev.is_set()``.

    When both are ``WakeEvent``s the wait is a pure condition sleep (no
    CPU while idle); plain ``Event``s fall back to ``_POLL_S`` polling.
    """
    if not (isinstance(ev, WakeEvent) and isinstance(done, WakeEvent)):
        while not done.is_set():
            if ev.wait(timeout=_POLL_S):
                return True
        return ev.is_set()
    cond = threading.Condition()
    ev.watch(cond)
    done.watch(cond)
    try:
        with cond:
            while not ev.is_set() and not done.is_set():
                cond.wait()
    finally:
        ev.unwatch(cond)
        done.unwatch(cond)
    return ev.is_set()


# --------------------------------------------------------------------------
# Resource tracking
# --------------------------------------------------------------------------


class ResourceCounter:
    """Semaphore-style tracker of worker slots split across task pools.

    Reproduces Colmena's resource tracker: agents ``acquire`` slots before
    submitting work, ``release`` when results return, and ``reallocate``
    moves capacity between pools mid-run (e.g., shift nodes from
    simulation to inference when a new model lands — Fig. 2's behaviour).
    """

    def __init__(self, total_slots: int, pools: Optional[List[str]] = None) -> None:
        self._cond = threading.Condition()
        self._pools: Dict[str, int] = {}
        pools = pools or ["default"]
        self._pools = {p: 0 for p in pools}
        self._pools[pools[0]] = total_slots
        # Allocation = slots assigned to a pool (busy + free); only
        # reallocate/grow/shrink move it, acquire/release do not.
        self._alloc: Dict[str, int] = dict(self._pools)
        self._total = total_slots
        self._event_log: Optional[Any] = None

    @property
    def event_log(self) -> Optional[Any]:
        """Optional repro.observe.EventLog (duck-typed; set post-init).
        Allocation changes emit per-pool ``slots`` gauges so reports can
        integrate capacity over time even while slots move mid-run."""
        return self._event_log

    @event_log.setter
    def event_log(self, log: Optional[Any]) -> None:
        self._event_log = log
        # Baseline gauges: without them the capacity integral would only
        # start at the first post-attach allocation change.
        self._emit_allocations()

    @property
    def total_slots(self) -> int:
        return self._total

    def pools(self) -> List[str]:
        with self._cond:
            return list(self._pools)

    def available(self, pool: str = "default") -> int:
        with self._cond:
            return self._pools.get(pool, 0)

    def allocation(self, pool: str = "default") -> int:
        """Slots currently assigned to ``pool`` (busy + idle)."""
        with self._cond:
            return self._alloc.get(pool, 0)

    def allocations(self) -> Dict[str, int]:
        with self._cond:
            return dict(self._alloc)

    def _emit_allocations(self) -> None:
        log = self._event_log
        if log is not None:
            for pool, slots in self.allocations().items():
                log.gauge("slots", slots, pool=pool)

    def add_pool(self, pool: str, slots: int = 0) -> None:
        with self._cond:
            self._pools.setdefault(pool, 0)
            self._pools[pool] += slots
            self._alloc[pool] = self._alloc.get(pool, 0) + slots
            self._total += slots
            self._cond.notify_all()
        self._emit_allocations()

    def grow(self, pool: str, slots: int) -> None:
        """Elastic scale-up: new capacity appears in ``pool``."""
        with self._cond:
            self._pools[pool] = self._pools.get(pool, 0) + slots
            self._alloc[pool] = self._alloc.get(pool, 0) + slots
            self._total += slots
            self._cond.notify_all()
        self._emit_allocations()

    def shrink(self, pool: str, slots: int, timeout: Optional[float] = None) -> bool:
        """Elastic scale-down: remove capacity once it is idle."""
        if not self.acquire(pool, slots, timeout=timeout):
            return False
        with self._cond:
            self._alloc[pool] = self._alloc.get(pool, 0) - slots
            self._total -= slots
        self._emit_allocations()
        return True

    def acquire(
        self,
        pool: str,
        n: int = 1,
        timeout: Optional[float] = None,
        stop_event: Optional[threading.Event] = None,
    ) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        # A WakeEvent stop_event notifies our condition on set(), so the
        # wait needs no poll granularity; a plain Event (that cannot be
        # subscribed to) forces the _POLL_S fallback.
        subscribed = isinstance(stop_event, WakeEvent)
        if subscribed:
            stop_event.watch(self._cond)
        try:
            with self._cond:
                while self._pools.get(pool, 0) < n:
                    if stop_event is not None and stop_event.is_set():
                        return False
                    remaining = None
                    if deadline is not None:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            return False
                    if stop_event is not None and not subscribed:
                        remaining = _POLL_S if remaining is None else min(remaining, _POLL_S)
                    self._cond.wait(remaining)
                self._pools[pool] -= n
                return True
        finally:
            if subscribed:
                stop_event.unwatch(self._cond)

    def release(self, pool: str, n: int = 1) -> None:
        with self._cond:
            self._pools[pool] = self._pools.get(pool, 0) + n
            self._cond.notify_all()

    def reallocate(
        self,
        src: str,
        dst: str,
        n: int = 1,
        timeout: Optional[float] = None,
        stop_event: Optional[threading.Event] = None,
    ) -> bool:
        """Move ``n`` slots from ``src`` to ``dst`` (blocks until idle)."""
        if not self.acquire(src, n, timeout=timeout, stop_event=stop_event):
            return False
        with self._cond:
            self._alloc[src] = self._alloc.get(src, 0) - n
            self._alloc[dst] = self._alloc.get(dst, 0) + n
            self._pools[dst] = self._pools.get(dst, 0) + n
            self._cond.notify_all()
        self._emit_allocations()
        return True


# --------------------------------------------------------------------------
# Agent decorators
# --------------------------------------------------------------------------


def agent(func: Optional[Callable] = None, *, startup: bool = False, critical: bool = True):
    def deco(f: Callable) -> Callable:
        f._colmena_kind = "agent"
        f._colmena_opts = {"startup": startup, "critical": critical and not startup}
        return f

    return deco(func) if func is not None else deco


def result_processor(func: Optional[Callable] = None, *, topic: str = "default", on: str = "result"):
    assert on in ("result", "completion")

    def deco(f: Callable) -> Callable:
        f._colmena_kind = "result_processor"
        f._colmena_opts = {"topic": topic, "on": on}
        return f

    return deco(func) if func is not None else deco


def event_responder(
    func: Optional[Callable] = None,
    *,
    event_name: str,
    reallocate: Optional[dict] = None,
    clear_after: bool = True,
):
    """``reallocate`` (optional): dict(src=, dst=, n=) applied while the
    responder runs and reversed afterwards — the paper's pattern of
    shifting nodes to retraining when 'enough data' arrives."""

    def deco(f: Callable) -> Callable:
        f._colmena_kind = "event_responder"
        f._colmena_opts = {
            "event_name": event_name,
            "reallocate": reallocate,
            "clear_after": clear_after,
        }
        return f

    return deco(func) if func is not None else deco


def task_submitter(func: Optional[Callable] = None, *, task_type: str = "default", n_slots: int = 1):
    def deco(f: Callable) -> Callable:
        f._colmena_kind = "task_submitter"
        f._colmena_opts = {"task_type": task_type, "n_slots": n_slots}
        return f

    return deco(func) if func is not None else deco


# --------------------------------------------------------------------------
# BaseThinker
# --------------------------------------------------------------------------


class BaseThinker:
    """Base class for steering policies. Subclass, decorate methods, run."""

    def __init__(
        self,
        queues: ColmenaQueues,
        resource_counter: Optional[ResourceCounter] = None,
        daemon: bool = True,
    ) -> None:
        self.queues = queues
        self.rec = resource_counter or ResourceCounter(1)
        # WakeEvents so waits on resources/heaps/named-events wake on
        # set() instead of polling (see wait_event/ResourceCounter.acquire).
        self.done = WakeEvent()
        self.daemon = daemon
        self.logger = logging.getLogger(f"repro.thinker.{type(self).__name__}")
        self._threads: List[threading.Thread] = []
        self._events: Dict[str, threading.Event] = {}
        self._agent_exc: List[BaseException] = []

    # ---------------------------------------------------------------- events
    def event(self, name: str) -> threading.Event:
        ev = self._events.get(name)
        if ev is None:
            ev = self._events[name] = WakeEvent()
        return ev

    def set_event(self, name: str) -> None:
        self.event(name).set()

    # --------------------------------------------------------------- agents
    def _collect_agents(self) -> List[Callable]:
        out = []
        for name in dir(self):
            if name.startswith("__"):
                continue
            fn = getattr(self, name, None)
            if callable(fn) and hasattr(fn, "_colmena_kind"):
                out.append(fn)
        return out

    # wrappers -------------------------------------------------------------
    def _run_agent(self, fn: Callable) -> None:
        opts = fn._colmena_opts
        try:
            fn()
        except BaseException as exc:  # noqa: BLE001 - surfaced in run()
            self.logger.exception("agent %s failed", fn.__name__)
            self._agent_exc.append(exc)
            self.done.set()
            return
        if opts["critical"]:
            self.logger.info("critical agent %s exited; shutting down", fn.__name__)
            self.done.set()

    def _run_result_processor(self, fn: Callable) -> None:
        opts = fn._colmena_opts
        # Queues with the wake-sentinel API let processors block in the
        # pop with no timeout: ``done.set()`` pushes one sentinel per
        # processor (see run()), so shutdown is instant. Foreign queue
        # implementations fall back to a bounded pop.
        timeout = (
            None if hasattr(self.queues, "wake_result_waiters")
            else _FALLBACK_GETTER_TIMEOUT_S
        )
        getter = (
            (lambda: self.queues.get_result(topic=opts["topic"], timeout=timeout))
            if opts["on"] == "result"
            else (lambda: self.queues.get_completion(topic=opts["topic"], timeout=timeout))
        )
        try:
            while not self.done.is_set():
                item = getter()
                if item is None:
                    continue
                fn(item)
                if isinstance(item, Result):
                    item.mark("decision_made")
                    item.finalize_timings()
                    log = getattr(self.queues, "event_log", None)
                    if log is not None:
                        log.task_event("decision_made", item, processor=fn.__name__)
        except BaseException as exc:  # noqa: BLE001
            self.logger.exception("result processor %s failed", fn.__name__)
            self._agent_exc.append(exc)
            self.done.set()

    def _run_event_responder(self, fn: Callable) -> None:
        opts = fn._colmena_opts
        ev = self.event(opts["event_name"])
        realloc = opts["reallocate"]
        try:
            while not self.done.is_set():
                if not wait_event(ev, self.done):  # woken by set_event()/done
                    continue
                if realloc:
                    self.rec.reallocate(realloc["src"], realloc["dst"], realloc["n"], stop_event=self.done)
                try:
                    fn()
                finally:
                    if realloc:
                        self.rec.reallocate(realloc["dst"], realloc["src"], realloc["n"], stop_event=self.done)
                if opts["clear_after"]:
                    ev.clear()
        except BaseException as exc:  # noqa: BLE001
            self.logger.exception("event responder %s failed", fn.__name__)
            self._agent_exc.append(exc)
            self.done.set()

    def _run_task_submitter(self, fn: Callable) -> None:
        opts = fn._colmena_opts
        try:
            while not self.done.is_set():
                # Blocks on the resource condition until slots free or
                # done is set (which wakes the wait) — no poll timeout.
                ok = self.rec.acquire(opts["task_type"], opts["n_slots"], stop_event=self.done)
                if not ok:
                    continue
                if self.done.is_set():
                    self.rec.release(opts["task_type"], opts["n_slots"])
                    break
                fn()
        except BaseException as exc:  # noqa: BLE001
            self.logger.exception("task submitter %s failed", fn.__name__)
            self._agent_exc.append(exc)
            self.done.set()

    def _arm_shutdown_wakeup(self, agents: List[Callable]) -> None:
        """On ``done.set()``, push one queue sentinel per result processor
        so pops blocked in ``get_result``/``get_completion`` return
        immediately — shutdown is not bounded by any pop timeout."""
        wake = getattr(self.queues, "wake_result_waiters", None)
        if wake is None:
            return
        counts: Dict[tuple, int] = {}
        for fn in agents:
            if fn._colmena_kind == "result_processor":
                key = (fn._colmena_opts["topic"], fn._colmena_opts["on"])
                counts[key] = counts.get(key, 0) + 1
        if not counts:
            return

        def _wake() -> None:
            try:
                wake(counts)
            except Exception:  # noqa: BLE001 - shutdown must not fail here
                self.logger.exception("failed to wake result processors")

        self.done.on_set(_wake)

    # ------------------------------------------------------------------ run
    def run(self, timeout: Optional[float] = None) -> None:
        """Start every agent thread; block until the Thinker is done."""
        agents = self._collect_agents()
        if not agents:
            raise RuntimeError("Thinker has no agents; decorate methods first")
        # Arm before any agent can set ``done`` (startup agents included).
        self._arm_shutdown_wakeup(agents)

        runners = {
            "agent": self._run_agent,
            "result_processor": self._run_result_processor,
            "event_responder": self._run_event_responder,
            "task_submitter": self._run_task_submitter,
        }
        startup = [f for f in agents if f._colmena_opts.get("startup")]
        rest = [f for f in agents if not f._colmena_opts.get("startup")]

        # Startup agents run to completion first (task seeding).
        for fn in startup:
            self._run_agent(fn)

        for fn in rest:
            t = threading.Thread(
                target=runners[fn._colmena_kind],
                args=(fn,),
                daemon=self.daemon,
                name=f"{type(self).__name__}.{fn.__name__}",
            )
            t.start()
            self._threads.append(t)

        self.done.wait(timeout=timeout)
        self.done.set()  # in case we got here via timeout
        for t in self._threads:
            t.join(timeout=2.0)
        if self._agent_exc:
            raise RuntimeError(f"{len(self._agent_exc)} agent(s) failed") from self._agent_exc[0]
