"""repro.app — one declarative composition API for the whole stack.

The paper's pitch is that scientists *only* write cooperative agents:
the platform owns queues, dispatch, the data fabric, and telemetry.
This module is that contract. An ``AppSpec`` declares the five
concerns — tasks, queue backend, data fabric, observe, steering, and
campaign persistence — and ``ColmenaApp`` composes the stack from it,
owning the full lifecycle as a context manager::

    from repro.app import AppSpec, ColmenaApp, SteeringSpec, task

    @task                       # registry: method name, pool, batching
    def simulate(x):
        return expensive(x)

    app = ColmenaApp(AppSpec(
        tasks=[simulate],
        pools={"default": 4},       # shorthand; normalizes to PoolSpec
        steering=SteeringSpec(MyThinker, dict(n_total=32)),
    ))
    with app.run(timeout=60) as handle:
        handle.wait()
    print(handle.report.completed, handle.observe_report()["makespan_s"])

Resources are declared as first-class ``PoolSpec``s (size, min/max
elasticity band, warm/prefetch knobs, per-pool fault injector); the
``{name: slots}`` shorthand stays accepted and is normalized in
``AppSpec.__post_init__``. Because specs are picklable, the same layout
crosses process boundaries (``ServerSpec(in_process=False)`` rebuilds
every named pool inside the spawned child) and serializes to TOML/JSON
campaign files (``AppSpec.save``/``load``, ``repro.core.specfile``)
launched with ``python -m repro.app run``.

Everything the app composes stays reachable (``handle.thinker``,
``handle.queues``, ``handle.store``, ``handle.event_log``), and the
low-level constructors (``LocalColmenaQueues`` + ``TaskServer`` +
``Campaign`` by hand) keep working — the app layer is sugar over them,
not a fork.

Lifecycle guarantees:
  * **ordered start** — resume campaign state, start the task server,
    start the adaptive reallocator, then launch the steering agents;
  * **ordered drain/stop** — stop steering, final campaign checkpoint,
    kill the server's request loop, stop the reallocator and worker
    pools, release fabric resources;
  * **crash containment** — an agent exception is captured, the stack
    is still torn down in order, and the exception re-raises when the
    ``with`` block exits;
  * **idempotency** — double start and double stop are no-ops; a
    stopped app refuses to restart (build a new one from the same
    spec);
  * **resume** — a new ``ColmenaApp`` over the same ``CampaignSpec``
    state dir resumes the steering state from the latest checkpoint.
"""

from __future__ import annotations

import dataclasses
import multiprocessing
import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union

from .campaign import Campaign, CampaignReport
from .executors import FailureInjector, PoolSpec, WorkerPool, normalize_pools, stateful_task
from .proxystore import Store, connector_from_spec
from .queues import ColmenaQueues, LocalColmenaQueues, PipeColmenaQueues
from .result import ResourceRequest
from .task_server import BatchPolicy, RetryPolicy, ServerMetrics, StragglerPolicy, TaskServer, serve_forever
from .thinker import BaseThinker

__all__ = [
    "AppSpec",
    "CampaignSpec",
    "ColmenaApp",
    "ControlSpec",
    "FabricSpec",
    "ObserveSpec",
    "PoolSpec",
    "ProcessTaskServer",
    "QueueSpec",
    "RemotePool",
    "ServerSpec",
    "SteeringSpec",
    "TaskDef",
    "task",
]


# --------------------------------------------------------------------------
# Task registry
# --------------------------------------------------------------------------


@dataclass
class TaskDef:
    """One entry of the app's task registry.

    ``pool``/``timeout_s`` become the method's default ``ResourceRequest``
    (explicit per-submission requests still win); ``batch`` opts the
    method into the server's batched-dispatch path.
    """

    fn: Callable
    method: Optional[str] = None
    pool: str = "default"
    timeout_s: Optional[float] = None
    batch: bool = False

    def __post_init__(self) -> None:
        if self.method is None:
            self.method = getattr(self.fn, "__name__", None)
        if not self.method:
            raise ValueError("TaskDef needs a method name")

    def resources(self) -> ResourceRequest:
        return ResourceRequest(pool=self.pool, timeout_s=self.timeout_s)


def task(
    fn: Optional[Callable] = None,
    *,
    method: Optional[str] = None,
    pool: str = "default",
    timeout_s: Optional[float] = None,
    batch: bool = False,
    stateful: bool = False,
):
    """Decorator form of :class:`TaskDef`: registers the function for
    ``AppSpec.tasks``. ``stateful=True`` additionally injects the
    worker registry (``repro.core.stateful_task``)."""

    def deco(f: Callable) -> Callable:
        if stateful:
            f = stateful_task(f)
        f._colmena_taskdef = TaskDef(
            fn=f, method=method, pool=pool, timeout_s=timeout_s, batch=batch
        )
        return f

    return deco(fn) if fn is not None else deco


def _as_taskdef(obj: Any) -> TaskDef:
    if isinstance(obj, TaskDef):
        return obj
    td = getattr(obj, "_colmena_taskdef", None)
    if td is not None:
        return td
    if callable(obj):
        return TaskDef(fn=obj)
    raise TypeError(f"cannot interpret {obj!r} as a task (use @task or TaskDef)")


# --------------------------------------------------------------------------
# Specs
# --------------------------------------------------------------------------


@dataclass
class QueueSpec:
    """Control-channel backend: ``local`` (in-process ``queue.Queue``) or
    ``pipe`` (multiprocessing queues with metered serialization — the
    paper's Redis deployment shape). Porting an app between them is this
    one field."""

    backend: str = "local"
    topics: Sequence[str] = ("default",)

    def __post_init__(self) -> None:
        if self.backend not in ("local", "pipe"):
            raise ValueError(f"unknown queue backend {self.backend!r}")


@dataclass
class FabricSpec:
    """ProxyStore data fabric: which connector carries bulk payloads,
    the auto-proxy threshold, and the worker-side caching knobs."""

    connector: Any = "memory"          # kind str | spec dict | Connector
    threshold: int = 10_000_000        # auto-proxy bound (10 MB in the paper)
    prefetch: bool = True              # overlap fabric I/O with compute
    warm_capacity: int = 32            # per-worker warm cache (0 disables)
    cache_size: int = 16               # store-level client cache
    store_name: Optional[str] = None   # default: unique per app


@dataclass
class ObserveSpec:
    """Telemetry + the adaptive-reallocation loop. ``log`` adopts an
    existing ``EventLog`` (merged traces across apps); otherwise one is
    created. ``reallocator`` is ``"greedy"``/``"ema"`` or a
    ``ReallocationPolicy`` instance; it steers the *thinker's*
    ``ResourceCounter`` and needs a steering spec. ``elastic`` extends
    the same closed loop to the worker fleet itself: an
    ``repro.observe.ElasticPolicy`` (or a dict of its knobs, or ``True``
    for defaults) drives ``WorkerPool.resize`` within each pool's
    ``PoolSpec`` min/max band (in-process servers only)."""

    log: Optional[Any] = None           # repro.observe.EventLog
    jsonl_path: Optional[str] = None
    capacity: int = 1 << 16
    reallocator: Optional[Any] = None   # "greedy" | "ema" | policy object
    realloc_interval: float = 0.02
    realloc_min_slots: Optional[Dict[str, int]] = None
    elastic: Optional[Any] = None       # True | dict | ElasticPolicy
    # Spawned-server trace sink: where a ``ServerSpec(in_process=False)``
    # child writes its own JSONL event log (queues drop the parent's log
    # when pickled). Defaults to ``<jsonl_path minus extension>.server.jsonl``
    # when ``jsonl_path`` is set; merge both files with
    # ``repro.observe.trace.merge_jsonl`` for one complete trace.
    server_jsonl_path: Optional[str] = None
    # JSONL sink rotation (bytes; None = unbounded) — bounds disk on soaks.
    rotate_bytes: Optional[int] = None
    rotate_keep: int = 3
    # Metrics export: a directory path, an ``repro.observe.ExportSpec``,
    # or a dict of its knobs — periodic Prometheus text + JSON snapshots.
    export: Optional[Any] = None
    # Live ops plane. ``ops_port`` starts an ``repro.observe.OpsServer``
    # (HTTP /metrics /healthz /readyz /snapshot /alerts; 0 = ephemeral
    # port, read it back from ``app.ops.port``). ``slo`` is ``True`` (a
    # default objective set), an ``SLOSpec``, or its dict/list form —
    # a streaming burn-rate alert engine over the live metrics.
    # ``anomaly`` adds the EWMA/z-score advisory detector (``True`` or
    # ``AnomalySpec`` knobs). ``remediate=True`` wires firing SLO alerts
    # to the steering loops the app composes: backlog alerts pre-grow
    # the elastic fleet, utilization-floor alerts force a reallocator
    # rebalance. (Loss-rate alerts are wired where a resubmission path
    # exists — see the chaos soak harness.)
    ops_port: Optional[int] = None
    slo: Optional[Any] = None           # True | SLOSpec | dict | [objectives]
    anomaly: Optional[Any] = None       # True | AnomalySpec | dict
    remediate: bool = False

    def resolved_server_jsonl(self) -> Optional[str]:
        if self.server_jsonl_path is not None:
            return self.server_jsonl_path
        if self.jsonl_path is None:
            return None
        base = self.jsonl_path
        return (base[:-6] if base.endswith(".jsonl") else base) + ".server.jsonl"


@dataclass
class SteeringSpec:
    """The steering agents. ``thinker`` is a ``BaseThinker`` subclass
    (instantiated as ``cls(queues, **kwargs)``) or a factory
    ``f(app, **kwargs) -> BaseThinker`` for thinkers whose inputs need
    composed pieces (e.g. work lists proxied through ``app.store``).
    ``steering=None`` on the spec is driver mode: no agents, the caller
    drives ``handle.queues`` directly."""

    thinker: Any
    kwargs: Dict[str, Any] = field(default_factory=dict)

    def build(self, app: "ColmenaApp") -> BaseThinker:
        if isinstance(self.thinker, type) and issubclass(self.thinker, BaseThinker):
            return self.thinker(app.queues, **self.kwargs)
        if callable(self.thinker):
            return self.thinker(app, **self.kwargs)
        raise TypeError("SteeringSpec.thinker must be a BaseThinker subclass or factory")


@dataclass
class CampaignSpec:
    """Campaign persistence: periodic checkpoints into ``state_dir`` and
    resume-from-latest through the same entry point."""

    state_dir: str
    checkpoint_interval_s: float = 5.0
    name: str = "campaign"
    resume: bool = True


@dataclass
class ControlSpec:
    """Submission envelope for the campaign control plane
    (``repro.control``): how this campaign shares a daemon's fleet.

    ``weight`` is its fair-share weight (slots apportion roughly
    proportionally among contending campaigns), ``priority`` orders
    preemption (higher priorities are satisfied first and may pause
    lower ones), ``min_slots`` is the floor below which the campaign is
    paused instead of starved, and ``demand`` caps the slots it will
    accept (default: the sizes its own pool specs request)."""

    weight: float = 1.0
    priority: int = 0
    min_slots: int = 1
    demand: Optional[int] = None

    def __post_init__(self) -> None:
        if self.weight <= 0:
            raise ValueError("ControlSpec.weight must be > 0")
        if self.min_slots < 1:
            raise ValueError("ControlSpec.min_slots must be >= 1")


@dataclass
class ServerSpec:
    """Task-server policies. ``in_process=False`` (pipe backend only)
    runs the server in its own spawned process — the paper's federated
    deployment shape; it requires picklable task functions. The full
    named-pool layout crosses the boundary as ``PoolSpec``s and is
    rebuilt inside the child, so multi-pool (federated multi-resource)
    sites work the same as in-process ones."""

    in_process: bool = True
    batching: Optional[BatchPolicy] = None   # explicit policy wins
    max_batch: int = 8
    linger_s: float = 0.002
    retry: Optional[RetryPolicy] = None
    straggler: Optional[StragglerPolicy] = None
    heartbeat_timeout_s: float = 10.0
    injector: Optional[FailureInjector] = None


@dataclass
class AppSpec:
    """Everything a Colmena application is, declaratively.

    ``pools`` accepts the historical ``{name: slots}`` shorthand, a
    ``{name: PoolSpec}`` mapping (mixed with ints is fine), or a sequence
    of ``PoolSpec``s; ``__post_init__`` normalizes every form to
    ``{name: PoolSpec}``, so the rest of the stack sees exactly one
    resource vocabulary."""

    tasks: Sequence[Any]
    steering: Optional[SteeringSpec] = None
    queues: Union[str, QueueSpec] = "local"
    pools: Optional[Any] = None        # {name: slots | PoolSpec} | [PoolSpec]
    fabric: Optional[FabricSpec] = None
    observe: Optional[ObserveSpec] = field(default_factory=ObserveSpec)
    campaign: Optional[CampaignSpec] = None
    server: ServerSpec = field(default_factory=ServerSpec)
    control: Optional[ControlSpec] = None

    def __post_init__(self) -> None:
        if isinstance(self.tasks, Mapping):
            self.tasks = [TaskDef(fn=fn, method=m) for m, fn in self.tasks.items()]
        if isinstance(self.queues, str):
            self.queues = QueueSpec(backend=self.queues)
        if self.observe is not None and self.observe.elastic is False:
            self.observe.elastic = None  # False means off, same as unset
        if self.observe is not None:
            if self.observe.slo is False:
                self.observe.slo = None
            if self.observe.anomaly is False:
                self.observe.anomaly = None
            if self.observe.remediate and self.observe.slo is None:
                raise ValueError("ObserveSpec.remediate needs an SLO spec (alerts drive remediation)")
        self.pools = normalize_pools(self.pools)
        self.pools.setdefault("default", PoolSpec("default", 1))
        if isinstance(self.steering, type) and issubclass(self.steering, BaseThinker):
            self.steering = SteeringSpec(self.steering)
        if self.campaign is not None and self.steering is None:
            raise ValueError("a campaign spec needs a steering spec (checkpoint state lives on the thinker)")
        if (
            self.steering is None
            and self.observe is not None
            and self.observe.reallocator is not None
        ):
            raise ValueError(
                "an adaptive reallocator needs a steering spec (it moves the thinker's slots)"
            )
        if not self.server.in_process and self.queues.backend != "pipe":
            raise ValueError("a separate server process needs the 'pipe' queue backend")
        # Elastic + out-of-process composes via the control channel:
        # the scaler drives RemotePool proxies whose resize requests
        # round-trip to the spawned site (no constraint needed here).

    # -- serialization (repro.core.specfile) --------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """Plain-dict form with tasks/thinkers by dotted import path (see
        ``repro.core.specfile``); round-trips through ``from_dict``."""
        from .specfile import spec_to_dict

        return spec_to_dict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "AppSpec":
        from .specfile import spec_from_dict

        return spec_from_dict(d)

    def save(self, path: str) -> str:
        """Write the spec as TOML or JSON (chosen by extension)."""
        from .specfile import save_spec

        return save_spec(self, path)

    @classmethod
    def load(cls, path: str, smoke: bool = False) -> "AppSpec":
        """Load a TOML/JSON campaign file (``smoke=True`` applies the
        file's ``[smoke]`` override table)."""
        from .specfile import load_spec

        return load_spec(path, smoke=smoke)


# --------------------------------------------------------------------------
# Process-mode task server (federated shape)
# --------------------------------------------------------------------------


class ProcessTaskServer:
    """Drop-in ``TaskServer`` stand-in running ``serve_forever`` in a
    spawned process (the multi-site deployments of Fig. 4). The pool
    layout ships as picklable ``PoolSpec``s (``pool_specs=`` in
    ``server_kwargs``) and the child rebuilds the full named-pool dict
    on its side, so multi-pool federated sites need no special casing.
    Metrics are process-local to the server and therefore empty on this
    side."""

    def __init__(
        self,
        queues: ColmenaQueues,
        methods: Dict[str, Callable],
        n_workers: int = 4,
        **server_kwargs: Any,
    ) -> None:
        self.queues = queues
        self.methods = dict(methods)
        self.n_workers = n_workers
        self.server_kwargs = server_kwargs
        self.metrics = ServerMetrics()
        self._proc: Optional[multiprocessing.process.BaseProcess] = None

    def start(self) -> "ProcessTaskServer":
        if self._proc is not None:
            return self
        ctx = multiprocessing.get_context("spawn")
        self._proc = ctx.Process(
            target=serve_forever,
            args=(self.queues, self.methods),
            kwargs={"n_workers": self.n_workers, **self.server_kwargs},
            daemon=True,
            name="colmena-task-server",
        )
        self._proc.start()
        return self

    def stop(self) -> None:
        proc, self._proc = self._proc, None
        if proc is None:
            return
        try:
            self.queues.send_kill_signal()
        except Exception:  # noqa: BLE001 - the process is terminated below
            pass
        proc.join(timeout=5)
        if proc.is_alive():
            proc.terminate()
            proc.join(timeout=2)


@dataclass
class _RemoteWorkerState:
    """Synthetic per-slot state for ``RemotePool.worker_states`` — the
    scaler only reads ``busy``/``alive``."""

    busy: bool
    alive: bool = True


class RemotePool:
    """``ElasticScaler``-compatible proxy for a pool living inside a
    spawned ``ProcessTaskServer`` site (cross-process elasticity).

    The live ``WorkerPool`` cannot cross the process boundary, so the
    proxy mirrors the scaler's read surface from the parent side:

      * ``n_workers`` tracks the last acked size (seeded from the spec);
      * ``queued()``/``worker_states()`` are estimated from the parent's
        own lifecycle events — tasks ``submitted`` minus results
        received for this pool is the in-flight count, of which up to
        ``n_workers`` are presumed busy and the rest queued. Tasks that
        rely on a method's default pool are attributed via
        ``method_pools`` (the server applies the same mapping remotely);
      * ``resize(target)`` round-trips a ``ControlRequest`` over the
        request queue and blocks for the ack on the control topic — the
        remote site clamps to its spec band, resizes, and records
        ``pool_resize`` in its own event log. On timeout (site dead or
        restarting) the proxy reports no change and the scaler simply
        retries on a later tick.
    """

    def __init__(
        self,
        queues: ColmenaQueues,
        spec: PoolSpec,
        event_log: Optional[Any] = None,
        method_pools: Optional[Dict[str, str]] = None,
        ack_timeout_s: float = 10.0,
    ) -> None:
        self.name = spec.name
        self.queues = queues
        self.spec = spec
        self.ack_timeout_s = ack_timeout_s
        self._method_pools = dict(method_pools or {})
        self._n_workers = spec.size
        self._inflight = 0
        self._lock = threading.Lock()
        if event_log is not None:
            event_log.subscribe(self._on_event, replay=True)

    @property
    def n_workers(self) -> int:
        return self._n_workers

    def _resolve_pool(self, ev: Any) -> str:
        if ev.pool and ev.pool != "default":
            return ev.pool
        return self._method_pools.get(ev.method, ev.pool or "default")

    def _on_event(self, ev: Any) -> None:
        if ev.kind != "task" or self._resolve_pool(ev) != self.name:
            return
        if ev.stage == "submitted":
            with self._lock:
                self._inflight += 1
        elif ev.stage == "result_received":
            with self._lock:
                self._inflight = max(0, self._inflight - 1)

    def queued(self) -> int:
        with self._lock:
            inflight = self._inflight
        return max(0, inflight - self._n_workers)

    def worker_states(self) -> List[_RemoteWorkerState]:
        with self._lock:
            inflight = self._inflight
        n = self._n_workers
        busy = min(inflight, n)
        return [_RemoteWorkerState(busy=i < busy) for i in range(n)]

    def resize(self, target: int) -> Tuple[int, int]:
        old = self._n_workers
        ack = self.queues.request_resize(
            self.name, int(target), timeout=self.ack_timeout_s, reason="elastic"
        )
        if ack is None or not ack.ok:
            return old, old  # unacked: report no change, retry next tick
        new = int(ack.detail.get("new", old))
        self._n_workers = new
        return int(ack.detail.get("old", old)), new


# --------------------------------------------------------------------------
# The app
# --------------------------------------------------------------------------


class AppHandle:
    """What ``ColmenaApp.run()`` hands the ``with`` body: the composed
    pieces plus ``wait``. Exiting the block drains and stops the stack
    in order and re-raises any agent crash."""

    def __init__(self, app: "ColmenaApp", timeout: Optional[float]) -> None:
        self.app = app
        self._timeout = timeout

    def __enter__(self) -> "AppHandle":
        self.app.start(timeout=self._timeout)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.app.stop()
        if exc_type is None and self.app.thinker_exception is not None:
            raise self.app.thinker_exception
        return False

    # -- delegation ----------------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> bool:
        return self.app.wait(timeout)

    def observe_report(self) -> dict:
        return self.app.observe_report()

    @property
    def thinker(self) -> Optional[BaseThinker]:
        return self.app.thinker

    @property
    def queues(self) -> ColmenaQueues:
        return self.app.queues

    @property
    def event_log(self) -> Optional[Any]:
        return self.app.event_log

    @property
    def store(self) -> Optional[Store]:
        return self.app.store

    @property
    def server(self) -> Any:
        return self.app.server

    @property
    def report(self) -> Optional[CampaignReport]:
        return self.app.report


class ColmenaApp:
    """Compose queues, fabric, server, observe, steering, and campaign
    from one :class:`AppSpec`; own their ordered lifecycle."""

    def __init__(self, spec: AppSpec) -> None:
        self.spec = spec
        self.taskdefs: List[TaskDef] = [_as_taskdef(t) for t in spec.tasks]
        methods = [td.method for td in self.taskdefs]
        dupes = {m for m in methods if methods.count(m) > 1}
        if dupes:
            raise ValueError(f"duplicate task methods: {sorted(dupes)}")

        # Composed pieces (populated by build()).
        self.event_log: Optional[Any] = None
        self.store: Optional[Store] = None
        self.queues: Optional[ColmenaQueues] = None
        self.pools: Dict[str, WorkerPool] = {}
        self.pool_specs: Dict[str, PoolSpec] = {}
        self.pool_sizes: Dict[str, int] = {}
        self.server: Any = None
        self.thinker: Optional[BaseThinker] = None
        self.reallocator: Optional[Any] = None
        self.elastic: Optional[Any] = None
        self.exporter: Optional[Any] = None
        # Live ops plane: one shared aggregator feeds the exporter, the
        # HTTP endpoint, and the SLO/anomaly engines.
        self.aggregator: Optional[Any] = None
        self.slo: Optional[Any] = None
        self.anomaly: Optional[Any] = None
        self.ops: Optional[Any] = None
        self.campaign: Optional[Campaign] = None
        self.report: Optional[CampaignReport] = None
        # Cross-process elastic proxies (out-of-process server + elastic).
        self.remote_pools: Dict[str, Any] = {}
        # Control-plane surface: lifecycle listeners (attach/detach) and
        # the externally-driven pause flag (pause()).
        self.paused = False
        self._listeners: List[Callable[[str, "ColmenaApp"], None]] = []

        self._built = False
        self._started = False
        self._stopped = False
        self._owns_log = False
        self._lifecycle_lock = threading.Lock()
        self._thinker_thread: Optional[threading.Thread] = None
        self._thinker_exc: Optional[BaseException] = None
        self._ckpt_stop: Optional[threading.Event] = None
        self._ckpt_thread: Optional[threading.Thread] = None
        self._t0: Optional[float] = None

    # ------------------------------------------------------------------ build
    def build(self) -> "ColmenaApp":
        """Compose the stack (idempotent; ``start`` calls it for you)."""
        if self._built:
            return self
        spec = self.spec

        # Observe first: every later component is born instrumented.
        if spec.observe is not None:
            if spec.observe.log is not None:
                self.event_log = spec.observe.log
            else:
                from repro.observe import EventLog

                self.event_log = EventLog(
                    capacity=spec.observe.capacity,
                    jsonl_path=spec.observe.jsonl_path,
                    rotate_bytes=spec.observe.rotate_bytes,
                    rotate_keep=spec.observe.rotate_keep,
                )
                self._owns_log = True

        # Data fabric.
        if spec.fabric is not None:
            name = spec.fabric.store_name or f"app-{uuid.uuid4().hex[:8]}"
            self.store = Store(
                name,
                connector_from_spec(spec.fabric.connector),
                cache_size=spec.fabric.cache_size,
            )

        # Queues.
        qspec = spec.queues
        qcls = LocalColmenaQueues if qspec.backend == "local" else PipeColmenaQueues
        self.queues = qcls(
            topics=qspec.topics,
            proxystore=self.store,
            proxy_threshold=spec.fabric.threshold if spec.fabric else 10_000_000,
            event_log=self.event_log,
        )

        # Worker pools: declared specs, plus every pool a task names.
        self.pool_specs = dict(spec.pools)
        for td in self.taskdefs:
            self.pool_specs.setdefault(td.pool, PoolSpec(td.pool, 1))
        # Fabric knobs are the app-level defaults for per-pool caching;
        # a PoolSpec's own fields win. Resolved ONCE here — both server
        # branches consume the same resolved specs, so in-process and
        # spawned servers always build identical pools.
        fabric = spec.fabric or FabricSpec()
        resolved_specs = {
            name: dataclasses.replace(
                ps,
                warm_capacity=ps.warm_capacity if ps.warm_capacity is not None else fabric.warm_capacity,
                prefetch=ps.prefetch if ps.prefetch is not None else fabric.prefetch,
                injector=ps.injector if ps.injector is not None else spec.server.injector,
            )
            for name, ps in self.pool_specs.items()
        }
        self.pool_sizes = {name: ps.size for name, ps in self.pool_specs.items()}

        methods = {td.method: td.fn for td in self.taskdefs}
        method_resources = {
            td.method: td.resources()
            for td in self.taskdefs
            if td.pool != "default" or td.timeout_s is not None
        }
        batching = spec.server.batching
        if batching is None:
            batch_methods = tuple(td.method for td in self.taskdefs if td.batch)
            if batch_methods:
                batching = BatchPolicy(
                    max_batch=spec.server.max_batch,
                    linger_s=spec.server.linger_s,
                    methods=batch_methods,
                )

        # Task server: in-process threads, or a spawned process (pipe).
        if spec.server.in_process:
            self.pools = {
                name: ps.build(event_log=self.event_log)
                for name, ps in resolved_specs.items()
            }
            self.server = TaskServer(
                self.queues,
                methods,
                pools=self.pools,
                retry=spec.server.retry,
                straggler=spec.server.straggler,
                batching=batching,
                heartbeat_timeout_s=spec.server.heartbeat_timeout_s,
                event_log=self.event_log,
                method_resources=method_resources,
            )
        else:
            server_jsonl = spec.observe.resolved_server_jsonl() if spec.observe else None
            self.server = ProcessTaskServer(
                self.queues,
                methods,
                pool_specs=resolved_specs,
                batching=batching,
                retry=spec.server.retry,
                straggler=spec.server.straggler,
                heartbeat_timeout_s=spec.server.heartbeat_timeout_s,
                method_resources=method_resources,
                jsonl_path=server_jsonl,
            )

        # Steering agents + the loops that ride on them.
        if spec.steering is not None:
            self.thinker = spec.steering.build(self)
            if self.event_log is not None:
                self.thinker.rec.event_log = self.event_log
            if spec.observe is not None and spec.observe.reallocator is not None:
                self.reallocator = self._build_reallocator(spec.observe)
        if spec.observe is not None and spec.observe.elastic is not None:
            self.elastic = self._build_elastic(spec.observe)
        ospec = spec.observe
        needs_aggregator = ospec is not None and (
            ospec.export is not None or ospec.ops_port is not None
            or ospec.slo is not None or ospec.anomaly is not None
        )
        if needs_aggregator:
            from repro.observe import MetricsAggregator

            self.aggregator = MetricsAggregator(self.event_log)
        if ospec is not None and ospec.export is not None:
            from repro.observe import ExportSpec, MetricsExporter

            exp = ospec.export
            if isinstance(exp, str):
                exp = ExportSpec(dir=exp)
            elif isinstance(exp, Mapping):
                exp = ExportSpec(**exp)
            self.exporter = MetricsExporter(
                self.event_log, spec=exp,
                slots_by_pool={name: ps.size for name, ps in self.pool_specs.items()},
                aggregator=self.aggregator,
            )
        if ospec is not None and ospec.slo is not None:
            from repro.observe import SLOEngine, SLOSpec

            self.slo = SLOEngine(
                self.event_log, SLOSpec.from_any(ospec.slo),
                aggregator=self.aggregator,
                slots_by_pool={name: ps.size for name, ps in self.pool_specs.items()},
            )
        if ospec is not None and ospec.anomaly is not None:
            from repro.observe import AnomalyDetector, AnomalySpec

            self.anomaly = AnomalyDetector(
                self.event_log, AnomalySpec.from_any(ospec.anomaly),
                aggregator=self.aggregator,
            )
            if self.slo is not None:
                # One tick thread: the SLO engine drives the detector.
                self.slo.anomaly = self.anomaly
        if ospec is not None and ospec.ops_port is not None:
            from repro.observe import OpsServer

            self.ops = OpsServer(
                aggregator=self.aggregator,
                slots_by_pool={name: ps.size for name, ps in self.pool_specs.items()},
                slo=self.slo,
                anomaly=self.anomaly,
                port=ospec.ops_port,
            )
        if ospec is not None and ospec.remediate and self.slo is not None:
            self._wire_remediations()
        if spec.campaign is not None:
            self.campaign = Campaign(
                self.thinker,
                self.server,
                state_dir=spec.campaign.state_dir,
                checkpoint_interval_s=spec.campaign.checkpoint_interval_s,
                name=spec.campaign.name,
            )

        self._built = True
        return self

    def _wire_remediations(self) -> None:
        """Close the observe→steer loop: firing SLO alerts trigger the
        steering components the app already composes. Every attempt is
        recorded as a ``remediation`` event by the engine."""
        if self.elastic is not None:
            def _pre_grow(alert: Dict[str, Any]) -> Any:
                grown = self.elastic.pre_grow(alert.get("pool"))
                return {"grown": grown}

            self.slo.on_fire("backlog", _pre_grow, label="elastic_pre_grow")
        if self.reallocator is not None:
            def _rebalance(alert: Dict[str, Any]) -> Any:
                return {"moves": len(self.reallocator.step() or [])}

            self.slo.on_fire("utilization", _rebalance, label="reallocator_rebalance")

    def _build_elastic(self, ospec: ObserveSpec) -> Any:
        from repro.observe import ElasticPolicy, ElasticScaler

        policy = ospec.elastic
        if policy is True:
            policy = ElasticPolicy()
        elif isinstance(policy, Mapping):
            policy = ElasticPolicy(**policy)
        elastic_specs = {n: ps for n, ps in self.pool_specs.items() if ps.elastic}
        if not elastic_specs:
            raise ValueError(
                "ObserveSpec.elastic is set but no PoolSpec widens its "
                "[min_size, max_size] band; declare at least one elastic pool"
            )
        if self.spec.server.in_process:
            pools: Dict[str, Any] = {n: self.pools[n] for n in elastic_specs}
        else:
            # Cross-process elasticity: the fleet lives in the spawned
            # site, so the scaler drives RemotePool proxies whose resizes
            # round-trip over the control channel.
            method_pools = {
                td.method: td.pool for td in self.taskdefs if td.pool != "default"
            }
            pools = {
                n: RemotePool(
                    self.queues, spec, event_log=self.event_log,
                    method_pools=method_pools,
                )
                for n, spec in elastic_specs.items()
            }
            self.remote_pools = pools
        return ElasticScaler(
            pools=pools,
            specs=elastic_specs,
            policy=policy,
            event_log=self.event_log,
            rec=self.thinker.rec if self.thinker is not None else None,
        )

    def _build_reallocator(self, ospec: ObserveSpec) -> Any:
        from repro.observe import (
            AdaptiveReallocator,
            EMABacklogPolicy,
            GreedyBacklogPolicy,
            MetricsAggregator,
        )

        policy = ospec.reallocator
        if policy == "greedy":
            policy = GreedyBacklogPolicy()
        elif policy == "ema":
            policy = EMABacklogPolicy()
        if self.event_log is None:
            raise ValueError("the adaptive reallocator needs an event log (observe spec)")
        return AdaptiveReallocator(
            self.thinker.rec,
            policy=policy,
            metrics=MetricsAggregator(self.event_log),
            interval=ospec.realloc_interval,
            min_slots=ospec.realloc_min_slots,
            event_log=self.event_log,
        )

    # -------------------------------------------------------------- lifecycle
    def run(self, timeout: Optional[float] = None) -> AppHandle:
        """Context-managed run: ``with app.run() as handle: ...``."""
        return AppHandle(self, timeout)

    def execute(self, timeout: Optional[float] = None) -> CampaignReport:
        """Blocking convenience: start, wait for steering, stop, report."""
        with self.run(timeout=timeout) as handle:
            handle.wait()
        return self.report

    def start(self, timeout: Optional[float] = None) -> "ColmenaApp":
        """Ordered start (idempotent): resume -> server -> reallocator ->
        checkpoints -> steering agents."""
        with self._lifecycle_lock:
            if self._stopped:
                raise RuntimeError("this ColmenaApp already ran; build a new one from the spec")
            if self._started:
                return self
            self._started = True
        self.build()
        self._t0 = time.monotonic()
        # Ops endpoint first: /healthz answers "starting" while the rest
        # of the stack comes up.
        if self.ops is not None:
            self.ops.start()
        if self.campaign is not None and self.spec.campaign.resume:
            self.campaign.try_resume()
        self.server.start()
        if self.reallocator is not None:
            self.reallocator.start()
        if self.elastic is not None:
            self.elastic.start()
        if self.exporter is not None:
            self.exporter.start()
        if self.slo is not None:
            self.slo.start()
        elif self.anomaly is not None:
            self.anomaly.start()  # standalone: no SLO engine to tick it
        if self.campaign is not None:
            self._ckpt_stop = threading.Event()
            self._ckpt_thread = threading.Thread(
                target=self.campaign.checkpoint_loop,
                args=(self._ckpt_stop,),
                daemon=True,
                name="app-campaign-ckpt",
            )
            self._ckpt_thread.start()
        if self.thinker is not None:
            self._thinker_thread = threading.Thread(
                target=self._drive_thinker, args=(timeout,), daemon=True, name="app-thinker"
            )
            self._thinker_thread.start()
        if self.ops is not None:
            self.ops.set_state("ready")
        self._notify("started")
        return self

    # ---------------------------------------------------------- control plane
    def attach(self, listener: Callable[[str, "ColmenaApp"], None]) -> None:
        """Attach a control-plane listener: called as ``listener(event,
        app)`` at lifecycle edges (``"started"``, ``"paused"``,
        ``"stopped"``). The control plane uses this to mirror app
        lifecycle into its durable campaign state machine."""
        self._listeners.append(listener)

    def detach(self, listener: Callable[[str, "ColmenaApp"], None]) -> None:
        self._listeners = [cb for cb in self._listeners if cb is not listener]

    def _notify(self, event: str) -> None:
        for cb in list(self._listeners):
            try:
                cb(event, self)
            except Exception:  # noqa: BLE001 - listeners must not break lifecycle
                pass

    def pause(self) -> Optional[CampaignReport]:
        """Externally-driven pause (the control plane's preemption path):
        drain the steering agents, take the final checkpoint, and release
        every slot — exactly ``stop()``, but the run is marked *paused*
        rather than finished. Resume by building a fresh ``ColmenaApp``
        over the same ``CampaignSpec`` state dir (``resume=True`` puts
        the thinker back where the checkpoint left it)."""
        self.paused = True
        # Snapshot before the drain as well: if an agent wedges during
        # stop(), the pre-drain checkpoint still bounds the lost work.
        if self.campaign is not None:
            self.campaign.pause()
        return self.stop()

    def _drive_thinker(self, timeout: Optional[float]) -> None:
        try:
            self.thinker.run(timeout=timeout)
        except BaseException as exc:  # noqa: BLE001 - re-raised at stop/exit
            self._thinker_exc = exc

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the steering agents finish (True) or ``timeout``
        elapses (False). Driver mode returns immediately."""
        t = self._thinker_thread
        if t is None:
            return True
        t.join(timeout)
        return not t.is_alive()

    @property
    def thinker_exception(self) -> Optional[BaseException]:
        """The contained agent crash, if any (re-raised on context exit)."""
        return self._thinker_exc

    def stop(self) -> Optional[CampaignReport]:
        """Ordered drain/stop (idempotent): steering -> final checkpoint
        -> kill signal -> reallocator -> server -> fabric. Returns the
        run report."""
        with self._lifecycle_lock:
            # Stop before start is a pure no-op (it must not poison a
            # later start); stop after stop returns the cached report.
            if self._stopped or not self._started:
                return self.report
            self._stopped = True
        if self.ops is not None:
            self.ops.set_state("draining")
        # Every step below is guarded: stop() must complete (and not mask
        # the original error) even when start() failed mid-build and only
        # part of the stack exists.
        if self.thinker is not None:
            self.thinker.done.set()
        if self._thinker_thread is not None:
            self._thinker_thread.join(timeout=10)
        if self._ckpt_stop is not None:
            self._ckpt_stop.set()
            if self._ckpt_thread is not None:
                self._ckpt_thread.join(timeout=2)
        if self.campaign is not None:
            self.campaign.final_checkpoint()
        if self.queues is not None:
            try:
                self.queues.send_kill_signal()
            except Exception:  # noqa: BLE001 - server.stop() below is the backstop
                pass
        if self.reallocator is not None:
            self.reallocator.stop()
        if self.elastic is not None:
            self.elastic.stop()
        if self.slo is not None:
            self.slo.stop()
        if self.anomaly is not None:
            self.anomaly.stop()
        if self.exporter is not None:
            self.exporter.stop()
        if self.server is not None:
            self.server.stop()
        if self.store is not None:
            try:
                self.store.close()
            except Exception:  # noqa: BLE001 - teardown must complete
                pass
        if self.ops is not None:
            self.ops.set_state("stopped")
            self.ops.stop()
        if self._owns_log and self.event_log is not None:
            self.event_log.close()
        completed = (
            self._thinker_exc is None
            and self.server is not None
            and (self._thinker_thread is None or not self._thinker_thread.is_alive())
        )
        self.report = CampaignReport(
            completed=completed,
            wall_seconds=(time.monotonic() - self._t0) if self._t0 else 0.0,
            checkpoints_written=self.campaign.checkpoints_written if self.campaign else 0,
            resumed_from=self.campaign._resumed_from if self.campaign else None,
            server_metrics=dict(self.server.metrics.__dict__) if self.server else {},
            queue_metrics=dict(self.queues.metrics.__dict__) if self.queues else {},
        )
        self._notify("paused" if self.paused else "stopped")
        return self.report

    # ---------------------------------------------------------------- observe
    def rebind_event_log(self, log: Any) -> Any:
        """Point every composed component at a fresh event log (components
        read ``event_log`` at emit time). Returns the previous log. Used
        by benchmarks that separate a warm-up phase from the measured
        phase without tearing the stack down."""
        prev, self.event_log = self.event_log, log
        self._owns_log = False
        if self.queues is not None:
            self.queues.event_log = log
        if hasattr(self.server, "event_log"):
            self.server.event_log = log
        for pool in self.pools.values():
            pool.event_log = log
        if self.thinker is not None:
            self.thinker.rec.event_log = log
        if self.reallocator is not None:
            self.reallocator.rebind_event_log(log)
        if self.aggregator is not None:
            from repro.observe import MetricsAggregator

            self.aggregator = MetricsAggregator(log)
        if self.exporter is not None:
            self.exporter.rebind(log, aggregator=self.aggregator)
        if self.slo is not None:
            self.slo.rebind(log, aggregator=self.aggregator)
        if self.anomaly is not None:
            self.anomaly.rebind(log, aggregator=self.aggregator)
        if self.ops is not None:
            self.ops.rebind(self.aggregator)
        if self.elastic is not None:
            self.elastic.rebind_event_log(log)
            # Fresh log, fresh left edge: without a baseline gauge the
            # fleet-capacity integral is undefined until the next resize
            # and utilization would fall back to the static pool size.
            self.elastic.emit_baseline()
        return prev

    def observe_report(self) -> dict:
        """The composed utilization/steering report over the event log."""
        if self.event_log is None:
            return {}
        from repro.observe import build_report

        return build_report(self.event_log, slots_by_pool=dict(self.pool_sizes))
