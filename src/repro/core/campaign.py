"""Campaign driver: ties Thinker + TaskServer together with fault tolerance.

A *campaign* is one AI-steered computational run (the paper's Fig. 2
molecular-design run is a campaign). The driver owns the lifecycle:

    campaign = Campaign(thinker=..., server=..., state_dir=...)
    campaign.run()

and supplies the fault-tolerance guarantees a 1000+-node deployment needs
at this layer:

  * periodic **campaign-state checkpoints** (what finished, what is
    queued, any user state the Thinker exposes through
    ``get_state``/``set_state``), written atomically;
  * **resume**: a restarted campaign reloads the newest checkpoint and
    re-submits in-flight work (tasks are required to be idempotent, as in
    the paper's quantum-chemistry/inference workloads);
  * crash containment: agent/executor exceptions mark the campaign failed
    without losing the checkpoint history.
"""

from __future__ import annotations

import hashlib
import json
import logging
import os
import pickle
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

from .task_server import TaskServer
from .thinker import BaseThinker

logger = logging.getLogger("repro.campaign")


@dataclass
class CampaignReport:
    completed: bool
    wall_seconds: float
    checkpoints_written: int
    resumed_from: Optional[str]
    server_metrics: dict
    queue_metrics: dict


class Campaign:
    def __init__(
        self,
        thinker: BaseThinker,
        server: TaskServer,
        state_dir: Optional[str] = None,
        checkpoint_interval_s: float = 5.0,
        name: str = "campaign",
        retain: int = 4,
    ) -> None:
        self.thinker = thinker
        self.server = server
        self.state_dir = state_dir
        self.checkpoint_interval_s = checkpoint_interval_s
        self.name = name
        # At least 2 retained checkpoints: the corrupt-checkpoint fallback
        # (try_resume walking newest -> oldest) needs a survivor to land on.
        self.retain = max(2, retain)
        self.checkpoints_written = 0
        self._resumed_from: Optional[str] = None
        self.resume_fallbacks = 0  # corrupt checkpoints skipped on resume
        # Serializes writers: the periodic checkpoint_loop thread vs. an
        # externally-driven pause() (the control plane checkpoints on
        # demand while the loop is still running).
        self._ckpt_lock = threading.Lock()
        if state_dir:
            os.makedirs(state_dir, exist_ok=True)

    # ------------------------------------------------------------ checkpoint
    def _ckpt_path(self, step: int) -> str:
        return os.path.join(self.state_dir, f"{self.name}-state-{step:06d}.pkl")

    def checkpoint(self) -> Optional[str]:
        if not self.state_dir:
            return None
        with self._ckpt_lock:
            get_state = getattr(self.thinker, "get_state", None)
            state = get_state() if callable(get_state) else {}
            record = {
                "time": time.time(),
                "thinker_state": state,
                "server_metrics": self.server.metrics.__dict__,
            }
            # Envelope with a content digest: a torn write usually fails to
            # unpickle, but a bit-flipped file can unpickle into garbage —
            # the digest turns both into a detectable load failure that
            # try_resume can fall back from.
            payload = pickle.dumps(record, protocol=pickle.HIGHEST_PROTOCOL)
            envelope = {"ckpt": 2, "sha256": hashlib.sha256(payload).hexdigest(), "payload": payload}
            step = self.checkpoints_written
            path = self._ckpt_path(step)
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                pickle.dump(envelope, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, path)  # atomic publish
            self.checkpoints_written += 1
            # Retain the last ``retain`` checkpoints: exactly one step expires
            # per write, so remove just it — not every step since the campaign
            # began (which was O(n^2) unlink attempts over a long run).
            expired = step - self.retain
            if expired >= 0:
                try:
                    os.remove(self._ckpt_path(expired))
                except FileNotFoundError:
                    pass
            return path

    def pause(self) -> Optional[str]:
        """Externally-driven pause point: write a checkpoint *now* (safe
        against the periodic loop) and return its path. The control plane
        calls this before releasing a preempted campaign's slots, so the
        resume that follows restores the freshest possible state rather
        than one up to ``checkpoint_interval_s`` stale."""
        try:
            return self.checkpoint()
        except Exception:  # noqa: BLE001 - pause must not kill the teardown
            logger.exception("pause checkpoint failed")
            return None

    def _checkpoint_candidates(self) -> List[str]:
        """Retained checkpoint paths, newest first."""
        if not self.state_dir or not os.path.isdir(self.state_dir):
            return []
        cands = sorted(
            (p for p in os.listdir(self.state_dir)
             if p.startswith(f"{self.name}-state-") and p.endswith(".pkl")),
            reverse=True,
        )
        return [os.path.join(self.state_dir, p) for p in cands]

    def latest_checkpoint(self) -> Optional[str]:
        cands = self._checkpoint_candidates()
        return cands[0] if cands else None

    @staticmethod
    def load_checkpoint(path: str) -> Dict[str, Any]:
        """Load and validate one checkpoint file; raises ``ValueError`` on
        a torn/corrupt file (unpicklable, digest mismatch, or not a
        checkpoint record). Pre-digest (v1) records load as-is."""
        try:
            with open(path, "rb") as f:
                doc = pickle.load(f)
        except Exception as exc:  # noqa: BLE001 - torn/corrupt pickles vary
            raise ValueError(f"unreadable checkpoint {path}: {type(exc).__name__}: {exc}") from exc
        if isinstance(doc, dict) and "payload" in doc and "sha256" in doc:
            payload = doc["payload"]
            if not isinstance(payload, bytes) or hashlib.sha256(payload).hexdigest() != doc["sha256"]:
                raise ValueError(f"checkpoint {path} failed its content digest (corrupt)")
            try:
                record = pickle.loads(payload)
            except Exception as exc:  # noqa: BLE001
                raise ValueError(f"corrupt checkpoint payload in {path}: {exc}") from exc
        else:
            record = doc  # legacy v1 record (no envelope)
        if not isinstance(record, dict) or "thinker_state" not in record:
            raise ValueError(f"{path} is not a campaign checkpoint record")
        return record

    def try_resume(self) -> bool:
        """Resume from the newest *loadable* checkpoint.

        A torn or corrupt checkpoint (a writer killed mid-publish, a
        flipped bit on disk) logs a warning and falls back to the next
        retained checkpoint instead of silently resuming from nothing —
        or crashing the resume. Returns False only when no checkpoint
        survives at all.
        """
        for path in self._checkpoint_candidates():
            try:
                record = self.load_checkpoint(path)
            except ValueError as exc:
                self.resume_fallbacks += 1
                logger.warning(
                    "skipping corrupt campaign checkpoint %s (%s); "
                    "falling back to the previous retained checkpoint", path, exc,
                )
                continue
            set_state = getattr(self.thinker, "set_state", None)
            if callable(set_state):
                set_state(record["thinker_state"])
            # Continue the step numbering past the resumed checkpoint so new
            # checkpoints never overwrite surviving history.
            prefix = f"{self.name}-state-"
            stem = os.path.basename(path)
            try:
                self.checkpoints_written = int(stem[len(prefix):-len(".pkl")]) + 1
            except ValueError:
                pass
            self._resumed_from = path
            logger.info("campaign resumed from %s", path)
            return True
        return False

    def checkpoint_loop(self, stop: threading.Event) -> None:
        """Write periodic checkpoints until ``stop`` is set. Failures are
        logged, never raised — checkpointing must not kill the run. Used
        by ``run`` and reused by ``repro.app.ColmenaApp``."""
        while not stop.is_set():
            stop.wait(self.checkpoint_interval_s)
            if stop.is_set():
                break
            try:
                self.checkpoint()
            except Exception:  # noqa: BLE001
                logger.exception("campaign checkpoint failed")

    def final_checkpoint(self) -> None:
        """Best-effort last checkpoint at shutdown."""
        if not self.state_dir:
            return
        try:
            self.checkpoint()
        except Exception:  # noqa: BLE001
            logger.exception("final campaign checkpoint failed")

    # ------------------------------------------------------------------ run
    def run(self, timeout: Optional[float] = None, resume: bool = True) -> CampaignReport:
        t0 = time.monotonic()
        if resume:
            self.try_resume()
        self.server.start()

        stop_ckpt = threading.Event()
        ckpt_thread = None
        if self.state_dir:
            ckpt_thread = threading.Thread(
                target=self.checkpoint_loop, args=(stop_ckpt,), daemon=True, name="campaign-ckpt"
            )
            ckpt_thread.start()

        completed = False
        try:
            self.thinker.run(timeout=timeout)
            completed = True
        finally:
            stop_ckpt.set()
            if ckpt_thread:
                ckpt_thread.join(timeout=2)
            self.final_checkpoint()
            self.queues_kill()
            self.server.stop()

        return CampaignReport(
            completed=completed,
            wall_seconds=time.monotonic() - t0,
            checkpoints_written=self.checkpoints_written,
            resumed_from=self._resumed_from,
            server_metrics=dict(self.server.metrics.__dict__),
            queue_metrics=dict(self.thinker.queues.metrics.__dict__),
        )

    def queues_kill(self) -> None:
        try:
            self.thinker.queues.send_kill_signal()
        except Exception:  # noqa: BLE001
            pass
