"""Worker pools: the execution substrate under the Task Server.

Workers are long-lived, *stateful* slots — the paper's "intelligent
initialization" lesson: each worker owns a ``registry`` dict that caches
expensive objects (deserialized models, compiled JAX functions, lookup
tables) between task invocations, instead of reloading per task. Task
functions opt in with the ``@stateful_task`` decorator, which injects the
worker registry as a keyword argument.

On top of the registry sits the **warm-worker cache**: a per-worker LRU
of resolved proxy payloads keyed by ``(method, store, proxy key)``, the
paper's "workflow tasks that cache costly operations between
invocations". Repeated inference tasks that reference the same proxied
model weights resolve them through the fabric once per worker instead of
once per task; hits and misses are emitted as ``repro.observe`` cache
events. The cache dies with its worker, so failed-over tasks re-resolve
cold on their new worker.

Work arrives in *batches*: ``submit_batch`` enqueues several same-method
tasks as one queue item (a single worker round-trip), and the worker
runs them back-to-back with correct per-task timestamps. A mid-batch
node death fails the remaining tasks with ``WORKER_DIED`` so the
TaskServer's retry machinery re-runs them elsewhere.

The pool also provides the failure surface used for fault-tolerance
testing: probabilistic task failures, explicit worker kills (node loss),
per-worker slowdowns (stragglers / heterogeneous nodes), heartbeats, and
elastic resize.
"""

from __future__ import annotations

import logging
import queue
import random
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from .proxystore import Proxy, iter_proxies, prefetch_all, resolve_all
from .result import FailureKind, Result

logger = logging.getLogger("repro.executors")


def stateful_task(fn: Callable) -> Callable:
    """Mark a task function as wanting the worker registry injected as the
    keyword argument ``registry`` (worker-side cache between invocations)."""
    fn._wants_registry = True
    return fn


# --------------------------------------------------------------------------
# Warm-worker cache
# --------------------------------------------------------------------------


@dataclass
class WarmCacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class WarmCache:
    """Per-worker sticky LRU of resolved proxy payloads.

    Keys are ``(method, store_name, proxy_key)`` so two methods sharing a
    payload keep independent entries (they may post-process it
    differently via the registry). Only accessed from the owning worker
    thread — no lock needed.
    """

    _MISS = object()

    def __init__(self, capacity: int = 32) -> None:
        self.capacity = capacity
        self.stats = WarmCacheStats()
        self._data: "OrderedDict[tuple, Any]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def lookup(self, key: tuple) -> Any:
        """Return the cached value or ``WarmCache._MISS``."""
        if key in self._data:
            self._data.move_to_end(key)
            self.stats.hits += 1
            return self._data[key]
        self.stats.misses += 1
        return self._MISS

    def insert(self, key: tuple, value: Any) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.stats.evictions += 1


def resolve_warm(
    obj: Any, method: str, warm: WarmCache,
    events: List[Tuple[str, Proxy]],
) -> Any:
    """Like ``resolve_all`` but Proxy leaves go through the warm cache.

    Appends ``("hit"|"miss", proxy)`` per leaf to ``events`` so the
    caller can emit observe events with full task context.
    """
    if isinstance(obj, Proxy):
        key = (method, obj.store_name, obj.key)
        value = warm.lookup(key)
        if value is not WarmCache._MISS:
            events.append(("hit", obj))
            return value
        value = obj.resolve()
        warm.insert(key, value)
        events.append(("miss", obj))
        return value
    if isinstance(obj, tuple):
        return tuple(resolve_warm(x, method, warm, events) for x in obj)
    if isinstance(obj, list):
        return [resolve_warm(x, method, warm, events) for x in obj]
    if isinstance(obj, dict):
        return {k: resolve_warm(v, method, warm, events) for k, v in obj.items()}
    return obj


class WorkerDied(RuntimeError):
    """Raised inside a worker when failure injection kills the 'node'."""


@dataclass
class FailureInjector:
    """Deterministic failure/straggler injection for tests and benchmarks.

    Beyond per-task probabilistic failures and per-worker dooming, the
    injector supports *zombie storms*: timed cohort kills. ``storms`` is
    a list of ``(at_s, n_workers)`` pairs relative to the injector's
    activation (its first ``before_task`` call); when a storm's deadline
    passes, the next ``n_workers`` distinct workers to pick up a task die
    with ``WorkerDied``. Because injectors ride inside pickled
    ``PoolSpec``s, a storm schedule configured at spec time fires inside
    a *spawned* federated server with no control channel needed — the
    chaos tier's way of dooming remote worker cohorts.
    """

    task_failure_rate: float = 0.0      # P(task raises WorkerDied)
    seed: int = 0
    # worker_id -> extra seconds added to every task (straggling node)
    slow_workers: Dict[int, float] = field(default_factory=dict)
    # worker ids that die permanently the next time they pick up a task
    doomed_workers: set = field(default_factory=set)
    # timed zombie storms: (seconds_after_activation, workers_to_kill)
    storms: List[Tuple[float, int]] = field(default_factory=list)

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self._t0: Optional[float] = None       # activation time (first task)
        self._doom_any = 0                     # wildcard dooms (storm fallout)
        self._storms_left = sorted(self.storms)
        self.storms_fired = 0

    # Injectors ride inside PoolSpecs across process boundaries (spawned
    # task servers); the lock is per-process, the rng restarts from seed.
    def __getstate__(self) -> dict:
        state = dict(self.__dict__)
        state.pop("_rng", None)
        state.pop("_lock", None)
        state.pop("_t0", None)
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self._t0 = None  # storms re-anchor to the receiving process's clock

    def doom_cohort(self, n: int) -> None:
        """Doom the next ``n`` distinct workers to pick up a task —
        whoever they are (a runtime zombie storm for in-process pools)."""
        with self._lock:
            self._doom_any += max(0, n)

    def _check_storms_locked(self, now: float) -> None:
        if self._t0 is None:
            self._t0 = now
        while self._storms_left and now - self._t0 >= self._storms_left[0][0]:
            _, n = self._storms_left.pop(0)
            self._doom_any += n
            self.storms_fired += 1
            logger.warning("failure injector: zombie storm fired, dooming %d workers", n)

    def before_task(self, worker_id: int, result: Result) -> None:
        with self._lock:
            self._check_storms_locked(time.monotonic())
            if self._doom_any > 0:
                self._doom_any -= 1
                raise WorkerDied(f"worker {worker_id} lost (injected storm)")
            if worker_id in self.doomed_workers:
                self.doomed_workers.discard(worker_id)
                raise WorkerDied(f"worker {worker_id} lost (injected node failure)")
            if self.task_failure_rate and self._rng.random() < self.task_failure_rate:
                raise WorkerDied(f"task {result.task_id} lost to injected failure")

    def after_task(self, worker_id: int) -> None:
        delay = self.slow_workers.get(worker_id, 0.0)
        if delay > 0:
            time.sleep(delay)


@dataclass
class WorkerState:
    worker_id: int
    busy: bool = False
    alive: bool = True
    current_task: Optional[str] = None
    # Task ids of the batch this worker is executing that have not yet
    # finished (heartbeat failover fails them all over together).
    current_batch: List[str] = field(default_factory=list)
    last_heartbeat: float = field(default_factory=time.monotonic)
    tasks_done: int = 0
    registry: Dict[str, Any] = field(default_factory=dict)
    warm: Optional[WarmCache] = None


@dataclass
class PoolSpec:
    """Declarative, picklable description of one worker pool.

    This is the unit of resource composition everywhere: ``AppSpec.pools``
    normalizes to it, process-mode task servers rebuild pools from it
    inside the spawned child (specs cross process boundaries; live
    ``WorkerPool`` objects cannot), and the elastic fleet machinery
    resizes within its ``[min_size, max_size]`` band.

    ``warm_capacity``/``prefetch`` left as ``None`` inherit the app's
    ``FabricSpec`` knobs (or the WorkerPool defaults when composed
    directly). ``min_size``/``max_size`` left as ``None`` pin the pool at
    ``size`` — elasticity is opt-in by widening the band.
    """

    name: str
    size: int = 4
    min_size: Optional[int] = None
    max_size: Optional[int] = None
    warm_capacity: Optional[int] = None
    prefetch: Optional[bool] = None
    injector: Optional[FailureInjector] = None

    def __post_init__(self) -> None:
        if self.size < 0:
            raise ValueError(f"pool {self.name!r}: size must be >= 0 (got {self.size})")
        lo, hi = self.bounds()
        if not (lo <= self.size <= hi):
            raise ValueError(
                f"pool {self.name!r}: size {self.size} outside [min_size, max_size] = [{lo}, {hi}]"
            )

    def bounds(self) -> Tuple[int, int]:
        lo = self.size if self.min_size is None else self.min_size
        hi = self.size if self.max_size is None else self.max_size
        if lo > hi:
            raise ValueError(f"pool {self.name!r}: min_size {lo} > max_size {hi}")
        return lo, hi

    @property
    def elastic(self) -> bool:
        lo, hi = self.bounds()
        return lo != hi

    def clamp(self, target: int) -> int:
        lo, hi = self.bounds()
        return max(lo, min(hi, target))

    def build(
        self,
        event_log: Optional[Any] = None,
        injector: Optional[FailureInjector] = None,
        warm_capacity: int = 32,
        prefetch: bool = True,
    ) -> "WorkerPool":
        """Construct the live pool. ``injector``/``warm_capacity``/
        ``prefetch`` arguments are the app-level defaults; the spec's own
        fields win when set."""
        return WorkerPool(
            self.name,
            self.size,
            injector=self.injector if self.injector is not None else injector,
            prefetch_proxies=self.prefetch if self.prefetch is not None else prefetch,
            warm_capacity=self.warm_capacity if self.warm_capacity is not None else warm_capacity,
            event_log=event_log,
        )

    # -- serialization (repro.core.specfile) --------------------------------
    def to_dict(self) -> Dict[str, Any]:
        if self.injector is not None:
            raise ValueError(
                f"pool {self.name!r}: a FailureInjector is not serializable; "
                "drop it from the spec before saving"
            )
        out: Dict[str, Any] = {"size": self.size}
        for key in ("min_size", "max_size", "warm_capacity", "prefetch"):
            val = getattr(self, key)
            if val is not None:
                out[key] = val
        return out

    @classmethod
    def from_dict(cls, name: str, d: Any) -> "PoolSpec":
        if isinstance(d, int):
            return cls(name=name, size=d)
        if not isinstance(d, Mapping):
            raise TypeError(f"pool {name!r}: expected an int or a table, got {type(d).__name__}")
        allowed = {"size", "min_size", "max_size", "warm_capacity", "prefetch"}
        unknown = set(d) - allowed
        if unknown:
            raise ValueError(f"pool {name!r}: unknown keys {sorted(unknown)}")
        return cls(name=name, **dict(d))


def normalize_pools(
    pools: Any,
    default_size: int = 4,
) -> Dict[str, PoolSpec]:
    """Normalize every accepted ``pools`` shorthand to ``{name: PoolSpec}``.

    Accepted: ``None`` (one default pool), ``{name: int}`` (the historical
    shorthand), ``{name: PoolSpec}`` (names must agree), a mix of the two,
    or a sequence of ``PoolSpec``s.
    """
    if pools is None:
        return {"default": PoolSpec("default", default_size)}
    out: Dict[str, PoolSpec] = {}
    if isinstance(pools, Mapping):
        items = pools.items()
    else:
        items = [(getattr(p, "name", None), p) for p in pools]
    for name, val in items:
        if isinstance(val, PoolSpec):
            if name is not None and name != val.name:
                raise ValueError(f"pool key {name!r} disagrees with PoolSpec.name {val.name!r}")
            spec = val
        elif isinstance(val, int):
            if name is None:  # sequence form carries no names: PoolSpecs only
                raise TypeError(
                    f"a pools sequence must contain PoolSpecs, got {val!r}; "
                    "use a {name: size} mapping for the int shorthand"
                )
            spec = PoolSpec(str(name), val)
        else:
            raise TypeError(
                f"pool {name!r}: expected an int or PoolSpec, got {type(val).__name__}"
            )
        if spec.name in out:
            raise ValueError(f"duplicate pool {spec.name!r}")
        out[spec.name] = spec
    return out


class WorkerPool:
    """A named pool of stateful worker threads executing Results.

    ``submit(result, fn, on_done)`` enqueues work; a free worker runs
    ``fn(*result.args, **result.kwargs)`` and invokes ``on_done(result)``.
    ``submit_batch`` enqueues several same-method tasks as one round-trip.
    Proxies in the args are prefetched (async resolution) before the call
    so fabric I/O overlaps any remaining queue wait; with
    ``warm_capacity > 0`` each worker keeps an LRU of resolved payloads
    keyed by (method, proxy id) so reused inputs resolve once per worker.
    """

    def __init__(
        self,
        name: str = "default",
        n_workers: int = 4,
        injector: Optional[FailureInjector] = None,
        prefetch_proxies: bool = True,
        warm_capacity: int = 32,
        event_log: Optional[Any] = None,  # repro.observe.EventLog (duck-typed)
    ) -> None:
        self.name = name
        self.injector = injector or FailureInjector()
        self.prefetch_proxies = prefetch_proxies
        self.warm_capacity = warm_capacity
        self.event_log = event_log
        self._queue: "queue.Queue[Any]" = queue.Queue()
        # Recently-prefetched proxy keys: with warm caching on, a payload
        # already flowing toward a worker cache is not prefetched again
        # for every task in every batch that references it.
        self._recent_prefetch: "OrderedDict[tuple, None]" = OrderedDict()
        self._prefetch_lock = threading.Lock()
        self._workers: Dict[int, WorkerState] = {}
        self._threads: Dict[int, threading.Thread] = {}
        self._next_id = 0
        self._lock = threading.Lock()
        self._shutdown = threading.Event()
        # Outstanding scale-down requests. Workers claim one at the top of
        # their loop — *before* popping a task — so a shrink lands as soon
        # as any worker goes between tasks, not after the whole backlog
        # drains (the old poison-pill-in-the-task-queue behaviour).
        self._pending_removals = 0
        self.add_workers(n_workers)

    # --------------------------------------------------------------- sizing
    @property
    def n_workers(self) -> int:
        """Effective capacity: live workers minus shrinks already
        requested but not yet claimed (a pending removal is capacity the
        pool has committed to give back)."""
        with self._lock:
            alive = sum(1 for w in self._workers.values() if w.alive)
            return max(0, alive - self._pending_removals)

    def add_workers(self, n: int) -> List[int]:
        """Elastic scale-up. Pending shrinks are cancelled first — a grow
        immediately after a shrink nets out instead of churning threads."""
        ids = []
        for _ in range(n):
            with self._lock:
                if self._pending_removals > 0:
                    self._pending_removals -= 1
                    continue
                wid = self._next_id
                self._next_id += 1
                state = WorkerState(
                    worker_id=wid,
                    warm=WarmCache(self.warm_capacity) if self.warm_capacity > 0 else None,
                )
                self._workers[wid] = state
            t = threading.Thread(
                target=self._worker_loop, args=(state,), daemon=True,
                name=f"{self.name}-worker-{wid}",
            )
            self._threads[wid] = t
            t.start()
            ids.append(wid)
        return ids

    def remove_workers(self, n: int) -> None:
        """Elastic scale-down: ``n`` workers exit after at most one more
        task. Removals are tracked as a counter claimed by idle workers
        ahead of queued work, so a shrink queued behind a deep backlog
        still lands promptly and ``n_workers`` reflects the committed
        capacity immediately. Requests beyond the live worker count are
        clamped — unclaimable phantom removals would otherwise absorb
        every later ``add_workers`` grow."""
        if n <= 0:
            return
        with self._lock:
            alive = sum(1 for w in self._workers.values() if w.alive)
            self._pending_removals = min(self._pending_removals + n, alive)

    def resize(self, target: int) -> Tuple[int, int]:
        """Elastic resize to ``target`` workers; returns ``(old, new)``
        effective counts. Built on ``add_workers``/``remove_workers`` so
        shrinks never interrupt a running task."""
        if target < 0:
            target = 0
        with self._lock:
            alive = sum(1 for w in self._workers.values() if w.alive)
            current = max(0, alive - self._pending_removals)
        if target > current:
            self.add_workers(target - current)
        elif target < current:
            self.remove_workers(current - target)
        return current, target

    def _claim_removal(self, state: WorkerState) -> bool:
        """Consume one pending removal for this worker (it will exit).

        The worker deregisters itself entirely: a clean scale-down is not
        a death, so the heartbeat monitor must neither fail over its
        (empty) task slate nor replace it. Dead workers never claim — a
        killed 'node' consuming the removal would leave the live fleet
        unshrunk and rob the heartbeat monitor of its failover record."""
        with self._lock:
            if self._pending_removals <= 0 or not state.alive:
                return False
            self._pending_removals -= 1
            state.alive = False
            self._workers.pop(state.worker_id, None)
            self._threads.pop(state.worker_id, None)
        return True

    def kill_worker(self, worker_id: int) -> None:
        """Simulate immediate node loss: mark dead; the heartbeat monitor /
        in-flight bookkeeping treats its running task as failed."""
        with self._lock:
            w = self._workers.get(worker_id)
            if w:
                w.alive = False
        self._forget_prefetched()

    def _forget_prefetched(self) -> None:
        """Drop the prefetch-dedup window. Called when a worker dies: its
        warm cache died with it, so payloads it kept warm must become
        prefetchable again for the tasks that fail over elsewhere."""
        with self._prefetch_lock:
            self._recent_prefetch.clear()

    # --------------------------------------------------------------- submit
    def _emit(self, stage: str, result: Result, **info: Any) -> None:
        log = self.event_log
        if log is not None:
            # pool = the executing pool (may differ from the requested one)
            log.task_event(stage, result, pool=self.name,
                           requested_pool=result.resources.pool, **info)

    def submit(self, result: Result, fn: Callable, on_done: Callable[[Result], None]) -> None:
        self.submit_batch([result], fn, on_done)

    def submit_batch(
        self, batch: List[Result], fn: Callable, on_done: Callable[[Result], None]
    ) -> None:
        """Enqueue several same-method tasks as ONE worker round-trip.

        Every proxy across the batch is prefetched up front so fabric
        resolution overlaps the earlier tasks' compute."""
        size = len(batch)
        for result in batch:
            result.mark("dispatched")
            self._emit("dispatched", result, batch_size=size)
        if self.prefetch_proxies:
            self._prefetch_batch(batch)
        self._queue.put((list(batch), fn, on_done))

    def _prefetch_batch(self, batch: List[Result]) -> None:
        """Start async resolution so fabric I/O overlaps compute. With warm
        caching on, each payload key is prefetched once per batch and
        skipped while still in the recent-prefetch window (workers keep it
        warm); without warm caching every proxy instance is prefetched."""
        dedup = self.warm_capacity > 0
        for result in batch:
            for p in iter_proxies((result.args, result.kwargs)):
                if dedup:
                    key = (p.store_name, p.key)
                    with self._prefetch_lock:
                        if key in self._recent_prefetch:
                            continue
                        self._recent_prefetch[key] = None
                        while len(self._recent_prefetch) > 256:
                            self._recent_prefetch.popitem(last=False)
                p.prefetch()

    def queued(self) -> int:
        return self._queue.qsize()

    # ----------------------------------------------------------- worker loop
    def _emit_cache_events(
        self, result: Result, state: WorkerState, events: List[Tuple[str, Proxy]]
    ) -> None:
        log = self.event_log
        cache_event = getattr(log, "cache_event", None) if log is not None else None
        if cache_event is None:
            return
        for outcome, proxy in events:
            cache_event(outcome, result, pool=self.name,
                        worker_id=state.worker_id, key=proxy.key,
                        nbytes=proxy.nbytes)

    def _run_task(self, state: WorkerState, result: Result, fn: Callable) -> bool:
        """Execute one task on this worker; returns False when the 'node'
        died (the caller fails the rest of its batch and exits)."""
        state.current_task = result.task_id
        state.last_heartbeat = time.monotonic()
        result.worker_id = state.worker_id
        result.mark("compute_started")
        self._emit("running", result, worker_id=state.worker_id)
        try:
            self.injector.before_task(state.worker_id, result)
            wants_reg = getattr(fn, "_wants_registry", False)
            if state.warm is not None:
                cache_events: List[Tuple[str, Proxy]] = []
                args = resolve_warm(result.args, result.method, state.warm, cache_events)
                kwargs = resolve_warm(result.kwargs, result.method, state.warm, cache_events)
                self._emit_cache_events(result, state, cache_events)
            else:
                args = resolve_all(result.args)
                kwargs = resolve_all(result.kwargs)
            if wants_reg:
                kwargs = dict(kwargs)
                kwargs["registry"] = state.registry
            value = fn(*args, **kwargs)
            self.injector.after_task(state.worker_id)
            result.mark("compute_ended")
            result.set_success(value)
            self._emit("completed", result, worker_id=state.worker_id)
        except WorkerDied as exc:
            result.mark("compute_ended")
            result.set_failure(FailureKind.WORKER_DIED, str(exc))
            self._emit("failed", result, worker_id=state.worker_id,
                       kind=FailureKind.WORKER_DIED.value)
            with self._lock:
                state.alive = False
            return False
        except Exception as exc:  # noqa: BLE001 - task exception
            result.mark("compute_ended")
            result.set_failure(FailureKind.EXCEPTION, f"{type(exc).__name__}: {exc}")
            self._emit("failed", result, worker_id=state.worker_id,
                       kind=FailureKind.EXCEPTION.value)
        return True

    def _worker_loop(self, state: WorkerState) -> None:
        while not self._shutdown.is_set():
            # Scale-down claims happen between tasks, ahead of the next
            # pop: the worker's warm cache dies with it.
            if self._claim_removal(state):
                self._forget_prefetched()
                return
            try:
                item = self._queue.get(timeout=0.05)
            except queue.Empty:
                state.last_heartbeat = time.monotonic()
                continue
            batch, fn, on_done = item
            if not state.alive:  # killed while idle: drop back and exit
                self._queue.put(item)
                return
            state.busy = True
            state.current_batch = [r.task_id for r in batch]
            died = False
            for result in batch:
                if died:
                    # The 'node' is gone: fail the rest of the batch so
                    # the TaskServer retries each task cold elsewhere.
                    result.set_failure(
                        FailureKind.WORKER_DIED,
                        f"worker {state.worker_id} died mid-batch",
                    )
                    self._emit("failed", result, worker_id=state.worker_id,
                               kind=FailureKind.WORKER_DIED.value)
                    try:
                        state.current_batch.remove(result.task_id)
                    except ValueError:
                        pass
                    on_done(result)
                    continue
                alive = self._run_task(state, result, fn)
                try:
                    state.current_batch.remove(result.task_id)
                except ValueError:
                    pass
                state.current_task = None
                state.last_heartbeat = time.monotonic()
                if alive:
                    state.tasks_done += 1
                else:
                    died = True
                on_done(result)
            state.busy = False
            state.current_batch = []
            state.current_task = None
            if died:
                self._forget_prefetched()
                return  # thread exits with its warm cache/registry

    # ------------------------------------------------------------ monitoring
    def worker_states(self) -> List[WorkerState]:
        with self._lock:
            return list(self._workers.values())

    def dead_workers(self, heartbeat_timeout_s: float = 5.0) -> List[WorkerState]:
        now = time.monotonic()
        out = []
        with self._lock:
            for w in self._workers.values():
                if not w.alive:
                    out.append(w)
                elif w.busy and now - w.last_heartbeat > heartbeat_timeout_s:
                    thread = self._threads.get(w.worker_id)
                    if thread is not None and thread.is_alive():
                        # The 'node' still pings — a long-running task is
                        # not a death (straggler speculation covers hangs).
                        w.last_heartbeat = now
                    else:
                        out.append(w)
        return out

    def shutdown(self) -> None:
        self._shutdown.set()
