"""Worker pools: the execution substrate under the Task Server.

Workers are long-lived, *stateful* slots — the paper's "intelligent
initialization" lesson: each worker owns a ``registry`` dict that caches
expensive objects (deserialized models, compiled JAX functions, lookup
tables) between task invocations, instead of reloading per task. Task
functions opt in with the ``@stateful_task`` decorator, which injects the
worker registry as a keyword argument.

The pool also provides the failure surface used for fault-tolerance
testing: probabilistic task failures, explicit worker kills (node loss),
per-worker slowdowns (stragglers / heterogeneous nodes), heartbeats, and
elastic resize.
"""

from __future__ import annotations

import logging
import queue
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from .proxystore import prefetch_all, resolve_all
from .result import FailureKind, Result

logger = logging.getLogger("repro.executors")


def stateful_task(fn: Callable) -> Callable:
    """Mark a task function as wanting the worker registry injected as the
    keyword argument ``registry`` (worker-side cache between invocations)."""
    fn._wants_registry = True
    return fn


class WorkerDied(RuntimeError):
    """Raised inside a worker when failure injection kills the 'node'."""


@dataclass
class FailureInjector:
    """Deterministic failure/straggler injection for tests and benchmarks."""

    task_failure_rate: float = 0.0      # P(task raises WorkerDied)
    seed: int = 0
    # worker_id -> extra seconds added to every task (straggling node)
    slow_workers: Dict[int, float] = field(default_factory=dict)
    # worker ids that die permanently the next time they pick up a task
    doomed_workers: set = field(default_factory=set)

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()

    def before_task(self, worker_id: int, result: Result) -> None:
        with self._lock:
            if worker_id in self.doomed_workers:
                self.doomed_workers.discard(worker_id)
                raise WorkerDied(f"worker {worker_id} lost (injected node failure)")
            if self.task_failure_rate and self._rng.random() < self.task_failure_rate:
                raise WorkerDied(f"task {result.task_id} lost to injected failure")

    def after_task(self, worker_id: int) -> None:
        delay = self.slow_workers.get(worker_id, 0.0)
        if delay > 0:
            time.sleep(delay)


@dataclass
class WorkerState:
    worker_id: int
    busy: bool = False
    alive: bool = True
    current_task: Optional[str] = None
    last_heartbeat: float = field(default_factory=time.monotonic)
    tasks_done: int = 0
    registry: Dict[str, Any] = field(default_factory=dict)


class WorkerPool:
    """A named pool of stateful worker threads executing Results.

    ``submit(result, fn, on_done)`` enqueues work; a free worker runs
    ``fn(*result.args, **result.kwargs)`` and invokes ``on_done(result)``.
    Proxies in the args are prefetched (async resolution) before the call
    so fabric I/O overlaps any remaining queue wait.
    """

    def __init__(
        self,
        name: str = "default",
        n_workers: int = 4,
        injector: Optional[FailureInjector] = None,
        prefetch_proxies: bool = True,
        event_log: Optional[Any] = None,  # repro.observe.EventLog (duck-typed)
    ) -> None:
        self.name = name
        self.injector = injector or FailureInjector()
        self.prefetch_proxies = prefetch_proxies
        self.event_log = event_log
        self._queue: "queue.Queue[Any]" = queue.Queue()
        self._workers: Dict[int, WorkerState] = {}
        self._threads: Dict[int, threading.Thread] = {}
        self._next_id = 0
        self._lock = threading.Lock()
        self._shutdown = threading.Event()
        self.add_workers(n_workers)

    # --------------------------------------------------------------- sizing
    @property
    def n_workers(self) -> int:
        with self._lock:
            return sum(1 for w in self._workers.values() if w.alive)

    def add_workers(self, n: int) -> List[int]:
        """Elastic scale-up."""
        ids = []
        for _ in range(n):
            with self._lock:
                wid = self._next_id
                self._next_id += 1
                state = WorkerState(worker_id=wid)
                self._workers[wid] = state
            t = threading.Thread(
                target=self._worker_loop, args=(state,), daemon=True,
                name=f"{self.name}-worker-{wid}",
            )
            self._threads[wid] = t
            t.start()
            ids.append(wid)
        return ids

    def remove_workers(self, n: int) -> None:
        """Elastic scale-down: poison-pill ``n`` workers (they exit after
        finishing their current task)."""
        for _ in range(n):
            self._queue.put(None)

    def kill_worker(self, worker_id: int) -> None:
        """Simulate immediate node loss: mark dead; the heartbeat monitor /
        in-flight bookkeeping treats its running task as failed."""
        with self._lock:
            w = self._workers.get(worker_id)
            if w:
                w.alive = False

    # --------------------------------------------------------------- submit
    def _emit(self, stage: str, result: Result, **info: Any) -> None:
        log = self.event_log
        if log is not None:
            # pool = the executing pool (may differ from the requested one)
            log.task_event(stage, result, pool=self.name,
                           requested_pool=result.resources.pool, **info)

    def submit(self, result: Result, fn: Callable, on_done: Callable[[Result], None]) -> None:
        result.mark("dispatched")
        self._emit("dispatched", result)
        if self.prefetch_proxies:
            prefetch_all(result.args)
            prefetch_all(result.kwargs)
        self._queue.put((result, fn, on_done))

    def queued(self) -> int:
        return self._queue.qsize()

    # ----------------------------------------------------------- worker loop
    def _worker_loop(self, state: WorkerState) -> None:
        while not self._shutdown.is_set():
            try:
                item = self._queue.get(timeout=0.05)
            except queue.Empty:
                state.last_heartbeat = time.monotonic()
                continue
            if item is None:  # poison pill (scale-down)
                with self._lock:
                    state.alive = False
                return
            result, fn, on_done = item
            if not state.alive:  # killed while idle: drop back and exit
                self._queue.put(item)
                return
            state.busy = True
            state.current_task = result.task_id
            state.last_heartbeat = time.monotonic()
            result.worker_id = state.worker_id
            result.mark("compute_started")
            self._emit("running", result, worker_id=state.worker_id)
            try:
                self.injector.before_task(state.worker_id, result)
                wants_reg = getattr(fn, "_wants_registry", False)
                args = resolve_all(result.args)
                kwargs = resolve_all(result.kwargs)
                if wants_reg:
                    kwargs = dict(kwargs)
                    kwargs["registry"] = state.registry
                value = fn(*args, **kwargs)
                self.injector.after_task(state.worker_id)
                result.mark("compute_ended")
                result.set_success(value)
                self._emit("completed", result, worker_id=state.worker_id)
            except WorkerDied as exc:
                result.mark("compute_ended")
                result.set_failure(FailureKind.WORKER_DIED, str(exc))
                self._emit("failed", result, worker_id=state.worker_id,
                           kind=FailureKind.WORKER_DIED.value)
                with self._lock:
                    state.alive = False
                state.busy = False
                try:
                    on_done(result)
                finally:
                    pass
                return  # the 'node' is gone; thread exits
            except Exception as exc:  # noqa: BLE001 - task exception
                result.mark("compute_ended")
                result.set_failure(FailureKind.EXCEPTION, f"{type(exc).__name__}: {exc}")
                self._emit("failed", result, worker_id=state.worker_id,
                           kind=FailureKind.EXCEPTION.value)
            state.busy = False
            state.current_task = None
            state.tasks_done += 1
            state.last_heartbeat = time.monotonic()
            on_done(result)

    # ------------------------------------------------------------ monitoring
    def worker_states(self) -> List[WorkerState]:
        with self._lock:
            return list(self._workers.values())

    def dead_workers(self, heartbeat_timeout_s: float = 5.0) -> List[WorkerState]:
        now = time.monotonic()
        out = []
        with self._lock:
            for w in self._workers.values():
                if not w.alive:
                    out.append(w)
                elif w.busy and now - w.last_heartbeat > heartbeat_timeout_s:
                    out.append(w)
        return out

    def shutdown(self) -> None:
        self._shutdown.set()
