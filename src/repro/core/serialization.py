"""Size-metered serialization for queue payloads.

Colmena reports "communication overheads" on every Result; to reproduce
that we meter every (de)serialization: bytes produced and wall time.
The serializer is proxy-aware: ``repro.core.proxystore.Proxy`` objects
serialize as tiny references (that is the whole point of the data fabric).
"""

from __future__ import annotations

import io
import pickle
import time
from dataclasses import dataclass
from typing import Any, Tuple

import numpy as np


@dataclass
class SerMetrics:
    bytes: int
    seconds: float


class Serializer:
    """Pickle-based serializer with size/time accounting.

    ``jax.Array`` / ``np.ndarray`` leaves are handled by pickle natively;
    for same-process queues we support a ``by_reference`` fast path that
    skips serialization entirely (measured size still reported, as the
    paper's in-memory Redis-on-node deployments behave this way).
    """

    def serialize(self, obj: Any) -> Tuple[bytes, SerMetrics]:
        t0 = time.monotonic()
        payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        return payload, SerMetrics(bytes=len(payload), seconds=time.monotonic() - t0)

    def deserialize(self, payload: bytes) -> Tuple[Any, SerMetrics]:
        t0 = time.monotonic()
        obj = pickle.loads(payload)
        return obj, SerMetrics(bytes=len(payload), seconds=time.monotonic() - t0)


def object_nbytes(obj: Any) -> int:
    """Cheap size estimate used by the auto-proxy threshold.

    Arrays are sized exactly without serializing; other objects fall back
    to a pickle round (bounded: we only need this for threshold checks on
    user payloads, which are small or arrays in practice).
    """
    # numpy / jax arrays expose nbytes
    nb = getattr(obj, "nbytes", None)
    if isinstance(nb, (int, np.integer)):
        return int(nb)
    if isinstance(obj, (bytes, bytearray)):
        return len(obj)
    if isinstance(obj, str):
        return len(obj.encode())
    if isinstance(obj, (list, tuple)):
        return sum(object_nbytes(o) for o in obj)
    if isinstance(obj, dict):
        return sum(object_nbytes(k) + object_nbytes(v) for k, v in obj.items())
    if isinstance(obj, (int, float, bool, type(None))):
        return 8
    buf = io.BytesIO()
    try:
        pickle.dump(obj, buf, protocol=pickle.HIGHEST_PROTOCOL)
    except Exception:
        return 64  # unknown, assume small
    return buf.tell()


SERIALIZER = Serializer()
