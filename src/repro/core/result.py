"""Result objects: the unit of communication between Thinker and Task Server.

Reproduces Colmena's ``Result`` record: it carries the task definition
(method name + args), resource requirements, free-form ``task_info``
metadata, and — critically for the paper's evaluation — a full timestamp
ledger from which the three latencies of the proxy application
(reaction / decision / dispatch, Fig. 7) are derived.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
import uuid
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Optional

_TASK_COUNTER = itertools.count()


@dataclass
class TraceContext:
    """Distributed-trace identity carried on a ``Result`` across every hop.

    Minted once at ``send_inputs`` and pickled with the Result, so the
    same ids appear in the client's, the pipe queues', and a spawned
    ``ProcessTaskServer``'s event logs — merging those JSONL sinks yields
    one causal trace per submission. Server-side re-executions (retry
    clones, speculative twins) get a *child* context: fresh ``span_id``,
    ``parent_span_id`` pointing at the attempt they descend from, same
    ``trace_id`` — so a task's whole retry tree folds into one timeline.
    """

    trace_id: str
    span_id: str
    parent_span_id: Optional[str] = None

    @classmethod
    def new(cls) -> "TraceContext":
        return cls(trace_id=uuid.uuid4().hex[:16], span_id=uuid.uuid4().hex[:8])

    def child(self) -> "TraceContext":
        return TraceContext(
            trace_id=self.trace_id,
            span_id=uuid.uuid4().hex[:8],
            parent_span_id=self.span_id,
        )

    def as_dict(self) -> dict:
        out = {"trace_id": self.trace_id, "span_id": self.span_id}
        if self.parent_span_id is not None:
            out["parent_span_id"] = self.parent_span_id
        return out


class FailureKind(str, Enum):
    """Why a task failed (used by the TaskServer retry policy)."""

    NONE = "none"
    EXCEPTION = "exception"          # task function raised
    WORKER_DIED = "worker_died"      # simulated node failure / heartbeat loss
    TIMEOUT = "timeout"              # exceeded wall-time limit
    CANCELLED = "cancelled"          # superseded by a speculative copy
    SERIALIZATION = "serialization"  # could not (de)serialize payload


@dataclass
class ResourceRequest:
    """Resources a task needs; mirrors Colmena's per-task resource hints.

    ``pool`` routes the task to a named executor/worker pool (the paper's
    multi-resource deployments: simulation on Theta CPUs, ML on a GPU
    cluster).  ``slots`` is the number of worker slots (nodes) consumed.
    """

    pool: str = "default"
    slots: int = 1
    # Wall-time limit in seconds; None = unlimited. Drives TIMEOUT failures.
    timeout_s: Optional[float] = None
    # Allow speculative re-execution if this task looks like a straggler.
    speculative_ok: bool = True


@dataclass
class Timestamps:
    """Every hop of a task's life, in ``time.monotonic()`` seconds.

    The proxy application defines:
      * reaction  = result_received - compute_ended   (completion -> Thinker)
      * decision  = next_submitted - result_received  (Thinker thinks)
      * dispatch  = compute_started - created         (request -> node)
    """

    created: Optional[float] = None           # Thinker built the request
    input_proxied: Optional[float] = None     # big inputs swapped for proxies
    queued: Optional[float] = None            # pushed onto the task queue
    picked_up: Optional[float] = None         # TaskServer popped it
    dispatched: Optional[float] = None        # handed to an executor slot
    compute_started: Optional[float] = None   # worker began running
    compute_ended: Optional[float] = None     # worker finished running
    result_proxied: Optional[float] = None    # big outputs swapped for proxies
    returned: Optional[float] = None          # pushed onto the result queue
    completion_notified: Optional[float] = None  # act-on-completion signal seen
    result_received: Optional[float] = None   # Thinker popped the result
    decision_made: Optional[float] = None     # Thinker finished reacting


@dataclass
class TimingInfo:
    """Derived timings (seconds) — populated by ``Result.finalize_timings``."""

    dispatch: Optional[float] = None
    compute: Optional[float] = None
    reaction: Optional[float] = None
    decision: Optional[float] = None
    total: Optional[float] = None
    # Bytes that flowed through the control channel vs. the data fabric.
    control_bytes: int = 0
    fabric_bytes: int = 0
    serialization_s: float = 0.0
    deserialization_s: float = 0.0


@dataclass
class Result:
    """A task request and (eventually) its outcome."""

    method: str
    args: tuple = ()
    kwargs: dict = field(default_factory=dict)
    task_info: dict = field(default_factory=dict)
    resources: ResourceRequest = field(default_factory=ResourceRequest)
    topic: str = "default"

    task_id: str = field(default_factory=lambda: f"task-{next(_TASK_COUNTER):08d}-{uuid.uuid4().hex[:8]}")
    # Minted by the queues at submission; pickled with the Result so every
    # process that touches the task logs events under the same trace_id.
    trace: Optional[TraceContext] = None
    value: Any = None
    success: Optional[bool] = None
    failure: FailureKind = FailureKind.NONE
    failure_info: Optional[str] = None
    retries: int = 0
    worker_id: Optional[int] = None
    speculative: bool = False

    time: Timestamps = field(default_factory=Timestamps)
    timing: TimingInfo = field(default_factory=TimingInfo)

    # ------------------------------------------------------------------ marks
    def mark(self, name: str) -> None:
        setattr(self.time, name, time.monotonic())

    # ---------------------------------------------------------------- success
    def set_success(self, value: Any) -> None:
        self.value = value
        self.success = True
        self.failure = FailureKind.NONE
        self.failure_info = None

    def set_failure(self, kind: FailureKind, info: str) -> None:
        self.value = None
        self.success = False
        self.failure = kind
        self.failure_info = info

    # ---------------------------------------------------------------- timings
    def finalize_timings(self) -> TimingInfo:
        t = self.time
        g = self.timing

        def span(a: Optional[float], b: Optional[float]) -> Optional[float]:
            return (b - a) if (a is not None and b is not None) else None

        g.dispatch = span(t.created, t.compute_started)
        g.compute = span(t.compute_started, t.compute_ended)
        g.reaction = span(t.compute_ended, t.completion_notified or t.result_received)
        g.decision = span(t.result_received, t.decision_made)
        g.total = span(t.created, t.decision_made or t.result_received)
        return g

    # ------------------------------------------------------------------ misc
    def clone_for_retry(self) -> "Result":
        """Fresh copy for re-submission after a failure (new timestamps)."""
        new = Result(
            method=self.method,
            args=self.args,
            kwargs=dict(self.kwargs),
            task_info=dict(self.task_info),
            resources=dataclasses.replace(self.resources),
            topic=self.topic,
        )
        new.retries = self.retries + 1
        new.trace = self.trace.child() if self.trace is not None else None
        return new

    def clone_for_speculation(self) -> "Result":
        """Copy used for straggler mitigation; keeps the same task_id so the
        first finisher wins and the loser is dropped."""
        new = Result(
            method=self.method,
            args=self.args,
            kwargs=dict(self.kwargs),
            task_info=dict(self.task_info),
            resources=dataclasses.replace(self.resources),
            topic=self.topic,
        )
        new.task_id = self.task_id
        new.speculative = True
        new.retries = self.retries
        new.trace = self.trace.child() if self.trace is not None else None
        return new

    def __repr__(self) -> str:  # keep logs short; args may be huge
        return (
            f"Result(id={self.task_id}, method={self.method}, topic={self.topic}, "
            f"success={self.success}, retries={self.retries})"
        )
