"""ProxyStore-style data fabric: pass-by-reference for task data.

Reproduces the paper's key communication optimization: large task inputs
and outputs are replaced by lightweight *proxies* in the control messages
that flow through the Task Queues; the actual payload moves through a
dedicated channel (the *connector*) and is resolved lazily on first use.

Features reproduced from the paper / ProxyStore:
  * auto-proxy threshold in the queues (10 MB in the paper; configurable),
  * manual proxying in the Thinker for objects reused across tasks
    (bulk ahead-of-time transfer: ``store.proxy(obj)``),
  * worker-side caching so tasks that reuse data (e.g. inference tasks
    sharing one model) fetch once,
  * asynchronous resolution (``Proxy.prefetch``) to overlap compute & I/O,
  * no payload I/O for failed / early-exited tasks (lazy: unresolved
    proxies never touch the fabric),
  * metrics separating control-channel bytes from fabric bytes.

Hardware adaptation (see DESIGN.md): on a TPU pod, tensors that already
live on device are proxied *by reference* (the connector stores the
``jax.Array`` handle; no serialization) — the ICI fabric is the side
channel. Host-side objects use the memory, file, or shared-memory
connectors, standing in for Redis / RDMA / Globus in the paper; the
``SharedMemoryConnector`` hands workers zero-copy ``ndarray`` views over
one POSIX shm segment.
"""

from __future__ import annotations

import os
import pickle
import tempfile
import threading
import time
import uuid

import numpy as np
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

from .serialization import object_nbytes

# --------------------------------------------------------------------------
# Connectors: where the bytes actually live.
# --------------------------------------------------------------------------


class Connector:
    """Backend storage channel. Subclasses stand in for Redis/RDMA/Globus."""

    name = "base"

    def put(self, key: str, obj: Any) -> int:
        raise NotImplementedError

    def get(self, key: str) -> Any:
        raise NotImplementedError

    def evict(self, key: str) -> None:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def spec(self) -> dict:
        """Enough info to reconstruct this connector in another process."""
        return {"kind": self.name}


class InMemoryConnector(Connector):
    """Same-process object store (the paper's Redis-on-the-Thinker-node,
    minus the socket). Objects are stored by reference: zero-copy, which
    is also how on-device ``jax.Array`` handles are passed on a pod."""

    name = "memory"

    def __init__(self) -> None:
        self._objs: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def put(self, key: str, obj: Any) -> int:
        with self._lock:
            self._objs[key] = obj
        return object_nbytes(obj)

    def get(self, key: str) -> Any:
        with self._lock:
            return self._objs[key]

    def evict(self, key: str) -> None:
        with self._lock:
            self._objs.pop(key, None)

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._objs


class FileConnector(Connector):
    """Cross-process store backed by a shared directory (stands in for the
    paper's Globus-Transfer channel / a parallel filesystem burst buffer)."""

    name = "file"

    def __init__(self, root: Optional[str] = None) -> None:
        self.root = root or tempfile.mkdtemp(prefix="repro-proxystore-")
        os.makedirs(self.root, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key + ".pkl")

    def put(self, key: str, obj: Any) -> int:
        tmp = self._path(key) + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump(obj, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, self._path(key))  # atomic publish
        return os.path.getsize(self._path(key))

    def get(self, key: str) -> Any:
        with open(self._path(key), "rb") as f:
            return pickle.load(f)

    def evict(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def spec(self) -> dict:
        return {"kind": self.name, "root": self.root}


class SharedMemoryConnector(Connector):
    """Cross-process store over POSIX shared memory with **zero-copy**
    array views (stands in for the paper's RDMA channel / a node-local
    object store like the plasma store Colmena deployments use).

    Arrays (numpy, or anything exposing ``__array__`` such as host
    ``jax.Array``\\ s) are written as raw bytes after a small pickled
    header; ``get`` attaches to the segment and returns an ``ndarray``
    *view* over the shared buffer — no copy, no deserialization. Other
    objects fall back to pickling into the segment.

    Segment lifetime: the connector keeps every attached ``SharedMemory``
    handle alive (views borrow its buffer). ``evict``/``close`` unlink the
    segment name so the OS reclaims it once every process unmaps, but the
    local mapping is *retired*, not closed — ``SharedMemory.close()``
    unmaps even under live buffer exports, which would turn later view
    reads into a segfault. Retired mappings are freed at process exit.
    """

    name = "shm"

    _HEADER_LEN = 8  # uint64 little-endian pickled-header size prefix

    def __init__(self, prefix: str = "repro") -> None:
        self.prefix = prefix
        self._segments: Dict[str, Any] = {}   # key -> SharedMemory (keeps views valid)
        self._created: set = set()            # keys this process must unlink
        self._retired: list = []              # evicted handles kept mapped for views
        self._lock = threading.Lock()

    def _seg_name(self, key: str) -> str:
        return f"{self.prefix}-{key}"

    def put(self, key: str, obj: Any) -> int:
        from multiprocessing import shared_memory

        if hasattr(obj, "__array__"):
            arr = np.ascontiguousarray(np.asarray(obj))
            header = pickle.dumps(
                {"kind": "ndarray", "shape": arr.shape, "dtype": arr.dtype.str},
                protocol=pickle.HIGHEST_PROTOCOL,
            )
            payload_nbytes = arr.nbytes
        else:
            arr = None
            blob = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
            header = pickle.dumps({"kind": "pickle"}, protocol=pickle.HIGHEST_PROTOCOL)
            payload_nbytes = len(blob)
        total = self._HEADER_LEN + len(header) + max(payload_nbytes, 1)
        shm = shared_memory.SharedMemory(name=self._seg_name(key), create=True, size=total)
        shm.buf[: self._HEADER_LEN] = len(header).to_bytes(self._HEADER_LEN, "little")
        off = self._HEADER_LEN
        shm.buf[off : off + len(header)] = header
        off += len(header)
        if arr is not None:
            dst = np.ndarray(arr.shape, dtype=arr.dtype, buffer=shm.buf, offset=off)
            dst[...] = arr
        else:
            shm.buf[off : off + payload_nbytes] = blob
        with self._lock:
            self._segments[key] = shm
            self._created.add(key)
        return payload_nbytes

    def get(self, key: str) -> Any:
        from multiprocessing import shared_memory

        with self._lock:
            shm = self._segments.get(key)
        if shm is None:
            shm = shared_memory.SharedMemory(name=self._seg_name(key))
            with self._lock:
                self._segments.setdefault(key, shm)
        hlen = int.from_bytes(bytes(shm.buf[: self._HEADER_LEN]), "little")
        off = self._HEADER_LEN
        meta = pickle.loads(bytes(shm.buf[off : off + hlen]))
        off += hlen
        if meta["kind"] == "ndarray":
            # Zero-copy view over the shared buffer (read-mostly by
            # convention: writes would be visible to every process).
            return np.ndarray(meta["shape"], dtype=np.dtype(meta["dtype"]),
                              buffer=shm.buf, offset=off)
        return pickle.loads(bytes(shm.buf[off:]))

    def evict(self, key: str) -> None:
        with self._lock:
            shm = self._segments.pop(key, None)
            created = key in self._created
            self._created.discard(key)
        if shm is not None:
            if created:
                # Unlink the name: POSIX frees the memory once the last
                # process unmaps.
                try:
                    shm.unlink()
                except FileNotFoundError:
                    pass
            # Never shm.close() here — zero-copy views handed out by get()
            # may still borrow the mapping, and close() unmaps under them.
            with self._lock:
                self._retired.append(shm)

    def exists(self, key: str) -> bool:
        from multiprocessing import shared_memory

        with self._lock:
            if key in self._segments:
                return True
        try:
            shm = shared_memory.SharedMemory(name=self._seg_name(key))
        except FileNotFoundError:
            return False
        shm.close()
        return True

    def close(self) -> None:
        """Unlink every segment this process created (mappings with live
        views stay retired until process exit)."""
        with self._lock:
            keys = list(self._segments)
        for key in keys:
            self.evict(key)

    def spec(self) -> dict:
        return {"kind": self.name, "prefix": self.prefix}


def connector_from_spec(spec) -> Connector:
    """Build a connector from a spec dict, a bare kind string (declarative
    shorthand used by ``repro.app``), or an already-built ``Connector``
    (returned as-is)."""
    if isinstance(spec, Connector):
        return spec
    if isinstance(spec, str):
        spec = {"kind": spec}
    if spec["kind"] == "memory":
        return InMemoryConnector()
    if spec["kind"] == "file":
        return FileConnector(spec.get("root"))
    if spec["kind"] == "shm":
        return SharedMemoryConnector(spec.get("prefix", "repro"))
    raise ValueError(f"unknown connector kind {spec['kind']!r}")


# --------------------------------------------------------------------------
# Store + metrics
# --------------------------------------------------------------------------


@dataclass
class StoreMetrics:
    puts: int = 0
    gets: int = 0
    cache_hits: int = 0
    fabric_bytes_out: int = 0
    fabric_bytes_in: int = 0
    put_seconds: float = 0.0
    get_seconds: float = 0.0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


_REGISTRY: Dict[str, "Store"] = {}
_REGISTRY_LOCK = threading.Lock()


class Store:
    """A named object store with worker-side caching.

    The *cache* reproduces the paper's lesson that "caching accelerates
    tasks that reuse data, such as inference tasks that use the same model
    over many input batches": repeated ``get`` of the same key is served
    locally (per-process LRU) instead of re-fetching through the fabric.
    """

    def __init__(
        self,
        name: str,
        connector: Optional[Connector] = None,
        cache_size: int = 16,
    ) -> None:
        self.name = name
        self.connector = connector or InMemoryConnector()
        self.metrics = StoreMetrics()
        self._cache: "OrderedDict[str, Any]" = OrderedDict()
        self._cache_size = cache_size
        self._lock = threading.Lock()
        register_store(self)

    # ------------------------------------------------------------- core API
    def put(self, obj: Any, key: Optional[str] = None) -> str:
        key = key or uuid.uuid4().hex
        t0 = time.monotonic()
        nbytes = self.connector.put(key, obj)
        with self._lock:
            self.metrics.puts += 1
            self.metrics.fabric_bytes_out += nbytes
            self.metrics.put_seconds += time.monotonic() - t0
        return key

    def get(self, key: str, use_cache: bool = True) -> Any:
        if use_cache:
            with self._lock:
                if key in self._cache:
                    self._cache.move_to_end(key)
                    self.metrics.cache_hits += 1
                    self.metrics.gets += 1
                    return self._cache[key]
        t0 = time.monotonic()
        obj = self.connector.get(key)
        nbytes = object_nbytes(obj)
        with self._lock:
            self.metrics.gets += 1
            self.metrics.fabric_bytes_in += nbytes
            self.metrics.get_seconds += time.monotonic() - t0
            if use_cache:
                self._cache[key] = obj
                while len(self._cache) > self._cache_size:
                    self._cache.popitem(last=False)
        return obj

    def evict(self, key: str) -> None:
        with self._lock:
            self._cache.pop(key, None)
        self.connector.evict(key)

    # ---------------------------------------------------------------- proxy
    def proxy(self, obj: Any, evict_after_resolve: bool = False) -> "Proxy":
        """Manually proxy an object (the paper's bulk / reused transfers)."""
        key = self.put(obj)
        return Proxy(
            store_name=self.name,
            key=key,
            nbytes=object_nbytes(obj),
            connector_spec=self.connector.spec(),
            evict_after_resolve=evict_after_resolve,
        )

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()

    def close(self) -> None:
        """Drop the client cache and release connector resources (e.g.
        shared-memory segments). Connectors without a ``close`` are
        left untouched; the store stays registered (keys resolve until
        the connector is gone)."""
        self.clear_cache()
        close = getattr(self.connector, "close", None)
        if callable(close):
            close()

    # Stores ride into server processes inside queue configs; locks and
    # the worker-side cache are per-process.
    def __getstate__(self) -> dict:
        state = dict(self.__dict__)
        state.pop("_lock", None)
        state.pop("_cache", None)
        state["connector"] = None
        state["_connector_spec"] = self.connector.spec()
        return state

    def __setstate__(self, state: dict) -> None:
        spec = state.pop("_connector_spec")
        self.__dict__.update(state)
        self.connector = connector_from_spec(spec)
        self._lock = threading.Lock()
        self._cache = OrderedDict()
        register_store(self)


def register_store(store: Store) -> None:
    with _REGISTRY_LOCK:
        _REGISTRY[store.name] = store


def get_store(name: str, connector_spec: Optional[dict] = None) -> Store:
    """Look up a store; reconstruct it from a spec in a fresh process."""
    with _REGISTRY_LOCK:
        if name in _REGISTRY:
            return _REGISTRY[name]
    if connector_spec is None:
        raise KeyError(f"store {name!r} not registered and no spec given")
    return Store(name, connector_from_spec(connector_spec))


# --------------------------------------------------------------------------
# Proxy
# --------------------------------------------------------------------------


class Proxy:
    """Lazy reference to an object in a Store.

    Pickles to a few hundred bytes regardless of target size — this is what
    rides the control channel. First use (``resolve`` or any forwarded
    attribute/dunder) fetches the payload through the fabric; ``prefetch``
    starts that fetch on a background thread to overlap compute and I/O.
    """

    __slots__ = (
        "store_name", "key", "nbytes", "connector_spec",
        "evict_after_resolve", "_target", "_resolved", "_prefetch_thread",
    )

    def __init__(
        self,
        store_name: str,
        key: str,
        nbytes: int,
        connector_spec: dict,
        evict_after_resolve: bool = False,
    ) -> None:
        self.store_name = store_name
        self.key = key
        self.nbytes = nbytes
        self.connector_spec = connector_spec
        self.evict_after_resolve = evict_after_resolve
        self._target: Any = None
        self._resolved = False
        self._prefetch_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- resolve
    @property
    def is_resolved(self) -> bool:
        return self._resolved

    def resolve(self) -> Any:
        if self._resolved:
            return self._target
        if self._prefetch_thread is not None:
            self._prefetch_thread.join()
            self._prefetch_thread = None
            if self._resolved:
                return self._target
        store = get_store(self.store_name, self.connector_spec)
        self._target = store.get(self.key)
        self._resolved = True
        if self.evict_after_resolve:
            store.evict(self.key)
        return self._target

    def prefetch(self) -> "Proxy":
        """Begin resolving on a background thread (async resolution)."""
        if self._resolved or self._prefetch_thread is not None:
            return self

        def _fetch() -> None:
            store = get_store(self.store_name, self.connector_spec)
            self._target = store.get(self.key)
            self._resolved = True

        t = threading.Thread(target=_fetch, daemon=True, name=f"prefetch-{self.key[:8]}")
        t.start()
        self._prefetch_thread = t
        return self

    # -------------------------------------------------- transparent forwarding
    def __getattr__(self, item: str) -> Any:
        # __slots__ attributes are found before __getattr__; anything else
        # forwards to the resolved target (transparent proxying).
        return getattr(self.resolve(), item)

    def __array__(self, dtype=None):  # numpy/jax interop
        import numpy as np

        arr = np.asarray(self.resolve())
        return arr.astype(dtype) if dtype is not None else arr

    def __getitem__(self, item):
        return self.resolve()[item]

    def __len__(self):
        return len(self.resolve())

    def __iter__(self):
        return iter(self.resolve())

    def __call__(self, *a, **kw):
        return self.resolve()(*a, **kw)

    def __add__(self, other):
        return self.resolve() + other

    def __radd__(self, other):
        return other + self.resolve()

    def __mul__(self, other):
        return self.resolve() * other

    def __rmul__(self, other):
        return other * self.resolve()

    def __matmul__(self, other):
        return self.resolve() @ other

    def __repr__(self) -> str:
        state = "resolved" if self._resolved else "lazy"
        return f"Proxy({self.store_name}/{self.key[:8]}, {self.nbytes}B, {state})"

    # ------------------------------------------------------------- pickling
    def __getstate__(self) -> dict:
        return {
            "store_name": self.store_name,
            "key": self.key,
            "nbytes": self.nbytes,
            "connector_spec": self.connector_spec,
            "evict_after_resolve": self.evict_after_resolve,
        }

    def __setstate__(self, state: dict) -> None:
        for k, v in state.items():
            object.__setattr__(self, k, v)
        object.__setattr__(self, "_target", None)
        object.__setattr__(self, "_resolved", False)
        object.__setattr__(self, "_prefetch_thread", None)

    def __setattr__(self, key, value):
        object.__setattr__(self, key, value)


# --------------------------------------------------------------------------
# Threshold-based auto-proxying (the queues call these)
# --------------------------------------------------------------------------


def apply_threshold(obj: Any, store: Store, threshold_bytes: int) -> Tuple[Any, int]:
    """Replace large leaves of ``obj`` with proxies.

    Returns (converted object, bytes moved to the fabric). Containers are
    walked one level deep per Colmena semantics (task args / kwargs values /
    result values are proxied individually).
    """
    moved = 0

    def convert(x: Any) -> Any:
        nonlocal moved
        if isinstance(x, Proxy):
            return x
        nb = object_nbytes(x)
        if nb >= threshold_bytes:
            moved += nb
            return store.proxy(x)
        return x

    if isinstance(obj, tuple):
        return tuple(convert(x) for x in obj), moved
    if isinstance(obj, list):
        return [convert(x) for x in obj], moved
    if isinstance(obj, dict):
        return {k: convert(v) for k, v in obj.items()}, moved
    return convert(obj), moved


def resolve_all(obj: Any) -> Any:
    """Force-resolve proxies in (possibly nested) containers."""
    if isinstance(obj, Proxy):
        return obj.resolve()
    if isinstance(obj, tuple):
        return tuple(resolve_all(x) for x in obj)
    if isinstance(obj, list):
        return [resolve_all(x) for x in obj]
    if isinstance(obj, dict):
        return {k: resolve_all(v) for k, v in obj.items()}
    return obj


def iter_proxies(obj: Any):
    """Yield every Proxy leaf in (possibly nested) containers."""
    if isinstance(obj, Proxy):
        yield obj
    elif isinstance(obj, (list, tuple)):
        for x in obj:
            yield from iter_proxies(x)
    elif isinstance(obj, dict):
        for v in obj.values():
            yield from iter_proxies(v)


def prefetch_all(obj: Any) -> Any:
    """Start async resolution for every proxy found (overlap compute/I-O)."""
    for p in iter_proxies(obj):
        p.prefetch()
    return obj
