"""Task Queues: the control channel between Thinker and Task Server.

Reproduces Colmena's queue layer:
  * one request queue (Thinker -> Task Server) and per-*topic* result
    queues (Task Server -> Thinker) so groups of agents operate
    independently;
  * exchangeable implementations behind one interface — ``LocalQueues``
    (in-process, stands in for Python pipes) and ``PipeQueues``
    (multiprocessing, stands in for Redis across processes) — porting an
    application between them is a one-line change;
  * threshold-based auto-proxying of large task inputs/outputs through a
    ProxyStore ``Store`` (10 MB in the paper's molecular-design app);
  * *act-on-completion*: ``send_result`` first publishes a tiny completion
    notice before the (possibly large) result payload, letting the Thinker
    react ~100x sooner and hide data-transfer latency (paper §Scaling,
    lesson 3);
  * *batched pops*: ``get_task_batch`` coalesces queued requests inside a
    configurable linger window so the Task Server can dispatch many small
    tasks in one worker round-trip.

Every message is size- and time-metered so Results report their own
communication overheads, as in the paper.
"""

from __future__ import annotations

import multiprocessing
import queue
import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, Optional

from .proxystore import Store, apply_threshold
from .result import FailureKind, ResourceRequest, Result, TraceContext
from .serialization import SERIALIZER


class KillSignal(Exception):
    """Raised on the server side when the Thinker requests shutdown."""


_KILL = "__COLMENA_KILL__"

# Client-side shutdown sentinel: pushed onto result/notice queues when a
# Thinker shuts down so result processors blocked in ``get_result`` /
# ``get_completion`` wake instantly instead of lagging a pop timeout.
_WAKE = "__COLMENA_WAKE__"

# Reserved result topic for control acks. Control requests ride the
# request queue (they must be ordered with task submissions), but their
# acks get a dedicated topic: ``_pop_typed`` discards non-matching items,
# so an ack sharing a topic with ``Result``s would silently eat results.
CONTROL_TOPIC = "__control__"


@dataclass
class ControlRequest:
    """An out-of-band command to a (possibly remote) task server.

    Travels over the *request* queue like a task submission, so it works
    unchanged across the pipe backend to a spawned ``ProcessTaskServer``
    site. Kinds: ``resize`` (params: ``target``, optional ``reason``)
    and ``ping`` (report pool sizes/backlog).
    """

    kind: str
    pool: str = "default"
    params: dict = field(default_factory=dict)
    request_id: str = field(default_factory=lambda: uuid.uuid4().hex)


@dataclass
class ControlAck:
    """The server's reply to a ``ControlRequest``, published on the
    reserved ``CONTROL_TOPIC`` result queue."""

    request_id: str
    kind: str
    pool: str
    ok: bool
    detail: dict = field(default_factory=dict)


@dataclass
class CompletionNotice:
    """Tiny record published the moment a task finishes computing."""

    task_id: str
    topic: str
    method: str
    success: bool
    task_info: dict = field(default_factory=dict)
    compute_seconds: Optional[float] = None


@dataclass
class QueueMetrics:
    tasks_sent: int = 0
    results_received: int = 0
    control_bytes: int = 0
    proxied_bytes: int = 0
    serialization_s: float = 0.0


class ColmenaQueues:
    """Interface shared by all queue implementations."""

    def __init__(
        self,
        topics: Iterable[str] = ("default",),
        proxystore: Optional[Store] = None,
        proxy_threshold: int = 10_000_000,  # 10 MB, as in the paper
        event_log: Optional[Any] = None,  # repro.observe.EventLog (duck-typed)
    ) -> None:
        self.topics = list(dict.fromkeys(list(topics) + ["default", CONTROL_TOPIC]))
        self.proxystore = proxystore
        self.proxy_threshold = proxy_threshold
        self.metrics = QueueMetrics()
        self.event_log = event_log
        self._metrics_lock = threading.Lock()
        # A kill signal observed mid-batch is deferred so already-popped
        # tasks in that batch are still dispatched before shutdown.
        self._kill_pending = False
        # Server-side hook: ``TaskServer`` installs its control handler
        # here (in its own process for spawned servers) so ``get_task``
        # can service ControlRequests inline on the dispatch thread.
        self.control_handler: Optional[Any] = None
        # Acks popped while waiting for a different request_id are parked
        # here instead of being dropped (concurrent control clients).
        self._ack_buffer: list = []

    def _emit(self, stage: str, result: Result, **info: Any) -> None:
        log = self.event_log
        if log is not None:
            log.task_event(stage, result, **info)

    # queues cross process boundaries (the server may run in its own
    # process); locks and the event log are per-process (each side of a
    # PipeColmenaQueues records its own lifecycle stages).
    def __getstate__(self) -> dict:
        state = dict(self.__dict__)
        state.pop("_metrics_lock", None)
        state["event_log"] = None
        # Bound methods of the server don't pickle; the child-side server
        # installs its own handler when it starts.
        state["control_handler"] = None
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._metrics_lock = threading.Lock()

    # -- transport primitives supplied by subclasses -------------------------
    def _push_request(self, payload: Any) -> None:
        raise NotImplementedError

    def _pop_request(self, timeout: Optional[float]) -> Any:
        raise NotImplementedError

    def _push_result(self, topic: str, payload: Any) -> None:
        raise NotImplementedError

    def _pop_result(self, topic: str, timeout: Optional[float]) -> Any:
        raise NotImplementedError

    def _push_notice(self, topic: str, payload: Any) -> None:
        raise NotImplementedError

    def _pop_notice(self, topic: str, timeout: Optional[float]) -> Any:
        raise NotImplementedError

    # -- encoding -------------------------------------------------------------
    def _encode(self, obj: Any) -> Any:
        """Serialize for transport; Local queues pass objects by reference
        but still meter the control-channel size the paper would pay."""
        return obj

    def _decode(self, obj: Any) -> Any:
        return obj

    # ------------------------------------------------------------- client API
    def send_inputs(
        self,
        *args: Any,
        method: str,
        topic: str = "default",
        task_info: Optional[dict] = None,
        resources: Optional[ResourceRequest] = None,
        keyword_args: Optional[dict] = None,
    ) -> str:
        """Request a computation; returns the task id."""
        result = Result(
            method=method,
            args=args,
            kwargs=keyword_args or {},
            task_info=task_info or {},
            resources=resources or ResourceRequest(),
            topic=topic,
        )
        result.trace = TraceContext.new()
        result.mark("created")
        self._emit("submitted", result)
        if self.proxystore is not None:
            new_args, moved_a = apply_threshold(result.args, self.proxystore, self.proxy_threshold)
            new_kwargs, moved_k = apply_threshold(result.kwargs, self.proxystore, self.proxy_threshold)
            result.args, result.kwargs = new_args, new_kwargs
            moved = moved_a + moved_k
            if moved:
                result.mark("input_proxied")
                result.timing.fabric_bytes += moved
                with self._metrics_lock:
                    self.metrics.proxied_bytes += moved
        result.mark("queued")
        self._emit("queued", result)
        self._push_request(self._encode(result))
        with self._metrics_lock:
            self.metrics.tasks_sent += 1
        return result.task_id

    def send_task(self, result: Result) -> str:
        """Submit a pre-built Result (used for retries / speculation)."""
        if result.trace is None:
            result.trace = TraceContext.new()
        result.mark("created")
        self._emit("submitted", result)
        result.mark("queued")
        self._emit("queued", result)
        self._push_request(self._encode(result))
        with self._metrics_lock:
            self.metrics.tasks_sent += 1
        return result.task_id

    def _pop_typed(self, pop, topic: str, timeout: Optional[float], want: type) -> Any:
        """Pop until a ``want`` instance arrives. A shutdown wake sentinel
        returns None immediately on a *blocking* pop (that is its job:
        unblock a result processor so it can re-check ``done``); on a
        bounded pop a leftover sentinel is discarded and the pop retries
        for the remaining timeout, so late drains never mistake a stale
        sentinel for an empty queue."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            payload = pop(topic, timeout)
            if payload is None:
                return None
            item = self._decode(payload)
            if isinstance(item, want):
                return item
            if deadline is None:  # blocking pop: the sentinel is the wakeup
                return None
            timeout = deadline - time.monotonic()
            if timeout <= 0:
                return None

    def get_result(self, topic: str = "default", timeout: Optional[float] = None) -> Optional[Result]:
        result = self._pop_typed(self._pop_result, topic, timeout, Result)
        if result is None:
            return None
        result.mark("result_received")
        self._emit("result_received", result, success=bool(result.success))
        result.finalize_timings()
        with self._metrics_lock:
            self.metrics.results_received += 1
        return result

    def get_completion(self, topic: str = "default", timeout: Optional[float] = None) -> Optional[CompletionNotice]:
        return self._pop_typed(self._pop_notice, topic, timeout, CompletionNotice)

    def wake_result_waiters(self, counts: Dict[tuple, int]) -> None:
        """Push shutdown sentinels for blocked result-processor pops.

        ``counts`` maps ``(topic, on)`` — ``on`` in {"result",
        "completion"} — to the number of consumers that may be blocked on
        that queue. Each consumer re-checks its ``done`` flag after any
        pop, so one sentinel per consumer makes shutdown instant without
        a pop timeout; unconsumed sentinels are inert (``get_result`` /
        ``get_completion`` filter them out).
        """
        for (topic, on), n in counts.items():
            if topic not in self.topics:
                continue
            push = self._push_result if on == "result" else self._push_notice
            for _ in range(max(0, n)):
                push(topic, self._encode(_WAKE))

    def send_kill_signal(self) -> None:
        self._push_request(_KILL)

    # ------------------------------------------------------- control channel
    def send_control(self, kind: str, pool: str = "default", **params: Any) -> ControlRequest:
        """Send an out-of-band command to the task server (fire-and-forget;
        pair with ``get_control_ack``/``request_resize`` for the reply)."""
        req = ControlRequest(kind=kind, pool=pool, params=params)
        self._push_request(self._encode(req))
        return req

    def send_control_ack(self, ack: ControlAck) -> None:
        """Server side: publish the reply on the reserved control topic."""
        self._push_result(CONTROL_TOPIC, self._encode(ack))

    def get_control_ack(
        self,
        timeout: Optional[float] = None,
        request_id: Optional[str] = None,
    ) -> Optional[ControlAck]:
        """Pop the next control ack (optionally a specific request's).

        With ``request_id``, acks for other requests are parked in a
        buffer (not dropped) so concurrent control clients can interleave.
        """
        for i, ack in enumerate(self._ack_buffer):
            if request_id is None or ack.request_id == request_id:
                return self._ack_buffer.pop(i)
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            ack = self._pop_typed(self._pop_result, CONTROL_TOPIC, timeout, ControlAck)
            if ack is None:
                return None
            if request_id is None or ack.request_id == request_id:
                return ack
            self._ack_buffer.append(ack)
            if deadline is not None:
                timeout = deadline - time.monotonic()
                if timeout <= 0:
                    return None

    def request_resize(
        self, pool: str, target: int, timeout: Optional[float] = 10.0, **params: Any
    ) -> Optional[ControlAck]:
        """Round-trip a pool-resize command: request over the request
        queue, ack back over the control topic. Returns None on timeout
        (e.g. the remote site died before replying)."""
        req = self.send_control("resize", pool=pool, target=int(target), **params)
        return self.get_control_ack(timeout=timeout, request_id=req.request_id)

    # ------------------------------------------------------------- server API
    def get_task(self, timeout: Optional[float] = None) -> Optional[Result]:
        if self._kill_pending:
            self._kill_pending = False
            raise KillSignal()
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            payload = self._pop_request(timeout)
            if payload is None:
                return None
            if isinstance(payload, str) and payload == _KILL:
                raise KillSignal()
            item = self._decode(payload)
            if isinstance(item, ControlRequest):
                # Serviced inline on the dispatch thread, before the next
                # task pop, so a resize ordered behind a burst of
                # submissions still lands promptly (pops are cheap).
                self._handle_control_request(item)
                if deadline is not None:
                    timeout = deadline - time.monotonic()
                    if timeout <= 0:
                        return None
                continue
            result: Result = item
            result.mark("picked_up")
            self._emit("picked_up", result)
            return result

    def _handle_control_request(self, req: ControlRequest) -> None:
        handler = self.control_handler
        if handler is None:
            self.send_control_ack(ControlAck(
                request_id=req.request_id, kind=req.kind, pool=req.pool,
                ok=False, detail={"error": "no control handler installed"},
            ))
            return
        handler(req)

    def get_task_batch(
        self,
        max_batch: int,
        timeout: Optional[float] = None,
        linger_s: float = 0.0,
    ) -> list:
        """Pop up to ``max_batch`` task requests in one call.

        Blocks up to ``timeout`` for the first task, then keeps popping
        until the batch is full or ``linger_s`` elapses — the coalescing
        window of batched dispatch. A kill signal seen after the first
        pop is deferred to the next ``get_task``/``get_task_batch`` call
        so no already-popped task is lost.
        """
        first = self.get_task(timeout)
        if first is None:
            return []
        batch = [first]
        deadline = time.monotonic() + linger_s
        while len(batch) < max_batch:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                task = self.get_task(timeout=remaining)
            except KillSignal:
                self._kill_pending = True
                break
            if task is None:
                break
            batch.append(task)
        return batch

    def send_result(self, result: Result) -> None:
        """Publish completion notice first (act-on-completion), then the
        result record; large values are proxied so the control channel
        stays light."""
        notice = CompletionNotice(
            task_id=result.task_id,
            topic=result.topic,
            method=result.method,
            success=bool(result.success),
            task_info=dict(result.task_info),
            compute_seconds=(
                result.time.compute_ended - result.time.compute_started
                if result.time.compute_ended and result.time.compute_started
                else None
            ),
        )
        result.mark("completion_notified")
        self._push_notice(result.topic, self._encode(notice))

        if self.proxystore is not None and result.success:
            new_value, moved = apply_threshold(result.value, self.proxystore, self.proxy_threshold)
            if moved:
                result.value = new_value
                result.mark("result_proxied")
                result.timing.fabric_bytes += moved
                with self._metrics_lock:
                    self.metrics.proxied_bytes += moved
        result.mark("returned")
        self._push_result(result.topic, self._encode(result))


class LocalColmenaQueues(ColmenaQueues):
    """In-process queues built on ``queue.Queue`` (the paper's "Pipes"
    choice: no server to run, objects move by reference)."""

    def __init__(self, **kwargs: Any) -> None:
        super().__init__(**kwargs)
        self._requests: "queue.Queue[Any]" = queue.Queue()
        self._results: Dict[str, "queue.Queue[Any]"] = {t: queue.Queue() for t in self.topics}
        self._notices: Dict[str, "queue.Queue[Any]"] = {t: queue.Queue() for t in self.topics}

    @staticmethod
    def _pop(q: "queue.Queue[Any]", timeout: Optional[float]) -> Any:
        try:
            if timeout is None:
                return q.get()
            return q.get(timeout=timeout)
        except queue.Empty:
            return None

    def _push_request(self, payload: Any) -> None:
        self._requests.put(payload)

    def _pop_request(self, timeout: Optional[float]) -> Any:
        return self._pop(self._requests, timeout)

    def _push_result(self, topic: str, payload: Any) -> None:
        self._results[topic].put(payload)

    def _pop_result(self, topic: str, timeout: Optional[float]) -> Any:
        return self._pop(self._results[topic], timeout)

    def _push_notice(self, topic: str, payload: Any) -> None:
        self._notices[topic].put(payload)

    def _pop_notice(self, topic: str, timeout: Optional[float]) -> Any:
        return self._pop(self._notices[topic], timeout)


class PipeColmenaQueues(ColmenaQueues):
    """Cross-process queues over ``multiprocessing`` pipes with explicit,
    metered serialization (the paper's Redis deployment shape: control
    messages cross a process/host boundary and must be encoded)."""

    def __init__(self, ctx: Optional[Any] = None, **kwargs: Any) -> None:
        super().__init__(**kwargs)
        ctx = ctx or multiprocessing.get_context("spawn")
        self._requests = ctx.Queue()
        self._results = {t: ctx.Queue() for t in self.topics}
        self._notices = {t: ctx.Queue() for t in self.topics}

    def _encode(self, obj: Any) -> Any:
        payload, m = SERIALIZER.serialize(obj)
        with self._metrics_lock:
            self.metrics.control_bytes += m.bytes
            self.metrics.serialization_s += m.seconds
        return payload

    def _decode(self, obj: Any) -> Any:
        value, m = SERIALIZER.deserialize(obj)
        with self._metrics_lock:
            self.metrics.serialization_s += m.seconds
        if isinstance(value, Result):
            value.timing.control_bytes += m.bytes
        return value

    @staticmethod
    def _pop(q: Any, timeout: Optional[float]) -> Any:
        try:
            if timeout is None:
                return q.get()
            return q.get(timeout=timeout)
        except queue.Empty:
            return None

    def _push_request(self, payload: Any) -> None:
        self._requests.put(payload)

    def _pop_request(self, timeout: Optional[float]) -> Any:
        raw = self._pop(self._requests, timeout)
        if raw is None:
            return None
        # The kill sentinel is itself pickled by _encode.
        obj, _ = SERIALIZER.deserialize(raw) if isinstance(raw, bytes) else (raw, None)
        if isinstance(obj, str) and obj == _KILL:
            return _KILL
        return raw

    def _push_result(self, topic: str, payload: Any) -> None:
        self._results[topic].put(payload)

    def _pop_result(self, topic: str, timeout: Optional[float]) -> Any:
        return self._pop(self._results[topic], timeout)

    def _push_notice(self, topic: str, payload: Any) -> None:
        self._notices[topic].put(payload)

    def _pop_notice(self, topic: str, timeout: Optional[float]) -> Any:
        return self._pop(self._notices[topic], timeout)

    def send_kill_signal(self) -> None:
        self._requests.put(SERIALIZER.serialize(_KILL)[0])
