"""Steering-policy templates: the paper's "Templates for Common Patterns".

The paper observes that although Thinkers are free-form, applications
repeat a handful of patterns. This module provides tuned implementations:

  * ``ConstantInflightThinker`` — the proxy application's policy: keep a
    constant number of tasks in flight, launching a replacement the
    moment one completes (used by benchmarks/proxy_app.py).
  * ``PriorityQueueThinker`` — an agent submits the top entry of a
    priority queue whenever resources free, while result processors
    re-rank the queue from completed computations (the paper's canonical
    template example).
  * ``BatchRetrainThinker`` — the molecular-design pattern (Fig. 2):
    simulate continuously; once N new results arrive, shift resources to
    retraining + inference, then push fresh priorities back to the queue.

All templates subclass ``BaseThinker`` and can be further subclassed;
hooks (``score``, ``on_result`` …) are the extension points.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Any, Callable, Iterable, List, Optional, Sequence, Tuple

from .queues import ColmenaQueues
from .result import ResourceRequest, Result
from .thinker import BaseThinker, ResourceCounter, agent, event_responder, result_processor, task_submitter


class ConstantInflightThinker(BaseThinker):
    """Maintain exactly ``n_parallel`` tasks in flight until a work list is
    exhausted — the paper's proxy application."""

    def __init__(
        self,
        queues: ColmenaQueues,
        work: Sequence[Tuple[tuple, dict]],
        method: str,
        n_parallel: int,
        topic: str = "default",
        pool: str = "default",
    ) -> None:
        super().__init__(queues, ResourceCounter(n_parallel))
        self._work = list(work)
        self._method = method
        self._topic = topic
        self._pool = pool
        self._next = 0
        self._outstanding = 0
        self._lock = threading.Lock()
        self.results: List[Result] = []

    def _submit_next(self) -> bool:
        with self._lock:
            if self._next >= len(self._work):
                return False
            args, kwargs = self._work[self._next]
            self._next += 1
            self._outstanding += 1
        self.queues.send_inputs(
            *args, method=self._method, topic=self._topic,
            keyword_args=kwargs, resources=ResourceRequest(pool=self._pool),
        )
        return True

    @agent(startup=True)
    def startup(self) -> None:
        for _ in range(min(self.rec.total_slots, len(self._work))):
            self._submit_next()

    @result_processor()
    def on_result(self, result: Result) -> None:
        self.results.append(result)
        submitted = self._submit_next()
        with self._lock:
            self._outstanding -= 1
            drained = self._outstanding == 0 and self._next >= len(self._work)
        if drained and not submitted:
            self.done.set()


class PriorityQueueThinker(BaseThinker):
    """Submit-from-priority-queue + re-rank-on-result template."""

    def __init__(
        self,
        queues: ColmenaQueues,
        method: str,
        n_slots: int,
        topic: str = "default",
        max_tasks: Optional[int] = None,
    ) -> None:
        super().__init__(queues, ResourceCounter(n_slots))
        self.method = method
        self.topic = topic
        self.max_tasks = max_tasks
        self._heap: List[Tuple[float, int, tuple, dict]] = []
        self._tie = itertools.count()
        # Condition instead of a bare lock: the submitter parks on it while
        # the heap is empty (holding its already-acquired slot) and wakes
        # on push() / shutdown — no release();sleep() slot-thrash. The
        # done WakeEvent notifies it too, so *any* done-setter (including
        # run(timeout=...)) wakes the parked submitter immediately.
        self._work_cond = threading.Condition()
        self.done.watch(self._work_cond)
        self._completed = 0
        self.results: List[Result] = []

    # -------------------------------------------------------------- queue ops
    def push(self, args: tuple, kwargs: Optional[dict] = None, priority: float = 0.0) -> None:
        """Lower priority value = run sooner."""
        with self._work_cond:
            heapq.heappush(self._heap, (priority, next(self._tie), args, kwargs or {}))
            self._work_cond.notify()

    def pending(self) -> int:
        with self._work_cond:
            return len(self._heap)

    # --------------------------------------------------------------- agents
    @task_submitter(task_type="default", n_slots=1)
    def submit_next(self) -> None:
        item = None
        with self._work_cond:
            # Pure condition sleep: woken by push() (arriving work) or by
            # the done WakeEvent (watched in __init__) — no poll timeout.
            while not self._heap and not self.done.is_set():
                self._work_cond.wait()
            if self._heap:
                item = heapq.heappop(self._heap)
        if item is None:  # shutting down with an empty heap
            self.rec.release("default", 1)
            return
        _, _, args, kwargs = item
        self.queues.send_inputs(*args, method=self.method, topic=self.topic, keyword_args=kwargs)

    @result_processor()
    def on_result_internal(self, result: Result) -> None:
        self.rec.release("default", 1)
        self.results.append(result)
        self._completed += 1
        self.on_result(result)
        if self.max_tasks is not None and self._completed >= self.max_tasks:
            self.done.set()
            with self._work_cond:
                self._work_cond.notify_all()

    # ---------------------------------------------------------------- hooks
    def on_result(self, result: Result) -> None:
        """Override: inspect result, push new work / re-rank."""


class BatchRetrainThinker(BaseThinker):
    """Simulate continuously; retrain + re-infer when enough data arrives.

    Hooks: ``simulate_args()`` yields task args; ``retrain(results)``
    returns new task priorities (list of (args, priority)).
    """

    def __init__(
        self,
        queues: ColmenaQueues,
        n_slots: int,
        retrain_after: int,
        simulate_method: str = "simulate",
        train_method: str = "train",
        infer_method: str = "infer",
        ml_slots: int = 1,
        max_results: Optional[int] = None,
    ) -> None:
        rec = ResourceCounter(n_slots, pools=["simulate", "ml"])
        rec.reallocate("simulate", "ml", min(ml_slots, n_slots))
        super().__init__(queues, rec)
        self.retrain_after = retrain_after
        self.simulate_method = simulate_method
        self.train_method = train_method
        self.infer_method = infer_method
        self.max_results = max_results
        self._new_since_train = 0
        self._total = 0
        self._ml_inflight = 0
        # Event (not a polled flag): once set, the simulation submitter
        # parks on ``done`` instead of thrashing its slot.
        self._drain = threading.Event()
        self._state_lock = threading.Lock()
        self.train_rounds = 0
        self.database: List[Result] = []

    def _maybe_finish(self) -> None:
        """Finish only when the sim budget is spent AND no ML task is in
        flight — otherwise the final retrain's result would be dropped."""
        with self._state_lock:
            ready = self._drain.is_set() and self._ml_inflight == 0
        if ready:
            self.done.set()

    # ---------------------------------------------------------------- hooks
    def simulate_args(self) -> tuple:
        raise NotImplementedError

    def on_simulation(self, result: Result) -> None:
        pass

    def make_train_task(self) -> Tuple[tuple, dict]:
        raise NotImplementedError

    def on_train(self, result: Result) -> None:
        pass

    # --------------------------------------------------------------- agents
    @task_submitter(task_type="simulate", n_slots=1)
    def submit_simulation(self) -> None:
        if self._drain.is_set():   # budget spent: stop feeding the pool
            self.rec.release("simulate", 1)
            # Park until shutdown (set by _maybe_finish once ML drains, or
            # externally) — no wakeup/release cycle while draining.
            self.done.wait()
            return
        args = self.simulate_args()
        self.queues.send_inputs(
            *args, method=self.simulate_method, topic="simulate",
            resources=ResourceRequest(pool="simulate"),
        )

    @result_processor(topic="simulate")
    def receive_simulation(self, result: Result) -> None:
        self.rec.release("simulate", 1)
        if result.success:
            self.database.append(result)
            self._new_since_train += 1
            self._total += 1
            self.on_simulation(result)
            if self._new_since_train >= self.retrain_after and not self._drain.is_set():
                self._new_since_train = 0
                self.set_event("retrain")
        if self.max_results is not None and self._total >= self.max_results:
            self._drain.set()
            self._maybe_finish()

    @event_responder(event_name="retrain")
    def run_training(self) -> None:
        args, kwargs = self.make_train_task()
        with self._state_lock:
            self._ml_inflight += 1
        self.queues.send_inputs(
            *args, method=self.train_method, topic="train",
            keyword_args=kwargs, resources=ResourceRequest(pool="ml"),
        )

    @result_processor(topic="train")
    def receive_training(self, result: Result) -> None:
        with self._state_lock:
            self._ml_inflight = max(0, self._ml_inflight - 1)
        self.train_rounds += 1
        self.on_train(result)
        self._maybe_finish()
