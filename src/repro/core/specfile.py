"""Serializable AppSpecs: TOML/JSON campaign files + the launch CLI.

A campaign that exists only as Python objects cannot leave its process:
it cannot be launched from a scheduler, diffed against last week's run,
or resumed on another node. This module gives ``AppSpec`` a canonical
plain-dict form (``spec_to_dict``/``spec_from_dict``) and a file form
(``save_spec``/``load_spec``, TOML or JSON by extension), with every
code object — task functions, thinker classes/factories — referenced by
dotted import path::

    [[tasks]]
    fn = "examples.quickstart.simulate"     # @task metadata honored

    [pools.default]
    size = 4
    min_size = 2          # widening the band opts into elasticity
    max_size = 8

    [steering]
    thinker = "examples.quickstart.Quickstart"
    [steering.kwargs]
    n_total = 32

Steering kwargs may reference arbitrary objects with two escapes:
``{"$ref" = "pkg.mod.attr"}`` imports an attribute, and
``{"$call" = "pkg.mod.factory", args = [...], kwargs = {...}}`` calls a
factory — how scenario objects (``repro.surrogate.make_scenario``) reach
a config-file campaign.

An optional ``[smoke]`` table holds overrides deep-merged into the spec
by ``load_spec(path, smoke=True)`` — the campaign file itself declares
its CI-sized form.

The CLI (``python -m repro.app``)::

    python -m repro.app run campaign.toml [--smoke] [--fresh] [--timeout N]
    python -m repro.app show campaign.toml        # normalized JSON (diffable)
"""

from __future__ import annotations

import argparse
import importlib
import json
import sys
from typing import Any, Dict, List, Mapping, Optional

from .executors import PoolSpec
from .task_server import BatchPolicy, RetryPolicy, StragglerPolicy
from .result import FailureKind
from .thinker import BaseThinker

__all__ = [
    "SPEC_VERSION",
    "diff_spec_dicts",
    "dumps_toml",
    "import_dotted",
    "dotted_path",
    "load_spec",
    "main",
    "save_spec",
    "spec_from_dict",
    "spec_to_dict",
]

# Campaign-file format version. ``spec_to_dict`` stamps it; ``spec_from_dict``
# migrates older versions forward and refuses newer ones with a clear error.
#   v1 (implicit — files with no ``version`` key): allowed the bare-int pool
#      shorthand ``pools.default = 4``.
#   v2: pools must be tables (``pools.default = {size = 4}``); the int
#      shorthand is migrated on load for v1 files but rejected in v2 files,
#      so saved specs are always diffable against what loads.
SPEC_VERSION = 2


# --------------------------------------------------------------------------
# Dotted import paths
# --------------------------------------------------------------------------


def import_dotted(path: str) -> Any:
    """Import ``pkg.mod.attr`` (attr may be nested, e.g. a classmethod
    owner). Raises ``ImportError`` with enough context to fix the config
    file, whichever half failed."""
    if not isinstance(path, str) or not path:
        raise ImportError(f"expected a dotted import path, got {path!r}")
    parts = path.split(".")
    # Longest importable module prefix wins; the rest are attributes.
    module = None
    for i in range(len(parts), 0, -1):
        prefix = ".".join(parts[:i])
        try:
            module = importlib.import_module(prefix)
            attrs = parts[i:]
            break
        except ModuleNotFoundError as exc:
            # Only "this prefix does not exist" shortens the prefix; a
            # module that exists but fails to import (missing dependency,
            # syntax error) must surface its real error, not a confusing
            # "no attribute" fallback.
            if exc.name and (prefix == exc.name or prefix.startswith(exc.name + ".")):
                continue
            raise ImportError(f"cannot import {path!r}: importing {prefix!r} failed: {exc}") from exc
        except ImportError as exc:
            raise ImportError(f"cannot import {path!r}: importing {prefix!r} failed: {exc}") from exc
    if module is None:
        raise ImportError(f"cannot import {path!r}: no importable module prefix")
    obj: Any = module
    for attr in attrs:
        try:
            obj = getattr(obj, attr)
        except AttributeError:
            raise ImportError(
                f"cannot import {path!r}: {obj.__name__ if hasattr(obj, '__name__') else obj!r} "
                f"has no attribute {attr!r}"
            ) from None
    return obj


def dotted_path(obj: Any) -> str:
    """The dotted path that re-imports ``obj``; raises when the object is
    not reachable that way (lambdas, locals, ad-hoc instances)."""
    module = getattr(obj, "__module__", None)
    qualname = getattr(obj, "__qualname__", None)
    if not module or not qualname:
        raise ValueError(
            f"{obj!r} has no importable identity; reference it by module-level "
            "function/class to serialize it"
        )
    if "<locals>" in qualname or "<lambda>" in qualname:
        raise ValueError(
            f"{module}.{qualname} is a local/lambda and cannot be re-imported; "
            "move it to module level to serialize the spec"
        )
    if module == "__main__":
        raise ValueError(
            f"__main__.{qualname} is only importable inside this process; "
            "move it into a module to serialize the spec"
        )
    path = f"{module}.{qualname}"
    try:
        found = import_dotted(path)
    except ImportError as exc:
        raise ValueError(f"{path} does not round-trip: {exc}") from exc
    if found is not obj:
        raise ValueError(f"{path} imports a different object than the one in the spec")
    return path


def _resolve_refs(value: Any) -> Any:
    """Recursively resolve ``$ref``/``$call`` escapes in config values."""
    if isinstance(value, Mapping):
        if "$ref" in value:
            extra = set(value) - {"$ref"}
            if extra:
                raise ValueError(f"$ref takes no other keys (got {sorted(extra)})")
            return import_dotted(value["$ref"])
        if "$call" in value:
            extra = set(value) - {"$call", "args", "kwargs"}
            if extra:
                raise ValueError(f"$call accepts only args/kwargs (got {sorted(extra)})")
            fn = import_dotted(value["$call"])
            args = _resolve_refs(list(value.get("args", ())))
            kwargs = _resolve_refs(dict(value.get("kwargs", {})))
            return fn(*args, **kwargs)
        return {k: _resolve_refs(v) for k, v in value.items()}
    if isinstance(value, list):
        return [_resolve_refs(v) for v in value]
    return value


def _check_plain(value: Any, where: str) -> Any:
    """Require config-file-representable values (str/int/float/bool +
    lists/dicts thereof)."""
    if value is None or isinstance(value, (str, int, float, bool)):
        return value
    if isinstance(value, (list, tuple)):
        return [_check_plain(v, where) for v in value]
    if isinstance(value, Mapping):
        return {str(k): _check_plain(v, f"{where}.{k}") for k, v in value.items()}
    raise ValueError(
        f"{where}: {type(value).__name__} values do not serialize; use a "
        "primitive, or reference the object via {'$ref': ...}/{'$call': ...} "
        "in the config file"
    )


# --------------------------------------------------------------------------
# Spec <-> dict
# --------------------------------------------------------------------------


def spec_to_dict(spec: Any) -> Dict[str, Any]:
    """Canonical plain-dict form of an ``AppSpec`` (JSON/TOML-ready,
    stable for diffing; ``spec_from_dict`` inverts it)."""
    from .app import AppSpec, TaskDef, _as_taskdef  # local: avoid cycle

    if not isinstance(spec, AppSpec):
        raise TypeError(f"expected AppSpec, got {type(spec).__name__}")

    tasks: List[Dict[str, Any]] = []
    for t in spec.tasks:
        td: TaskDef = _as_taskdef(t)
        # method/pool/batch are always explicit so a table entry never
        # falls back to (possibly different) decorator metadata on load.
        entry: Dict[str, Any] = {
            "fn": dotted_path(td.fn),
            "method": td.method,
            "pool": td.pool,
            "batch": td.batch,
        }
        if td.timeout_s is not None:
            entry["timeout_s"] = td.timeout_s
        tasks.append(entry)

    out: Dict[str, Any] = {
        "version": SPEC_VERSION,
        "tasks": tasks,
        "queues": {"backend": spec.queues.backend, "topics": list(spec.queues.topics)},
        "pools": {name: ps.to_dict() for name, ps in sorted(spec.pools.items())},
    }

    if spec.fabric is not None:
        f = spec.fabric
        if not isinstance(f.connector, (str, Mapping)):
            raise ValueError(
                "FabricSpec.connector must be a kind string or spec table to "
                f"serialize (got {type(f.connector).__name__})"
            )
        fab: Dict[str, Any] = {
            "connector": f.connector if isinstance(f.connector, str) else dict(f.connector),
            "threshold": f.threshold,
            "prefetch": f.prefetch,
            "warm_capacity": f.warm_capacity,
            "cache_size": f.cache_size,
        }
        if f.store_name is not None:
            fab["store_name"] = f.store_name
        out["fabric"] = fab

    if spec.observe is None:
        out["observe"] = False
    else:
        o = spec.observe
        if o.log is not None:
            raise ValueError("ObserveSpec.log (a live EventLog) does not serialize")
        if o.reallocator is not None and not isinstance(o.reallocator, str):
            raise ValueError(
                "ObserveSpec.reallocator must be 'greedy'/'ema' to serialize "
                f"(got {type(o.reallocator).__name__})"
            )
        obs: Dict[str, Any] = {"capacity": o.capacity}
        if o.jsonl_path is not None:
            obs["jsonl_path"] = o.jsonl_path
        if o.server_jsonl_path is not None:
            obs["server_jsonl_path"] = o.server_jsonl_path
        if o.rotate_bytes is not None:
            obs["rotate_bytes"] = o.rotate_bytes
            obs["rotate_keep"] = o.rotate_keep
        if o.export is not None:
            if isinstance(o.export, str):
                obs["export"] = o.export
            elif isinstance(o.export, Mapping):
                obs["export"] = dict(o.export)
            elif hasattr(o.export, "to_dict"):
                obs["export"] = o.export.to_dict()
            else:
                raise ValueError(
                    f"ObserveSpec.export {type(o.export).__name__} does not serialize"
                )
        if o.reallocator is not None:
            obs["reallocator"] = o.reallocator
            obs["realloc_interval"] = o.realloc_interval
        if o.realloc_min_slots:
            obs["realloc_min_slots"] = dict(o.realloc_min_slots)
        if o.elastic is not None:
            if o.elastic is True:
                obs["elastic"] = {}
            elif isinstance(o.elastic, Mapping):
                obs["elastic"] = dict(o.elastic)
            elif hasattr(o.elastic, "to_dict"):
                obs["elastic"] = o.elastic.to_dict()
            else:
                raise ValueError(
                    f"ObserveSpec.elastic {type(o.elastic).__name__} does not serialize"
                )
        if o.ops_port is not None:
            obs["ops_port"] = o.ops_port
        if o.remediate:
            obs["remediate"] = True
        for knob in ("slo", "anomaly"):
            v = getattr(o, knob)
            if v is None:
                continue
            if v is True:
                obs[knob] = {}
            elif isinstance(v, Mapping):
                obs[knob] = dict(v)
            elif hasattr(v, "to_dict"):
                obs[knob] = v.to_dict()
            else:
                raise ValueError(
                    f"ObserveSpec.{knob} {type(v).__name__} does not serialize"
                )
        out["observe"] = obs

    if spec.steering is not None:
        out["steering"] = {
            "thinker": dotted_path(spec.steering.thinker),
            "kwargs": _check_plain(spec.steering.kwargs, "steering.kwargs"),
        }

    if spec.campaign is not None:
        c = spec.campaign
        out["campaign"] = {
            "state_dir": c.state_dir,
            "checkpoint_interval_s": c.checkpoint_interval_s,
            "name": c.name,
            "resume": c.resume,
        }

    if spec.control is not None:
        ctl = spec.control
        control: Dict[str, Any] = {
            "weight": ctl.weight,
            "priority": ctl.priority,
            "min_slots": ctl.min_slots,
        }
        if ctl.demand is not None:
            control["demand"] = ctl.demand
        out["control"] = control

    s = spec.server
    if s.injector is not None:
        raise ValueError("ServerSpec.injector (a FailureInjector) does not serialize")
    server: Dict[str, Any] = {
        "in_process": s.in_process,
        "max_batch": s.max_batch,
        "linger_s": s.linger_s,
        "heartbeat_timeout_s": s.heartbeat_timeout_s,
    }
    if s.retry is not None:
        server["retry"] = {
            "max_retries": s.retry.max_retries,
            "backoff_s": s.retry.backoff_s,
            "retry_on": [k.name for k in s.retry.retry_on],
        }
    if s.straggler is not None:
        server["straggler"] = {
            "enabled": s.straggler.enabled,
            "factor": s.straggler.factor,
            "min_history": s.straggler.min_history,
            "check_interval_s": s.straggler.check_interval_s,
        }
    if s.batching is not None:
        b: Dict[str, Any] = {"max_batch": s.batching.max_batch, "linger_s": s.batching.linger_s}
        if s.batching.methods is not None:
            b["methods"] = list(s.batching.methods)
        server["batching"] = b
    out["server"] = server
    return out


def _task_from_entry(entry: Any) -> Any:
    from .app import TaskDef, _as_taskdef  # local: avoid cycle

    if isinstance(entry, str):
        return _as_taskdef(import_dotted(entry))
    if not isinstance(entry, Mapping):
        raise TypeError(f"task entry must be a dotted path or table, got {type(entry).__name__}")
    if "fn" not in entry:
        raise ValueError(f"task entry needs an 'fn' dotted path (got keys {sorted(entry)})")
    unknown = set(entry) - {"fn", "method", "pool", "timeout_s", "batch"}
    if unknown:
        raise ValueError(
            f"task entry {entry['fn']!r}: unknown keys {sorted(unknown)}"
        )
    fn = import_dotted(entry["fn"])
    base = _as_taskdef(fn)  # honors @task decorator metadata
    return TaskDef(
        fn=base.fn,
        method=entry.get("method", base.method),
        pool=entry.get("pool", base.pool),
        timeout_s=entry.get("timeout_s", base.timeout_s),
        batch=entry.get("batch", base.batch),
    )


def _spec_version(d: Mapping[str, Any]) -> int:
    """Validate the ``version`` key; files without one are v1 (the format
    that predates versioning). Future versions fail loudly rather than
    half-loading a file written by a newer build."""
    v = d.get("version", 1)
    if isinstance(v, bool) or not isinstance(v, int):
        raise ValueError(f"spec version must be an integer (got {v!r})")
    if v < 1:
        raise ValueError(f"spec version must be >= 1 (got {v})")
    if v > SPEC_VERSION:
        raise ValueError(
            f"campaign spec declares version {v}, but this build reads "
            f"version <= {SPEC_VERSION} — upgrade repro, or re-save the "
            "spec from the build that wrote it"
        )
    return v


def _migrate_spec_dict(d: Mapping[str, Any], version: int) -> Dict[str, Any]:
    """Rewrite a pre-``SPEC_VERSION`` dict into the current shape.
    v1 -> v2: the bare-int pool shorthand becomes an explicit table."""
    out = dict(d)
    if version < 2 and isinstance(out.get("pools"), Mapping):
        out["pools"] = {
            name: ({"size": v} if isinstance(v, int) and not isinstance(v, bool) else v)
            for name, v in out["pools"].items()
        }
    return out


def spec_from_dict(d: Mapping[str, Any]) -> Any:
    """Build an ``AppSpec`` from its plain-dict form (inverse of
    ``spec_to_dict``; also accepts hand-written config shorthands).
    Pre-``SPEC_VERSION`` dicts are migrated forward on the fly."""
    from .app import (  # local: avoid cycle
        AppSpec,
        CampaignSpec,
        ControlSpec,
        FabricSpec,
        ObserveSpec,
        QueueSpec,
        ServerSpec,
        SteeringSpec,
    )

    known = {"version", "tasks", "queues", "pools", "fabric", "observe",
             "steering", "campaign", "server", "control", "smoke"}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"unknown spec sections: {sorted(unknown)}")
    version = _spec_version(d)
    d = _migrate_spec_dict(d, version)
    if version >= 2 and isinstance(d.get("pools"), Mapping):
        bare = sorted(name for name, v in d["pools"].items()
                      if isinstance(v, int) and not isinstance(v, bool))
        if bare:
            raise ValueError(
                f"pools {bare}: version {version} specs spell pool sizes as "
                "tables ({size = n}); the bare-int shorthand is only read "
                "from version 1 (unversioned) files"
            )
    if "tasks" not in d or not d["tasks"]:
        raise ValueError("a campaign needs at least one [[tasks]] entry")

    tasks = [_task_from_entry(t) for t in d["tasks"]]

    q = d.get("queues", "local")
    if isinstance(q, str):
        queues: Any = q
    else:
        unknown_q = set(q) - {"backend", "topics"}
        if unknown_q:
            raise ValueError(f"queues: unknown keys {sorted(unknown_q)}")
        queues = QueueSpec(
            backend=q.get("backend", "local"), topics=tuple(q.get("topics", ("default",)))
        )

    pools = None
    if "pools" in d:
        pools = {name: PoolSpec.from_dict(name, v) for name, v in d["pools"].items()}

    fabric = None
    if "fabric" in d and d["fabric"] is not False:
        f = dict(d["fabric"])
        fabric = FabricSpec(**f)

    observe: Optional[ObserveSpec]
    o = d.get("observe", {})
    if o is False:
        observe = None
    else:
        o = dict(o)
        if "elastic" in o and o["elastic"] is not False:
            o["elastic"] = dict(o["elastic"]) if isinstance(o["elastic"], Mapping) else o["elastic"]
        elif o.get("elastic") is False:
            o.pop("elastic")
        for knob in ("slo", "anomaly"):
            # `slo = false` in a [smoke] override disables the engine the
            # same way `elastic = false` disables the scaler.
            if o.get(knob) is False:
                o.pop(knob)
            elif knob in o and isinstance(o[knob], Mapping):
                o[knob] = dict(o[knob])
        observe = ObserveSpec(**o)

    steering = None
    if "steering" in d:
        st = d["steering"]
        thinker = import_dotted(st["thinker"])
        if not callable(thinker):
            raise ValueError(
                f"steering.thinker {st['thinker']!r} is not a BaseThinker subclass "
                "or factory callable"
            )
        steering = SteeringSpec(thinker, _resolve_refs(dict(st.get("kwargs", {}))))

    campaign = None
    if "campaign" in d:
        campaign = CampaignSpec(**dict(d["campaign"]))

    control = None
    if "control" in d:
        control = ControlSpec(**dict(d["control"]))

    server = ServerSpec()
    if "server" in d:
        s = dict(d["server"])
        if "retry" in s:
            r = dict(s["retry"])
            if "retry_on" in r:
                r["retry_on"] = tuple(FailureKind[name] for name in r["retry_on"])
            s["retry"] = RetryPolicy(**r)
        if "straggler" in s:
            s["straggler"] = StragglerPolicy(**dict(s["straggler"]))
        if "batching" in s:
            b = dict(s["batching"])
            if "methods" in b:
                b["methods"] = tuple(b["methods"])
            s["batching"] = BatchPolicy(**b)
        server = ServerSpec(**s)

    return AppSpec(
        tasks=tasks,
        steering=steering,
        queues=queues,
        pools=pools,
        fabric=fabric,
        observe=observe,
        campaign=campaign,
        server=server,
        control=control,
    )


# --------------------------------------------------------------------------
# TOML (write: minimal emitter for the spec subset; read: tomllib/tomli)
# --------------------------------------------------------------------------


def _toml_key(k: str) -> str:
    if k and all(c.isalnum() or c in "-_" for c in k):
        return k
    return '"' + k.replace("\\", "\\\\").replace('"', '\\"') + '"'


def _toml_scalar(v: Any) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, int):
        return str(v)
    if isinstance(v, float):
        s = repr(v)
        return s if any(c in s for c in ".eE") else s + ".0"
    if isinstance(v, str):
        return json.dumps(v)  # valid TOML basic string
    if isinstance(v, (list, tuple)):
        return "[" + ", ".join(_toml_scalar(x) for x in v) + "]"
    if isinstance(v, Mapping):
        inner = ", ".join(f"{_toml_key(k)} = {_toml_scalar(x)}" for k, x in v.items())
        return "{" + inner + "}"
    raise TypeError(f"cannot write {type(v).__name__} to TOML")


def _emit_table(d: Mapping[str, Any], prefix: List[str], lines: List[str]) -> None:
    scalars = {k: v for k, v in d.items()
               if not isinstance(v, Mapping)
               and not (isinstance(v, list) and v and all(isinstance(x, Mapping) for x in v))}
    tables = {k: v for k, v in d.items() if isinstance(v, Mapping)}
    arrays = {k: v for k, v in d.items()
              if isinstance(v, list) and v and all(isinstance(x, Mapping) for x in v)}
    if prefix and (scalars or not (tables or arrays)):
        lines.append("[" + ".".join(_toml_key(p) for p in prefix) + "]")
    for k, v in scalars.items():
        lines.append(f"{_toml_key(k)} = {_toml_scalar(v)}")
    if scalars or (prefix and not (tables or arrays)):
        lines.append("")
    for k, rows in arrays.items():
        header = ".".join(_toml_key(p) for p in prefix + [k])
        for row in rows:
            lines.append(f"[[{header}]]")
            for rk, rv in row.items():
                lines.append(f"{_toml_key(rk)} = {_toml_scalar(rv)}")
            lines.append("")
    for k, v in tables.items():
        _emit_table(v, prefix + [k], lines)


def dumps_toml(d: Mapping[str, Any]) -> str:
    """Serialize a spec dict as TOML (round-trips through ``tomllib``)."""
    lines: List[str] = []
    _emit_table(d, [], lines)
    return "\n".join(lines).rstrip() + "\n"


def _load_toml(path: str) -> Dict[str, Any]:
    try:
        import tomllib  # Python >= 3.11
    except ModuleNotFoundError:  # pragma: no cover - 3.10 path
        import tomli as tomllib
    with open(path, "rb") as f:
        return tomllib.load(f)


# --------------------------------------------------------------------------
# Files
# --------------------------------------------------------------------------


def _deep_merge(base: Dict[str, Any], override: Mapping[str, Any]) -> Dict[str, Any]:
    out = dict(base)
    for k, v in override.items():
        if isinstance(v, Mapping) and isinstance(out.get(k), Mapping):
            out[k] = _deep_merge(dict(out[k]), v)
        else:
            out[k] = v
    return out


def load_spec(path: str, smoke: bool = False) -> Any:
    """Load a TOML/JSON campaign file into an ``AppSpec``. ``smoke=True``
    deep-merges the file's ``[smoke]`` table over the spec first (the
    file's own CI-sized form)."""
    if path.endswith(".json"):
        with open(path) as f:
            d = json.load(f)
    elif path.endswith(".toml"):
        d = _load_toml(path)
    else:
        raise ValueError(f"campaign file must be .toml or .json (got {path!r})")
    overrides = d.pop("smoke", None)
    if smoke:
        if not overrides:
            raise ValueError(f"{path} has no [smoke] table; cannot apply --smoke")
        d = _deep_merge(d, overrides)
    return spec_from_dict(d)


def save_spec(spec: Any, path: str) -> str:
    """Write the spec as TOML or JSON (by extension); returns the path."""
    d = spec_to_dict(spec)
    if path.endswith(".json"):
        body = json.dumps(d, indent=2, sort_keys=True) + "\n"
    elif path.endswith(".toml"):
        body = dumps_toml(d)
    else:
        raise ValueError(f"campaign file must be .toml or .json (got {path!r})")
    with open(path, "w") as f:
        f.write(body)
    return path


# --------------------------------------------------------------------------
# Spec diff: field-aware comparison of two campaign files
# --------------------------------------------------------------------------


def _load_raw(path: str, smoke: bool = False) -> Dict[str, Any]:
    """Load a campaign file as a raw dict (no import of task modules) so
    ``diff`` works even when a spec's ``fn`` targets are unimportable."""
    if path.endswith(".json"):
        with open(path) as f:
            d = json.load(f)
    elif path.endswith(".toml"):
        d = _load_toml(path)
    else:
        raise ValueError(f"campaign file must be .toml or .json (got {path!r})")
    overrides = d.pop("smoke", None)
    if smoke:
        if not overrides:
            raise ValueError(f"{path} has no [smoke] table; cannot apply --smoke")
        d = _deep_merge(d, overrides)
    return d


def _render_value(v: Any) -> str:
    """Human-readable rendering for diff lines: ``$ref``/``$call`` markers
    print as calls rather than opaque nested dicts."""
    if isinstance(v, Mapping):
        if "$ref" in v:
            return f"$ref({v['$ref']})"
        if "$call" in v:
            parts = [repr(a) for a in v.get("args", ())]
            parts += [f"{k}={r!r}" for k, r in v.get("kwargs", {}).items()]
            return f"$call({v['$call']})({', '.join(parts)})"
    return json.dumps(v, sort_keys=True, default=repr)


def _is_marker(v: Any) -> bool:
    return isinstance(v, Mapping) and ("$ref" in v or "$call" in v)


def _flatten_spec(d: Any, prefix: str = "") -> Dict[str, Any]:
    """Flatten a spec dict to ``dotted.path -> leaf`` pairs. ``$ref``/
    ``$call`` tables are leaves. The ``[[tasks]]`` array is keyed by each
    entry's method/fn name when unambiguous, so reordering tasks does not
    diff and per-task field changes anchor to the task's name."""
    flat: Dict[str, Any] = {}
    if isinstance(d, Mapping) and not _is_marker(d):
        if not d:
            flat[prefix] = {}
        for k, v in d.items():
            flat.update(_flatten_spec(v, f"{prefix}.{k}" if prefix else str(k)))
        return flat
    if isinstance(d, list) and d and all(isinstance(x, Mapping) for x in d) \
            and not any(_is_marker(x) for x in d):
        names = [x.get("method") or x.get("fn") for x in d]
        use_names = all(names) and len(set(names)) == len(names)
        for i, v in enumerate(d):
            key = names[i] if use_names else str(i)
            flat.update(_flatten_spec(v, f"{prefix}[{key}]"))
        return flat
    flat[prefix] = d
    return flat


def diff_spec_dicts(a: Mapping[str, Any], b: Mapping[str, Any]) -> List[str]:
    """Field-aware diff of two raw spec dicts. Returns human-readable
    lines (``~`` changed, ``+`` only in b, ``-`` only in a); empty means
    the specs are equivalent after migration to the current version."""
    lines: List[str] = []
    va, vb = _spec_version(a), _spec_version(b)
    if va != vb:
        note = []
        if va < SPEC_VERSION:
            note.append("a migrated")
        if vb < SPEC_VERSION:
            note.append("b migrated")
        suffix = f" ({', '.join(note)} to v{SPEC_VERSION} for comparison)" if note else ""
        lines.append(f"~ version: {va} -> {vb}{suffix}")
    fa = _flatten_spec(_migrate_spec_dict(a, va))
    fb = _flatten_spec(_migrate_spec_dict(b, vb))
    fa.pop("version", None)
    fb.pop("version", None)
    for path in sorted(set(fa) | set(fb)):
        if path not in fb:
            lines.append(f"- {path} = {_render_value(fa[path])}")
        elif path not in fa:
            lines.append(f"+ {path} = {_render_value(fb[path])}")
        elif fa[path] != fb[path]:
            lines.append(f"~ {path}: {_render_value(fa[path])} -> {_render_value(fb[path])}")
    return lines


# --------------------------------------------------------------------------
# CLI: python -m repro.app run campaign.toml
# --------------------------------------------------------------------------


def _cmd_run(args: argparse.Namespace) -> int:
    from .app import ColmenaApp

    spec = load_spec(args.path, smoke=args.smoke)
    if args.fresh and spec.campaign is not None:
        spec.campaign.resume = False
    if args.resume and spec.campaign is None:
        print("error: --resume needs a [campaign] section", file=sys.stderr)
        return 2
    app = ColmenaApp(spec)
    report = app.execute(timeout=args.timeout)
    print(f"campaign,completed,{int(report.completed)}")
    print(f"campaign,wall_seconds,{report.wall_seconds:.2f}")
    print(f"campaign,checkpoints_written,{report.checkpoints_written}")
    print(f"campaign,resumed_from,{report.resumed_from or ''}")
    print(f"campaign,tasks_completed,{report.server_metrics.get('tasks_completed', 0)}")
    obs = app.observe_report()
    if obs:
        print(f"campaign,makespan_s,{obs.get('makespan_s', 0.0)}")
        for pool, u in sorted(obs.get("utilization", {}).items()):
            print(f"utilization,{pool},{u}")
    return 0 if report.completed else 1


def _cmd_show(args: argparse.Namespace) -> int:
    spec = load_spec(args.path, smoke=args.smoke)
    print(json.dumps(spec_to_dict(spec), indent=2, sort_keys=True))
    return 0


def _cmd_diff(args: argparse.Namespace) -> int:
    a = _load_raw(args.a, smoke=args.smoke)
    b = _load_raw(args.b, smoke=args.smoke)
    lines = diff_spec_dicts(a, b)
    for line in lines:
        print(line)
    if not lines:
        print(f"specs are equivalent: {args.a} == {args.b}")
        return 0
    return 1


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.app",
        description="Launch or inspect a Colmena campaign defined in a TOML/JSON file.",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    run = sub.add_parser("run", help="compose and run the campaign")
    run.add_argument("path", help="campaign .toml or .json file")
    run.add_argument("--smoke", action="store_true",
                     help="apply the file's [smoke] override table")
    run.add_argument("--resume", action="store_true",
                     help="require a [campaign] section (resume is its default)")
    run.add_argument("--fresh", action="store_true",
                     help="ignore existing checkpoints (resume=False)")
    run.add_argument("--timeout", type=float, default=None,
                     help="wall-clock bound for the steering agents")
    run.set_defaults(fn=_cmd_run)

    show = sub.add_parser("show", help="print the normalized spec as JSON (diffable)")
    show.add_argument("path")
    show.add_argument("--smoke", action="store_true")
    show.set_defaults(fn=_cmd_show)

    diff = sub.add_parser(
        "diff", help="field-aware diff of two campaign files (exit 1 when they differ)"
    )
    diff.add_argument("a")
    diff.add_argument("b")
    diff.add_argument("--smoke", action="store_true",
                      help="apply each file's [smoke] override table before diffing")
    diff.set_defaults(fn=_cmd_diff)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except BrokenPipeError:
        # Output is CSV-ish lines meant for `| head` / `| grep -q`;
        # a consumer closing the pipe early is not a campaign failure.
        try:
            sys.stdout.close()
        except Exception:  # noqa: BLE001
            pass
        return 0
