"""Training substrate: optimizer, train step, compression, checkpoint, data."""

from .optimizer import OptimizerConfig, init_opt_state, apply_updates, lr_at
from .train_step import make_train_step, init_train_state
from .checkpoint import CheckpointManager
from .data import SyntheticLM, PrefetchLoader, DataConfig
from .grad_compress import (
    CompressedSync,
    compress_tree,
    decompress_tree,
    payload_bytes,
    quantize_int8,
    dequantize_int8,
)
