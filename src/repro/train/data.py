"""Deterministic synthetic data pipeline with host prefetch.

Produces language-model batches (tokens/labels and stub modality inputs)
from a seeded generator — reproducible across restarts (the pipeline
state is just ``(seed, step)``, which rides in the checkpoint `extra`).
A background prefetch thread keeps ``depth`` batches ready so host data
generation overlaps device compute (the paper's asynchronous-I/O lesson
applied to the input pipeline).

The token stream is not uniform noise: it is a Zipfian unigram mix with
a Markov bigram component so the model has learnable structure and the
end-to-end driver example shows a genuinely decreasing loss curve.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


@dataclass
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2
    markov_strength: float = 0.7


class SyntheticLM:
    """Zipf + Markov synthetic token stream."""

    def __init__(self, cfg: ModelConfig, seq_len: int, batch: int, dc: DataConfig = DataConfig()):
        self.cfg = cfg
        self.seq = seq_len
        self.batch = batch
        self.dc = dc
        v = cfg.vocab_size
        rng = np.random.default_rng(dc.seed)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = ranks ** -dc.zipf_a
        self.unigram /= self.unigram.sum()
        # sparse deterministic bigram: each token prefers (t*7 + 11) % v
        self.next_pref = (np.arange(v) * 7 + 11) % v

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.dc.seed, step))
        v = self.cfg.vocab_size
        toks = np.empty((self.batch, self.seq + 1), np.int32)
        toks[:, 0] = rng.choice(v, size=self.batch, p=self.unigram)
        draws = rng.random((self.batch, self.seq))
        fresh = rng.choice(v, size=(self.batch, self.seq), p=self.unigram)
        for t in range(1, self.seq + 1):
            follow = self.next_pref[toks[:, t - 1]]
            toks[:, t] = np.where(draws[:, t - 1] < self.dc.markov_strength, follow, fresh[:, t - 1])
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        cfg = self.cfg
        if cfg.family == "whisper":
            out["frames"] = rng.standard_normal(
                (self.batch, cfg.encoder_seq, cfg.d_model), np.float32) * 0.1
        if cfg.family == "vlm":
            out["patches"] = rng.standard_normal(
                (self.batch, cfg.vision_patches, cfg.d_model), np.float32) * 0.1
        return out


class PrefetchLoader:
    """Background-thread prefetch over SyntheticLM (or any step->batch fn)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.source = source
        self.step = start_step
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True, name="data-prefetch")
        self._thread.start()

    def _work(self) -> None:
        s = self.step
        while not self._stop.is_set():
            b = self.source.batch_at(s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
