"""Optimizers (hand-rolled: no optax in this environment).

* ``adamw`` — standard AdamW with decoupled weight decay.
* ``adafactor`` — factored second moment (row/col statistics for >=2-D
  params) + optional bf16 first moment. This is the memory lever that
  fits llama3-405B training on 256 x 16 GB chips: m in bf16 (2 B/param)
  + factored v (~0 B/param) instead of AdamW's 8 B/param.

Optimizer state dtype is configurable (``cfg.opt_state_dtype``); update
math always runs in f32.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    state_dtype: str = "float32"   # float32 | bfloat16


def lr_at(oc: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - oc.warmup_steps) / jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = oc.min_lr_frac + (1 - oc.min_lr_frac) * cos
    return oc.lr * warm * frac


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params: Any, oc: OptimizerConfig) -> Dict[str, Any]:
    dt = jnp.dtype(oc.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, oc: OptimizerConfig):
    step = state["step"] + 1
    lr = lr_at(oc, step)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(oc.state_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = m_new / bc1
        vh = v_new / bc2
        delta = lr * (mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - delta).astype(p.dtype), m_new.astype(dt), v_new.astype(dt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(state["m"])[0]
    flat_v = jax.tree_util.tree_flatten(state["v"])[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; memory-efficient for 405B)
# ---------------------------------------------------------------------------


def _factored(shape: Tuple[int, ...]) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params: Any, oc: OptimizerConfig) -> Dict[str, Any]:
    dt = jnp.dtype(oc.state_dtype)

    def v_init(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),         # row stats
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col stats
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "m": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, dt), params),
        "v": jax.tree_util.tree_map(v_init, params, is_leaf=lambda x: hasattr(x, "shape")),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(params, grads, state, oc: OptimizerConfig):
    step = state["step"] + 1
    lr = lr_at(oc, step)
    b2 = oc.b2
    dt = jnp.dtype(oc.state_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + 1e-30
        if _factored(p.shape):
            vr = b2 * v["vr"] + (1 - b2) * g2.mean(-1)
            vc = b2 * v["vc"] + (1 - b2) * g2.mean(-2)
            denom = (vr[..., None] * vc[..., None, :]) / jnp.maximum(
                vr.mean(-1)[..., None, None], 1e-30
            )
            precond = gf * jax.lax.rsqrt(denom + oc.eps)
            v_new = {"vr": vr, "vc": vc}
        else:
            vv = b2 * v["v"] + (1 - b2) * g2
            precond = gf * jax.lax.rsqrt(vv + oc.eps)
            v_new = {"v": vv}
        m_new = oc.b1 * m.astype(jnp.float32) + (1 - oc.b1) * precond
        delta = lr * (m_new + oc.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - delta).astype(p.dtype), m_new.astype(dt), v_new

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(state["m"])[0]
    flat_v = jax.tree_util.tree_leaves(
        state["v"], is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    )
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------


def init_opt_state(params: Any, oc: OptimizerConfig) -> Dict[str, Any]:
    return adafactor_init(params, oc) if oc.name == "adafactor" else adamw_init(params, oc)


def apply_updates(params, grads, state, oc: OptimizerConfig):
    if oc.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    else:
        gnorm = global_norm(grads)
    if oc.name == "adafactor":
        new_p, new_s = adafactor_update(params, grads, state, oc)
    else:
        new_p, new_s = adamw_update(params, grads, state, oc)
    return new_p, new_s, {"grad_norm": gnorm, "lr": lr_at(oc, new_s["step"])}
