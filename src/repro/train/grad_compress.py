"""Gradient compression with error feedback (cross-pod / multi-site sync).

Two mechanisms, mirroring the paper's two communication regimes:

1. **Within a pod** (ICI-connected): gradients are synced by the XLA SPMD
   partitioner; "compression" is dtype-level — ``cfg.grad_accum_dtype=
   bfloat16`` halves all-reduce bytes. Nothing to do here.

2. **Across pods / sites** (the paper's Globus multi-site deployments,
   where data moves through the ProxyStore fabric): gradients are
   quantized to int8 with per-row scales before transmission, and a
   local f32 *error-feedback* buffer accumulates the quantization
   residual so the compressed sync remains unbiased over time
   (EF-SGD). ~4x fewer fabric bytes per sync.

``CompressedSync`` is used by the multi-pod training driver: each pod
publishes its compressed gradient tree through the data fabric; the
reducer averages dequantized trees.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row symmetric int8 quantization. x: (..., d)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, error_buf: Optional[Any] = None):
    """Quantize a gradient pytree; returns (payload tree, new error buffer).

    The error buffer holds ``g_total - dequant(q)`` per leaf and is added
    to the next step's gradient before quantization (error feedback)."""
    leaves, tdef = jax.tree_util.tree_flatten(grads)
    err_leaves = (
        jax.tree_util.tree_flatten(error_buf)[0] if error_buf is not None
        else [jnp.zeros_like(l, jnp.float32) for l in leaves]
    )
    payload, new_err = [], []
    for g, e in zip(leaves, err_leaves):
        total = g.astype(jnp.float32) + e
        flat = total.reshape(-1, total.shape[-1]) if total.ndim > 1 else total.reshape(1, -1)
        q, scale = quantize_int8(flat)
        deq = dequantize_int8(q, scale).reshape(total.shape)
        payload.append({"q": q, "scale": scale, "shape": total.shape})
        new_err.append(total - deq)
    return (
        jax.tree_util.tree_unflatten(tdef, payload),
        jax.tree_util.tree_unflatten(tdef, new_err),
    )


def decompress_tree(payload: Any) -> Any:
    def one(p):
        return dequantize_int8(p["q"], p["scale"]).reshape(p["shape"])

    return jax.tree_util.tree_map(
        one, payload, is_leaf=lambda x: isinstance(x, dict) and "q" in x
    )


def payload_bytes(payload: Any) -> int:
    total = 0
    for p in jax.tree_util.tree_leaves(
        payload, is_leaf=lambda x: isinstance(x, dict) and "q" in x
    ):
        total += p["q"].size + p["scale"].size * 4
    return total


@dataclass
class CompressedSync:
    """Cross-pod gradient averaging through the data fabric.

    Each participant calls ``contribute(pod_id, grads)``; once all
    ``n_pods`` arrive, ``reduce()`` returns the dequantized average.
    Error-feedback buffers are per-pod local state."""

    n_pods: int
    error_bufs: Dict[int, Any] = field(default_factory=dict)
    _inbox: Dict[int, Any] = field(default_factory=dict)
    bytes_sent: int = 0
    bytes_uncompressed: int = 0

    def contribute(self, pod_id: int, grads: Any) -> Any:
        payload, new_err = compress_tree(grads, self.error_bufs.get(pod_id))
        self.error_bufs[pod_id] = new_err
        self._inbox[pod_id] = payload
        self.bytes_sent += payload_bytes(payload)
        self.bytes_uncompressed += sum(
            l.size * 4 for l in jax.tree_util.tree_leaves(grads)
        )
        return payload

    def ready(self) -> bool:
        return len(self._inbox) >= self.n_pods

    def reduce(self) -> Any:
        assert self.ready()
        trees = [decompress_tree(p) for p in self._inbox.values()]
        self._inbox.clear()
        return jax.tree_util.tree_map(
            lambda *xs: sum(xs) / len(xs), *trees
        )
